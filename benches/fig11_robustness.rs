//! Fig. 11 — robustness: OOM occurrence rate (11a) and SLO attainment (11b)
//! at fleet scale under dynamic traffic.
//!
//! Paper claims (single instance, steady load): HFT shows ~34% OOM error
//! rate beyond 50 RPS vs CoCoServe's ~2% (17× better); HFT's SLO
//! attainment deteriorates from ~25 RPS, CoCoServe holds to ~50, vLLM in
//! between. This bench runs the memory-tight stressor on an 8-instance
//! fleet (every device squeezed by a 12 GiB co-tenant) and sweeps the full
//! scenario library — steady, diurnal, burst, ramp, two-tenant — since OOM
//! churn is precisely a dynamic-traffic phenomenon.
//!
//! Every cell comes from the deterministic event kernel; one configuration
//! per scenario is re-run and byte-compared (golden replay) before the
//! table is reported.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::Trace;

const N_INSTANCES: usize = 8;
const N_DEVICES: usize = 8;
const RPS: f64 = 55.0;
const DURATION_S: f64 = 20.0;
const SEED: u64 = 21;

/// Memory-tight fleet: each device loses 12 GiB to a co-tenant, leaving
/// ~3.8 GiB of KV headroom next to the 13B weights — the robustness
/// stressor from the paper's Fig. 11 setup, replicated per device.
fn run(policy: SimPolicy, trace: &Trace) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    for d in 0..N_DEVICES {
        cluster.device_mut(d).alloc("co-tenant", 12.0 * GIB).unwrap();
    }
    let placements: Vec<_> = (0..N_INSTANCES)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % N_DEVICES),
                policy,
            )
        })
        .collect();
    let sim = Simulation::new(cfg, cluster, placements);
    sim.run(trace, DURATION_S)
}

fn main() {
    println!(
        "Fig. 11 — OOM rate & SLO attainment, {N_INSTANCES} instances on \
         {N_DEVICES} memory-tight A100s, {RPS:.0} rps aggregate\n"
    );
    let mut t = Table::new(&[
        "scenario", "hft OOM%", "vllm OOM%", "coco OOM%",
        "hft SLO%", "vllm SLO%", "coco SLO%",
    ]);
    let mut rep = Report::new("fig11_robustness");
    let mut replay_ok = true;
    let (mut h_oom_worst, mut c_oom_worst) = (0.0f64, 0.0f64);

    for (name, trace) in Trace::scenario_sweep(RPS, DURATION_S, SEED) {
        let h = run(baselines::hft(16), &trace);
        let v = run(baselines::vllm_like(48), &trace);
        let c = run(baselines::cocoserve(48), &trace);

        // golden replay on the most stateful configuration
        let c_again = run(baselines::cocoserve(48), &trace);
        let identical = c.to_json().to_string() == c_again.to_json().to_string();
        replay_ok &= identical;
        if !identical {
            eprintln!("WARNING: scenario `{name}` was not replay-deterministic");
        }

        let (ho, vo, co) = (h.oom_rate() * 100.0, v.oom_rate() * 100.0, c.oom_rate() * 100.0);
        let (hs, vs, cs) = (
            h.slo_attainment() * 100.0,
            v.slo_attainment() * 100.0,
            c.slo_attainment() * 100.0,
        );
        h_oom_worst = h_oom_worst.max(ho);
        c_oom_worst = c_oom_worst.max(co.max(0.1));
        t.row(&[
            name.to_string(),
            format!("{ho:.1}"),
            format!("{vo:.1}"),
            format!("{co:.1}"),
            format!("{hs:.1}"),
            format!("{vs:.1}"),
            format!("{cs:.1}"),
        ]);
        rep.set(
            name,
            json::obj(vec![
                ("oom_pct", json::arr([ho, vo, co].into_iter().map(json::num))),
                ("slo_pct", json::arr([hs, vs, cs].into_iter().map(json::num))),
                ("oom_events", json::arr(
                    [h.total_oom_events, v.total_oom_events, c.total_oom_events]
                        .into_iter()
                        .map(|n| json::num(n as f64)),
                )),
                ("coco_scale_downs", json::num(c.scale_downs as f64)),
                ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
            ]),
        );
    }

    t.print();
    println!(
        "\nworst-scenario OOM rate: HFT {h_oom_worst:.1}% vs CoCoServe {c_oom_worst:.1}% \
         → {:.0}× stability improvement (paper: 34% vs 2%, 17×)",
        h_oom_worst / c_oom_worst
    );
    println!(
        "golden replay across all scenarios: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
