//! Real-path performance — the L3 hot-path bench (EXPERIMENTS.md §Perf).
//!
//! Times the actual PJRT pipeline on the tiny model: prefill and decode
//! step latency per batch bucket, tokens/s, and the coordinator overhead
//! (host-side time outside `execute`). The perf pass iterates on this
//! bench; its criterion (DESIGN.md §Perf): the driver should be
//! PJRT-execute-bound, i.e. coordinator overhead well under 20%.

use cocoserve::engine::{LayerExec, TinyEngine};
use cocoserve::runtime::{artifacts_available, default_artifacts_dir};
use cocoserve::util::bench::{fmt_secs, time_it, Report, Table};
use cocoserve::util::json;

fn main() {
    if !artifacts_available() {
        eprintln!("skipping real_engine_perf: run `make artifacts`");
        return;
    }
    let engine = TinyEngine::open(&default_artifacts_dir(), "tiny-llama").unwrap();
    println!("real-path perf — tiny-llama on CPU PJRT\n");

    let mut rep = Report::new("real_engine_perf");
    let mut t = Table::new(&["op", "batch", "mean", "p95", "tok/s"]);

    for &b in &[1usize, 2, 4, 8] {
        // prefill
        let prompts: Vec<Vec<i32>> = (0..b).map(|i| vec![(i + 1) as i32; 12]).collect();
        let timing = time_it(2, 10, || {
            let mut seqs: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| engine.new_sequence(i as u64, p))
                .collect();
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.prefill(&mut refs).unwrap();
        });
        t.row(&[
            "prefill s16".into(),
            format!("{b}"),
            fmt_secs(timing.mean_s),
            fmt_secs(timing.p95_s),
            format!("{:.0}", b as f64 * 12.0 / timing.mean_s),
        ]);
        rep.set(&format!("prefill_b{b}_mean_s"), json::num(timing.mean_s));

        // decode (warm steady state)
        let mut seqs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| engine.new_sequence(i as u64, p))
            .collect();
        {
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.prefill(&mut refs).unwrap();
        }
        let timing = time_it(3, 30, || {
            // reset kv_len periodically to avoid overflow across iters
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            if refs[0].kv_len >= engine.max_seq - 2 {
                for r in refs.iter_mut() {
                    r.kv_len = 13;
                    r.tokens.truncate(13);
                }
            }
            engine.decode(&mut refs).unwrap();
        });
        t.row(&[
            "decode".into(),
            format!("{b}"),
            fmt_secs(timing.mean_s),
            fmt_secs(timing.p95_s),
            format!("{:.0}", b as f64 / timing.mean_s),
        ]);
        rep.set(&format!("decode_b{b}_mean_s"), json::num(timing.mean_s));
    }
    t.print();

    // fused vs split module execution overhead
    let mut eng2 = TinyEngine::open(&default_artifacts_dir(), "tiny-llama").unwrap();
    eng2.exec = LayerExec::Split;
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![(i + 1) as i32; 12]).collect();
    let fused = time_it(1, 5, || {
        engine.generate_greedy(&prompts, 8).unwrap();
    });
    let split = time_it(1, 5, || {
        eng2.generate_greedy(&prompts, 8).unwrap();
    });
    println!(
        "\ngenerate b4 n8: fused {} vs split-module {} ({:+.1}% — the cost of \
         projection-granular execution)",
        fmt_secs(fused.mean_s),
        fmt_secs(split.mean_s),
        (split.mean_s / fused.mean_s - 1.0) * 100.0
    );
    rep.set("fused_gen_s", json::num(fused.mean_s));
    rep.set("split_gen_s", json::num(split.mean_s));

    // coordinator overhead: wall time minus PJRT execute time share
    let execs_before = engine.pjrt.executions();
    let t0 = std::time::Instant::now();
    engine.generate_greedy(&prompts, 16).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let execs = engine.pjrt.executions() - execs_before;
    println!(
        "generate b4 n16: {} wall · {execs} PJRT executions · {:.2} ms/exec",
        fmt_secs(wall),
        wall / execs as f64 * 1e3
    );
    rep.set("gen_b4_n16_wall_s", json::num(wall));
    rep.set("gen_b4_n16_execs", json::num(execs as f64));
    println!("report: {}", rep.write().unwrap().display());
}
