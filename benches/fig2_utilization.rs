//! Fig. 2 — GPU resource utilization of HFT vs vLLM across request rates.
//!
//! Paper setup: single LLaMA-13B instance on one A100, RPS sweep, 5 repeats.
//! Claim to reproduce: at low rates (RPS ≤ 10) both frameworks leave
//! ~20–40% of GPU resources idle (static allocation), utilization climbs
//! with RPS.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: [f64; 6] = [1.0, 5.0, 10.0, 20.0, 35.0, 50.0];
const REPEATS: u64 = 5;

fn utilization(policy: SimPolicy, rps: f64, seed: u64) -> (f64, f64) {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(1, DeviceSpec::a100_40gb());
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
    let trace = Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), 20.0, seed);
    let r = sim.run(&trace, 20.0);
    let (_, compute, mem) = r.device_util[0];
    (compute, mem)
}

fn main() {
    println!("Fig. 2 — utilization vs RPS (13B on 1×A100, mean of {REPEATS} seeds)\n");
    let mut t = Table::new(&["rps", "hft compute%", "hft mem%", "vllm compute%", "vllm mem%"]);
    let mut rep = Report::new("fig2_utilization");
    let mut series: Vec<Vec<f64>> = vec![vec![]; 4];
    for &rps in &RPS {
        let mut acc = [0.0f64; 4];
        for seed in 0..REPEATS {
            let (hc, hm) = utilization(baselines::hft(16), rps, 100 + seed);
            let (vc, vm) = utilization(baselines::vllm_like(16), rps, 100 + seed);
            acc[0] += hc;
            acc[1] += hm;
            acc[2] += vc;
            acc[3] += vm;
        }
        for a in &mut acc {
            *a = *a / REPEATS as f64 * 100.0;
        }
        for (s, a) in series.iter_mut().zip(&acc) {
            s.push(*a);
        }
        t.row(&[
            format!("{rps:.0}"),
            format!("{:.1}", acc[0]),
            format!("{:.1}", acc[1]),
            format!("{:.1}", acc[2]),
            format!("{:.1}", acc[3]),
        ]);
    }
    t.print();

    // the paper's headline claim: ≥20% idle at RPS ≤ 10
    let low_idx = RPS.iter().position(|&r| r == 10.0).unwrap();
    let max_util_at_low = series[0][low_idx].max(series[2][low_idx]);
    println!(
        "\ncompute utilization at RPS=10: {:.1}% → {:.1}% idle (paper: 20–40% idle)",
        max_util_at_low,
        100.0 - max_util_at_low
    );

    rep.set("rps", json::arr(RPS.iter().map(|&x| json::num(x))));
    for (name, s) in ["hft_compute", "hft_mem", "vllm_compute", "vllm_mem"]
        .iter()
        .zip(&series)
    {
        rep.series(name, s);
    }
    let path = rep.write().expect("report");
    println!("report: {}", path.display());
}
