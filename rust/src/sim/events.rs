//! The discrete-event queue driving the simulation kernel.
//!
//! A binary heap of timestamped events with **fully deterministic
//! ordering**: events pop by ascending time, then by kind priority
//! (arrivals before their routing deliveries before forecast ticks
//! before controller ticks before device failures before scaling-op
//! starts/completions before
//! step completions before wake-ups — routing delivers before a
//! coinciding forecast tick closes its rate buckets, the forecast closes
//! before a coinciding controller tick consumes it, a device failure is
//! observed before any same-time op completion can land bytes on the
//! dead device, and scaling ops
//! apply before a coinciding step completion so the step's successor
//! sees the post-op placement), then by instance
//! id, then by insertion sequence. Two runs
//! over the same trace therefore process an identical event sequence,
//! which is what makes the golden-replay test (byte-identical metrics
//! JSON) possible.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The `idx`-th trace request reaches the router.
    Arrival { request_idx: usize },
    /// The coordinator routed trace request `request_idx` to `instance`;
    /// delivery (scheduler submission) happens when this event fires.
    /// Routed orders directly after Arrival so a routing decision made at
    /// an arrival's timestamp delivers before any same-time controller
    /// tick or step completion observes the queue.
    Routed { request_idx: usize, instance: usize },
    /// The predictive control plane advances its rate buckets to now.
    /// Scheduled only when a predictor is configured, at the controller
    /// period. Priority-slotted after `Routed` and before
    /// `ControllerTick`: a forecast closed at time t has seen every
    /// arrival routed at ≤ t, and a coinciding controller tick consumes
    /// *this* tick's forecast, never last period's.
    ForecastTick,
    /// The §5 controller evaluates every autoscaling instance.
    ControllerTick,
    /// Device `device` fails (spot preemption or hardware loss) at this
    /// instant: its memory is gone, its billing stops, and every instance
    /// holding modules on it recovers (plan rollback + emergency
    /// re-placement + request re-routing). A coordinator barrier like the
    /// ticks — it touches many instances and the fleet ledgers — slotted
    /// *before* `OpCompleted` so a same-time op completion targeting the
    /// dead device observes the failure (and its plan's abort) rather
    /// than landing bytes on a corpse.
    DeviceFailed { device: usize },
    /// Op `op_idx` of instance `instance`'s in-flight [`crate::plan::ScalePlan`]
    /// finishes: its ledger + placement effects apply now — this is what
    /// makes scaling overlap serving instead of pausing it. Completions
    /// order before starts so an abort invalidates the next op's start
    /// event (epoch bump) before it fires at the same instant.
    OpCompleted { instance: usize, op_idx: usize, epoch: u64 },
    /// Op `op_idx` begins its transfer. `epoch` guards against events of
    /// an aborted/superseded plan (stale epochs are ignored).
    OpStarted { instance: usize, op_idx: usize, epoch: u64 },
    /// Instance `instance` finishes the in-flight step started as its
    /// `token`-th step (stale completions — e.g. after an OOM rebuild
    /// cleared the step — carry an old token and are ignored).
    StepComplete { instance: usize, token: u64 },
    /// Re-poll instance `instance` (static-batch timeout or OOM backoff).
    Wake { instance: usize },
}

impl EventKind {
    /// Number of event kinds — the size of per-kind histogram tables
    /// (see [`crate::telemetry::profiler::KernelProfiler`]).
    pub const N_SLOTS: usize = 9;

    /// Display names indexed by [`EventKind::slot`], in priority order.
    pub const SLOT_NAMES: [&'static str; EventKind::N_SLOTS] = [
        "Arrival",
        "Routed",
        "ForecastTick",
        "ControllerTick",
        "DeviceFailed",
        "OpCompleted",
        "OpStarted",
        "StepComplete",
        "Wake",
    ];

    /// Dense per-kind index (`0..N_SLOTS`), equal to the kind's
    /// same-time precedence. Used by the kernel self-profiler to bucket
    /// dispatch wall-time and allocations per event kind.
    pub fn slot(&self) -> usize {
        self.priority() as usize
    }

    /// Precedence among same-time events (lower pops first).
    fn priority(&self) -> u8 {
        match self {
            EventKind::Arrival { .. } => 0,
            EventKind::Routed { .. } => 1,
            EventKind::ForecastTick => 2,
            EventKind::ControllerTick => 3,
            EventKind::DeviceFailed { .. } => 4,
            EventKind::OpCompleted { .. } => 5,
            EventKind::OpStarted { .. } => 6,
            EventKind::StepComplete { .. } => 7,
            EventKind::Wake { .. } => 8,
        }
    }

    /// Instance tie-break key (non-instance events sort first).
    fn instance_key(&self) -> usize {
        match self {
            EventKind::Arrival { .. }
            | EventKind::ForecastTick
            | EventKind::ControllerTick
            | EventKind::DeviceFailed { .. } => 0,
            EventKind::Routed { instance, .. }
            | EventKind::OpCompleted { instance, .. }
            | EventKind::OpStarted { instance, .. }
            | EventKind::StepComplete { instance, .. }
            | EventKind::Wake { instance } => *instance,
        }
    }
}

/// A scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Simulated firing time (seconds).
    pub time: f64,
    /// What fires.
    pub kind: EventKind,
    /// Monotone insertion counter — the final FIFO tie-break.
    seq: u64,
}

impl Event {
    fn key(&self) -> (f64, u8, usize, u64) {
        (self.time, self.kind.priority(), self.kind.instance_key(), self.seq)
    }
}

/// Min-heap wrapper (BinaryHeap is a max-heap, so the ordering is reversed).
#[derive(Debug)]
struct HeapEntry(Event);

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        let (ta, pa, ia, sa) = self.0.key();
        let (tb, pb, ib, sb) = other.0.key();
        // reversed: the greatest heap entry is the earliest event
        tb.total_cmp(&ta)
            .then(pb.cmp(&pa))
            .then(ib.cmp(&ia))
            .then(sb.cmp(&sa))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<HeapEntry>,
    next_seq: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `kind` to fire at `time` (must be finite).
    ///
    /// Non-finite time is a hard error in **all** builds: a NaN timestamp
    /// would silently corrupt `total_cmp` heap order (NaN sorts last) and
    /// with it every determinism guarantee the kernel makes — doubly so
    /// now that the shard merge relies on cross-queue key comparisons.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite(), "event scheduled at non-finite time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry(Event { time, kind, seq }));
    }

    /// Pop the earliest event (ties broken as the module docs describe).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|e| e.0)
    }

    /// The earliest event without popping it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|e| &e.0)
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Is nothing scheduled?
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Anything the kernel can schedule events into. The event handlers are
/// written against this so the sequential loop (one [`EventQueue`]) and
/// the sharded epoch loop ([`ShardedEventQueue`]) share one dispatch body
/// — which is the whole byte-parity argument: same handlers, same push
/// sequence, provably same pop order.
pub trait EventSink {
    /// Schedule `kind` to fire at `time` (must be finite).
    fn push(&mut self, time: f64, kind: EventKind);
}

impl EventSink for EventQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        EventQueue::push(self, time, kind);
    }
}

/// Strict `<` over the cross-queue merge key (time, kind priority,
/// instance id). `total_cmp` is safe here: push rejects non-finite times.
fn key3_lt(a: (f64, u8, usize), b: (f64, u8, usize)) -> bool {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)) == Ordering::Less
}

/// Strict `<` over the full per-queue key (merge key + FIFO seq). Only
/// ever decides ties *within* one shard (buffer front vs. its own queue
/// head, which share a seq counter); across queues the first three
/// components never tie — see [`ShardedEventQueue`].
fn key4_lt(a: (f64, u8, usize, u64), b: (f64, u8, usize, u64)) -> bool {
    a.0.total_cmp(&b.0)
        .then(a.1.cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .then(a.3.cmp(&b.3))
        == Ordering::Less
}

/// Below this many queued shard events an epoch drain runs inline —
/// spawning scoped threads costs more than it saves. The choice is
/// performance-only: drained-vs-live events merge identically either way.
const PARALLEL_DRAIN_MIN: usize = 4096;

/// One instance-group shard: its own deterministic queue plus the window
/// buffer the epoch fan-out drains into (front = next to merge).
#[derive(Debug, Default)]
struct Shard {
    queue: EventQueue,
    buffer: VecDeque<Event>,
}

impl Shard {
    /// Pop every event ordering strictly before `bound` (the next
    /// coordinator barrier; `None` = drain everything) into the buffer.
    fn drain_due(&mut self, bound: Option<(f64, u8, usize)>) {
        while let Some(e) = self.queue.peek() {
            let k = (e.time, e.kind.priority(), e.kind.instance_key());
            if let Some(b) = bound {
                if !key3_lt(k, b) {
                    break;
                }
            }
            let e = self.queue.pop().expect("peeked event");
            self.buffer.push_back(e);
        }
    }

    /// Full key of this shard's next event: the earlier of the buffer
    /// front and the live queue head (both keyed by one seq counter).
    fn head_key(&self) -> Option<((f64, u8, usize, u64), bool)> {
        let b = self.buffer.front().map(|e| e.key());
        let q = self.queue.peek().map(|e| e.key());
        match (b, q) {
            (None, None) => None,
            (Some(bk), None) => Some((bk, true)),
            (None, Some(qk)) => Some((qk, false)),
            (Some(bk), Some(qk)) => {
                // same counter, distinct seqs — strictly ordered
                if key4_lt(bk, qk) {
                    Some((bk, true))
                } else {
                    Some((qk, false))
                }
            }
        }
    }
}

/// The sharded event queue behind the epoch-barrier drive loop.
///
/// Events split by kind: **global** kinds (`Arrival`, `ForecastTick`,
/// `ControllerTick`, `DeviceFailed` — the coordinator barriers) live in
/// one global queue; **instance-local** kinds (`Routed`, `OpStarted`,
/// `OpCompleted`, `StepComplete`, `Wake`) go to the shard owning their instance
/// (`instance % n_shards`). Within an epoch — the span between two
/// global events — each shard drains its due events independently (in
/// parallel via [`std::thread::scope`] when there is enough queued work),
/// and [`ShardedEventQueue::pop_merged`] merges shard windows and barrier
/// events back into one stream.
///
/// ### Why the merged order is *identical* to one [`EventQueue`]
///
/// The single-queue order is (time, kind priority, instance id, FIFO
/// seq). Across sub-queues the first three components never tie: global
/// kinds hold priorities {0, 2, 3, 4} and local kinds {1, 5, 6, 7, 8}
/// (disjoint), and two local events with equal (time, priority) in
/// different shards name different instances by construction. A tie can
/// therefore only occur *within* one sub-queue, where its own FIFO
/// counter reproduces global push order (pushes interleave identically —
/// the kernel pushes in the same sequence either way). Hence per-queue
/// seq counters suffice, and the merge is exact — the property test
/// below drives randomly split streams through both paths and asserts
/// equality.
#[derive(Debug)]
pub struct ShardedEventQueue {
    shards: Vec<Shard>,
    global: EventQueue,
}

impl ShardedEventQueue {
    /// A queue with `n_shards` instance-group shards (≥ 1).
    pub fn new(n_shards: usize) -> ShardedEventQueue {
        assert!(n_shards >= 1, "need at least one shard");
        ShardedEventQueue {
            shards: (0..n_shards).map(|_| Shard::default()).collect(),
            global: EventQueue::new(),
        }
    }

    /// Number of instance-group shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns `kind` (`None` = the global barrier queue).
    fn shard_of(&self, kind: &EventKind) -> Option<usize> {
        match kind {
            EventKind::Arrival { .. }
            | EventKind::ForecastTick
            | EventKind::ControllerTick
            | EventKind::DeviceFailed { .. } => None,
            _ => Some(kind.instance_key() % self.shards.len()),
        }
    }

    /// Events currently scheduled (all shards + barriers + windows).
    pub fn len(&self) -> usize {
        self.global.len()
            + self.shards.iter().map(|s| s.queue.len() + s.buffer.len()).sum::<usize>()
    }

    /// Is nothing scheduled?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Start-of-epoch fan-out: when every window buffer is empty, pop
    /// each shard's events ordering before the next coordinator barrier
    /// (the global queue's head) into that shard's window buffer — in
    /// parallel across shards when enough work is queued to pay for the
    /// threads. Mid-epoch (windows still being consumed) this is a no-op;
    /// events scheduled during the epoch stay in their live shard queues
    /// and merge through [`Self::pop_merged`]'s head comparison, so the
    /// buffered/live split never affects the merged order.
    pub fn drain_epoch(&mut self) {
        if self.shards.iter().any(|s| !s.buffer.is_empty()) {
            return;
        }
        let bound = self
            .global
            .peek()
            .map(|e| (e.time, e.kind.priority(), e.kind.instance_key()));
        let queued: usize = self.shards.iter().map(|s| s.queue.len()).sum();
        if self.shards.len() >= 2 && queued >= PARALLEL_DRAIN_MIN {
            std::thread::scope(|scope| {
                for shard in self.shards.iter_mut() {
                    scope.spawn(move || shard.drain_due(bound));
                }
            });
        } else {
            for shard in self.shards.iter_mut() {
                shard.drain_due(bound);
            }
        }
    }

    /// Pop the earliest event across every shard window, live shard
    /// queue, and the global barrier queue — the deterministic K-way
    /// merge. Exactly reproduces a single queue's pop order (see the
    /// type-level docs for the tie-impossibility argument).
    pub fn pop_merged(&mut self) -> Option<Event> {
        enum Src {
            Shard(usize, bool), // (index, from_buffer)
            Global,
        }
        let mut best: Option<((f64, u8, usize, u64), Src)> = None;
        let beats = |k: (f64, u8, usize, u64), best: &Option<((f64, u8, usize, u64), Src)>| {
            match best {
                None => true,
                Some((bk, _)) => key4_lt(k, *bk),
            }
        };
        for (i, shard) in self.shards.iter().enumerate() {
            if let Some((k, from_buffer)) = shard.head_key() {
                if beats(k, &best) {
                    best = Some((k, Src::Shard(i, from_buffer)));
                }
            }
        }
        if let Some(e) = self.global.peek() {
            let k = e.key();
            if beats(k, &best) {
                best = Some((k, Src::Global));
            }
        }
        match best?.1 {
            Src::Shard(i, true) => self.shards[i].buffer.pop_front(),
            Src::Shard(i, false) => self.shards[i].queue.pop(),
            Src::Global => self.global.pop(),
        }
    }
}

impl EventSink for ShardedEventQueue {
    fn push(&mut self, time: f64, kind: EventKind) {
        match self.shard_of(&kind) {
            None => self.global.push(time, kind),
            Some(s) => self.shards[s].queue.push(time, kind),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn drain(q: &mut EventQueue) -> Vec<Event> {
        let mut v = vec![];
        while let Some(e) = q.pop() {
            v.push(e);
        }
        v
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::ControllerTick);
        q.push(1.0, EventKind::Arrival { request_idx: 0 });
        q.push(2.0, EventKind::StepComplete { instance: 0, token: 1 });
        let times: Vec<f64> = drain(&mut q).iter().map(|e| e.time).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn same_time_orders_by_kind_priority() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Wake { instance: 0 });
        q.push(5.0, EventKind::StepComplete { instance: 0, token: 1 });
        q.push(5.0, EventKind::ControllerTick);
        q.push(5.0, EventKind::Routed { request_idx: 7, instance: 0 });
        q.push(5.0, EventKind::Arrival { request_idx: 7 });
        q.push(5.0, EventKind::ForecastTick);
        q.push(5.0, EventKind::OpCompleted { instance: 0, op_idx: 0, epoch: 1 });
        q.push(5.0, EventKind::OpStarted { instance: 0, op_idx: 1, epoch: 1 });
        q.push(5.0, EventKind::DeviceFailed { device: 2 });
        let kinds: Vec<EventKind> = drain(&mut q).iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Arrival { request_idx: 7 },
                EventKind::Routed { request_idx: 7, instance: 0 },
                EventKind::ForecastTick,
                EventKind::ControllerTick,
                EventKind::DeviceFailed { device: 2 },
                EventKind::OpCompleted { instance: 0, op_idx: 0, epoch: 1 },
                EventKind::OpStarted { instance: 0, op_idx: 1, epoch: 1 },
                EventKind::StepComplete { instance: 0, token: 1 },
                EventKind::Wake { instance: 0 },
            ]
        );
    }

    #[test]
    fn same_time_same_kind_orders_by_instance_then_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::StepComplete { instance: 2, token: 1 });
        q.push(1.0, EventKind::StepComplete { instance: 0, token: 4 });
        q.push(1.0, EventKind::StepComplete { instance: 0, token: 9 });
        let popped = drain(&mut q);
        assert_eq!(popped[0].kind, EventKind::StepComplete { instance: 0, token: 4 });
        assert_eq!(popped[1].kind, EventKind::StepComplete { instance: 0, token: 9 });
        assert_eq!(popped[2].kind, EventKind::StepComplete { instance: 2, token: 1 });
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::ControllerTick);
        q.push(1.0, EventKind::ControllerTick);
        assert_eq!(q.pop().unwrap().time, 1.0);
        q.push(0.5, EventKind::Wake { instance: 3 });
        q.push(3.0, EventKind::ControllerTick);
        assert_eq!(q.pop().unwrap().time, 0.5);
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.len(), 2);
    }

    #[test]
    #[should_panic(expected = "non-finite time")]
    fn non_finite_time_is_a_hard_error_in_all_builds() {
        // regression: this used to be a debug_assert!, so a release build
        // would silently accept NaN and corrupt the heap order
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventKind::ControllerTick);
    }

    #[test]
    #[should_panic(expected = "non-finite time")]
    fn infinite_time_is_rejected_too() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, EventKind::Wake { instance: 0 });
    }

    /// Random event kind for the merge property (same-time batches across
    /// all kinds and instances).
    fn arbitrary_kind(r: &mut Rng) -> EventKind {
        let instance = r.below(6) as usize;
        match r.below(9) {
            0 => EventKind::Arrival { request_idx: r.below(50) as usize },
            1 => EventKind::Routed { request_idx: r.below(50) as usize, instance },
            2 => EventKind::ForecastTick,
            3 => EventKind::ControllerTick,
            8 => EventKind::DeviceFailed { device: r.below(4) as usize },
            4 => EventKind::OpCompleted {
                instance,
                op_idx: r.below(4) as usize,
                epoch: r.below(3),
            },
            5 => EventKind::OpStarted {
                instance,
                op_idx: r.below(4) as usize,
                epoch: r.below(3),
            },
            6 => EventKind::StepComplete { instance, token: r.below(20) },
            _ => EventKind::Wake { instance },
        }
    }

    /// Property: splitting a push stream across K shards and merging back
    /// pops the exact sequence a single sequential queue pops over the
    /// union — with randomized same-time batches across kinds/instances,
    /// interleaved pops, and epoch drains exercising the window buffers.
    #[test]
    fn prop_shard_merge_matches_sequential_queue() {
        prop::check(
            "shard-merge-parity",
            |r: &mut Rng| {
                // (time, kind) pushes from a coarse time grid so same-time
                // ties across kinds + instances are common, plus an action
                // tape: 0 = push, 1 = pop, 2 = drain_epoch
                let pushes: Vec<(f64, EventKind)> = (0..120)
                    .map(|_| (r.below(8) as f64 * 0.5, arbitrary_kind(r)))
                    .collect();
                let actions: Vec<u8> =
                    (0..200).map(|_| r.below(3) as u8).collect();
                let k = 1 + r.below(5) as usize;
                (pushes, actions, k)
            },
            |(pushes, actions, k)| {
                let mut single = EventQueue::new();
                let mut sharded = ShardedEventQueue::new(*k);
                let mut next_push = 0usize;
                for &a in actions {
                    match a {
                        0 if next_push < pushes.len() => {
                            let (t, kind) = pushes[next_push];
                            next_push += 1;
                            single.push(t, kind);
                            EventSink::push(&mut sharded, t, kind);
                        }
                        1 => {
                            let want = single.pop().map(|e| (e.time, e.kind));
                            let got = sharded.pop_merged().map(|e| (e.time, e.kind));
                            if want != got {
                                return Err(format!("pop mismatch: {want:?} vs {got:?}"));
                            }
                        }
                        _ => sharded.drain_epoch(),
                    }
                }
                // flush the remainder in lockstep
                loop {
                    let want = single.pop().map(|e| (e.time, e.kind));
                    let got = sharded.pop_merged().map(|e| (e.time, e.kind));
                    if want != got {
                        return Err(format!("tail mismatch: {want:?} vs {got:?}"));
                    }
                    if want.is_none() {
                        return Ok(());
                    }
                }
            },
        );
    }

    #[test]
    fn shard_merge_interleaves_barrier_and_local_events() {
        // at one timestamp: Arrival(0) < Routed(1) < Forecast(2) <
        // Controller(3) < DeviceFailed(4) < locals — the merge must
        // interleave the global queue between local priorities, not
        // treat it as one block
        let mut q = ShardedEventQueue::new(2);
        EventSink::push(&mut q, 1.0, EventKind::StepComplete { instance: 3, token: 9 });
        EventSink::push(&mut q, 1.0, EventKind::ControllerTick);
        EventSink::push(&mut q, 1.0, EventKind::Routed { request_idx: 0, instance: 4 });
        EventSink::push(&mut q, 1.0, EventKind::Arrival { request_idx: 0 });
        q.drain_epoch(); // windows stop at the Arrival barrier
        let mut kinds = vec![];
        while let Some(e) = q.pop_merged() {
            kinds.push(e.kind);
        }
        assert_eq!(
            kinds,
            vec![
                EventKind::Arrival { request_idx: 0 },
                EventKind::Routed { request_idx: 0, instance: 4 },
                EventKind::ControllerTick,
                EventKind::StepComplete { instance: 3, token: 9 },
            ]
        );
    }

    #[test]
    fn determinism_across_identical_push_sequences() {
        let build = || {
            let mut q = EventQueue::new();
            for i in 0..50 {
                let t = (i * 7 % 13) as f64 * 0.5;
                q.push(t, EventKind::StepComplete { instance: i % 4, token: i as u64 });
                q.push(t, EventKind::Wake { instance: (i + 1) % 4 });
            }
            q
        };
        let a: Vec<(f64, EventKind)> =
            drain(&mut build()).iter().map(|e| (e.time, e.kind)).collect();
        let b: Vec<(f64, EventKind)> =
            drain(&mut build()).iter().map(|e| (e.time, e.kind)).collect();
        assert_eq!(a, b);
    }
}
