"""L2 correctness: module functions compose to the reference model.

The key invariants:
  * decoder layer == attn block + ffn block == qkv/core/o_proj + ffn
    (module-level migration must not change semantics — paper §3.1
    "preservation of model semantics during these operations"),
  * prefill-then-decode == one longer prefill (KV-cache correctness),
  * padding never leaks into real positions (the Rust scheduler pads to
    shape buckets).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref

CFG = configs.TINY


@pytest.fixture(scope="module")
def weights():
    return model.init_weights(CFG, seed=3)


def layer_args(weights, i=0):
    lw = weights["layers"][i]
    return [lw[n] for n in model.LAYER_WEIGHT_NAMES]


def make_hidden(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((b, s, CFG.d_model), dtype=np.float32))


def positions(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


class TestModuleComposition:
    def test_layer_equals_attn_plus_ffn(self, weights):
        b, s = 2, 16
        hid, pos = make_hidden(b, s), positions(b, s)
        la = layer_args(weights)
        want, wk, wv = model.layer_prefill(hid, pos, *la,
                                           n_heads=CFG.n_heads)
        mid, k, v = model.attn_prefill(hid, pos, *la[:5],
                                       n_heads=CFG.n_heads)
        (got,) = model.ffn(mid, *la[5:])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(k, wk, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(v, wv, rtol=1e-5, atol=1e-5)

    def test_attn_equals_projection_granularity(self, weights):
        """qkv_proj + attn_core + o_proj == attn_prefill — the projection-
        level migration units of §3.3 compose exactly."""
        b, s = 2, 16
        hid, pos = make_hidden(b, s), positions(b, s)
        la = layer_args(weights)
        want, wk, wv = model.attn_prefill(hid, pos, *la[:5],
                                          n_heads=CFG.n_heads)
        q, k, v = model.qkv_proj(hid, pos, *la[:4], n_heads=CFG.n_heads)
        (core,) = model.attn_core_prefill(q, k, v)
        (got,) = model.o_proj(hid, core, la[4])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(k, wk, rtol=1e-5, atol=1e-5)

    def test_layer_matches_jnp_reference(self, weights):
        b, s = 2, 32
        hid, pos = make_hidden(b, s), positions(b, s)
        la = layer_args(weights)
        got, gk, gv = model.layer_prefill(hid, pos, *la, n_heads=CFG.n_heads)
        wd = dict(weights["layers"][0])
        wd["n_heads"] = CFG.n_heads
        want, wk, wv = ref.decoder_layer_prefill(hid, pos, wd)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gk, wk, rtol=1e-4, atol=1e-4)


class TestKvCacheConsistency:
    def test_prefill_then_decode_matches_longer_prefill(self, weights):
        """Decode step t+1 after prefilling t tokens must equal prefilling
        t+1 tokens — the KV-cache contract the Rust engine relies on."""
        b, s = 2, 8
        S = configs.MAX_SEQ_LEN
        rng = np.random.default_rng(7)
        full = jnp.asarray(
            rng.standard_normal((b, s + 1, CFG.d_model), dtype=np.float32))
        la = layer_args(weights)

        want, _, _ = model.layer_prefill(
            full, positions(b, s + 1), *la, n_heads=CFG.n_heads)

        hid, k, v = model.layer_prefill(
            full[:, :s], positions(b, s), *la, n_heads=CFG.n_heads)
        kc = jnp.zeros((b, CFG.n_heads, S, CFG.head_dim))
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :, :s, :].set(k)
        vc = vc.at[:, :, :s, :].set(v)
        lens = jnp.full((b,), s, jnp.int32)
        got, k_new, v_new = model.layer_decode(
            full[:, s:s + 1], kc, vc, lens, *la, n_heads=CFG.n_heads)

        np.testing.assert_allclose(
            got[:, 0], want[:, s], rtol=1e-4, atol=1e-4)
        assert k_new.shape == (b, CFG.n_heads, CFG.head_dim)

    def test_decode_per_sequence_lengths(self, weights):
        """Batched decode with *different* seq_lens must equal independent
        single-sequence decodes (continuous batching correctness)."""
        S = configs.MAX_SEQ_LEN
        la = layer_args(weights)
        rng = np.random.default_rng(11)

        lens_host = [5, 9]
        hid = jnp.asarray(
            rng.standard_normal((2, 1, CFG.d_model), dtype=np.float32))
        kc = jnp.asarray(rng.standard_normal(
            (2, CFG.n_heads, S, CFG.head_dim), dtype=np.float32))
        vc = jnp.asarray(rng.standard_normal(
            (2, CFG.n_heads, S, CFG.head_dim), dtype=np.float32))
        lens = jnp.asarray(lens_host, jnp.int32)

        got, _, _ = model.layer_decode(hid, kc, vc, lens, *la,
                                       n_heads=CFG.n_heads)
        for i, L in enumerate(lens_host):
            want, _, _ = model.layer_decode(
                hid[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                jnp.asarray([L], jnp.int32), *la, n_heads=CFG.n_heads)
            np.testing.assert_allclose(got[i], want[0], rtol=1e-4, atol=1e-4)

    def test_decode_ignores_stale_cache_beyond_len(self, weights):
        """Slots >= seq_len are masked: garbage there must not matter —
        this is what makes bucket-padded prefill KV safe."""
        S = configs.MAX_SEQ_LEN
        la = layer_args(weights)
        rng = np.random.default_rng(13)
        hid = jnp.asarray(
            rng.standard_normal((1, 1, CFG.d_model), dtype=np.float32))
        kc = jnp.asarray(rng.standard_normal(
            (1, CFG.n_heads, S, CFG.head_dim), dtype=np.float32))
        vc = jnp.asarray(rng.standard_normal(
            (1, CFG.n_heads, S, CFG.head_dim), dtype=np.float32))
        lens = jnp.asarray([6], jnp.int32)
        got, _, _ = model.layer_decode(hid, kc, vc, lens, *la,
                                       n_heads=CFG.n_heads)
        # poison everything beyond the written slot (index 6)
        kc2 = kc.at[:, :, 7:, :].set(1e6)
        vc2 = vc.at[:, :, 7:, :].set(-1e6)
        got2, _, _ = model.layer_decode(hid, kc2, vc2, lens, *la,
                                        n_heads=CFG.n_heads)
        np.testing.assert_allclose(got, got2, rtol=1e-5, atol=1e-5)


class TestPadding:
    def test_batch_padding_does_not_change_real_rows(self, weights):
        """Bucket-padding the batch axis must not perturb real sequences."""
        b, s = 2, 16
        hid, pos = make_hidden(b, s), positions(b, s)
        la = layer_args(weights)
        want, _, _ = model.layer_prefill(hid, pos, *la, n_heads=CFG.n_heads)
        pad = jnp.concatenate([hid, jnp.zeros((2, s, CFG.d_model))], axis=0)
        ppos = positions(4, s)
        got, _, _ = model.layer_prefill(pad, ppos, *la, n_heads=CFG.n_heads)
        np.testing.assert_allclose(got[:b], want, rtol=1e-5, atol=1e-5)

    def test_lm_head_uses_true_length(self, weights):
        """With tail padding, lm_head must read position len-1, not s-1."""
        b, s = 2, 16
        hid = make_hidden(b, s, seed=5)
        lens = jnp.asarray([7, 12], jnp.int32)
        tok, logits = model.lm_head_prefill(
            hid, lens, weights["rms_f"], weights["w_out"])
        for i, L in enumerate([7, 12]):
            x = ref.rmsnorm(hid[i, L - 1], weights["rms_f"])
            want = jnp.argmax(x @ weights["w_out"])
            assert int(tok[i]) == int(want)
        assert logits.shape == (b, CFG.vocab_size)


class TestEmbedAndHead:
    def test_embed_gathers_rows(self, weights):
        toks = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        (hid,) = model.embed(toks, weights["emb"])
        np.testing.assert_allclose(hid[0, 0], weights["emb"][1])
        np.testing.assert_allclose(hid[1, 1], weights["emb"][4])

    def test_lm_head_decode_matches_prefill_at_len1(self, weights):
        hid = make_hidden(2, 1, seed=9)
        t1, l1 = model.lm_head_decode(hid, weights["rms_f"],
                                      weights["w_out"])
        t2, l2 = model.lm_head_prefill(hid, jnp.asarray([1, 1], jnp.int32),
                                       weights["rms_f"], weights["w_out"])
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


class TestForwardGreedy:
    def test_deterministic(self, weights):
        out1 = model.forward_greedy(CFG, weights, [[1, 2, 3]], 4)
        out2 = model.forward_greedy(CFG, weights, [[1, 2, 3]], 4)
        assert out1 == out2
        assert len(out1[0]) == 7

    def test_batch_independence(self, weights):
        """Greedy outputs for a prompt must not depend on batch-mates."""
        a = model.forward_greedy(CFG, weights, [[5, 6, 7]], 3)[0]
        b = model.forward_greedy(CFG, weights,
                                 [[5, 6, 7], [9, 10, 11, 12]], 3)[0]
        assert a == b

    def test_tokens_in_vocab(self, weights):
        out = model.forward_greedy(CFG, weights, [[0, 1]], 5)[0]
        assert all(0 <= t < CFG.vocab_size for t in out)
