//! Compiled placement profiles — the zero-allocation step-cost kernel.
//!
//! The simulator's roofline step costs (`prefill_step_time` /
//! `decode_step_time`) are the hottest code in a fleet-scale run: they
//! execute once per serving step per instance. Walking the [`Placement`]
//! directly pays O(layers × replicas) with two heap allocations *per
//! layer* per call (`layer_devices` builds a `Vec`, `split_batch`
//! allocates the shares). A [`PlacementProfile`] compiles the placement
//! once — Noria-style: compile the dataflow, invalidate incrementally —
//! into contiguous per-layer device-group segments with the roofline
//! coefficients (effective FLOPs, HBM bandwidth) precomputed, so the step
//! costs become allocation-free linear scans over flat arrays.
//!
//! ### Determinism contract
//!
//! A profile is a *cache*, never a re-derivation: its scans perform the
//! **same f64 operations in the same order** as the uncompiled reference
//! walk over `Placement` + `Cluster`:
//!
//! * segments store devices in `layer_device_iter` order (primary first,
//!   replicas in creation order), so the per-replica `max` fold visits
//!   the same operands in the same order;
//! * batch shares are recomputed arithmetically (`base + (i < extra)`) —
//!   integer math, exactly [`crate::scheduler::split_batch`]'s values;
//! * `effective_flops` (`peak × mfu`) and `hbm_bw` are pure functions of
//!   the static [`crate::cluster::DeviceSpec`], so hoisting them to
//!   compile time cannot change a bit.
//!
//! The `profile_cache` integration test asserts this bit-for-bit
//! (`f64::to_bits`) against an uncompiled reference across randomized
//! plan mutations.
//!
//! ### Invalidation
//!
//! Profiles are keyed by an epoch the owner bumps on every placement
//! mutation. In the simulator that is exactly the plan lifecycle: an
//! `OpCompleted` event applying a [`crate::plan::ScalePlan`] op, a
//! mid-flight rollback, or an emergency scale-down. Steady-state serving
//! never recompiles.

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::Cluster;
use crate::model::cost::{CostModel, Shape};
use crate::model::{ModuleId, ModuleKind};

use super::Placement;

/// Per-layer communication constant of the §3.2 transition term (launch
/// latency of a scatter/all-gather pair). Kept identical to the inline
/// constant the uncompiled step costs used.
const TRANSITION_LAUNCH_S: f64 = 20e-6;

/// A placement compiled against a cluster's device specs: flat per-layer
/// device-group segments plus every placement-derived constant the serving
/// hot path needs. Rebuild via [`PlacementProfile::compile`] whenever the
/// placement changes; everything here is otherwise immutable.
#[derive(Debug, Clone)]
pub struct PlacementProfile {
    /// Decoder-layer count of the compiled placement.
    pub n_layers: usize,
    /// Cache key: the owner's placement revision at compile time.
    pub epoch: u64,
    /// Segment offsets: layer `l`'s device entries live at
    /// `seg_off[l]..seg_off[l + 1]` in the flat arrays below.
    seg_off: Vec<u32>,
    /// Effective sustained FLOPs of each device entry (peak × MFU).
    seg_eff_flops: Vec<f64>,
    /// HBM bandwidth of each device entry (decode-roofline denominator).
    seg_hbm_bw: Vec<f64>,
    /// Device id of each entry (diagnostics + tests).
    seg_device: Vec<u32>,
    /// Precompiled `Placement::transition_count()`.
    pub transitions: usize,
    /// Link bandwidth the transition term divides by (device 0's, as in
    /// the uncompiled reference).
    link_bw0: f64,
    /// Effective FLOPs of layer 0's primary device (embed + lm_head term).
    head_eff_flops: f64,
    /// Mean layer degree — the batch-capacity multiplier (Fig. 4 lanes).
    pub mean_degree: f64,
    /// Distinct devices hosting any copy of any layer, ascending — the
    /// busy-charge set (BTreeSet iteration order, precompiled).
    pub device_set: Vec<usize>,
    /// Distinct primary devices, ascending — the §8 contention footprint.
    pub primary_set: Vec<usize>,
    /// Primary device per layer, in layer order (hottest-device scans).
    pub primary_devices: Vec<usize>,
    /// KV-cache residency groups: (device, layer count), ascending by
    /// device — the per-device grouping `sync_kv` mirrors into ledgers.
    pub kv_groups: Vec<(usize, u32)>,
}

impl PlacementProfile {
    /// Flatten `placement` against `cluster`'s device specs. Allocates —
    /// called only at deploy time and at plan-epoch invalidation points,
    /// never on the steady-state step path.
    pub fn compile(placement: &Placement, cluster: &Cluster, epoch: u64) -> PlacementProfile {
        let n = placement.n_layers;
        let mut seg_off = Vec::with_capacity(n + 1);
        let mut seg_eff_flops = Vec::new();
        let mut seg_hbm_bw = Vec::new();
        let mut seg_device = Vec::new();
        let mut device_set = BTreeSet::new();
        seg_off.push(0u32);
        for l in 0..n {
            for d in placement.layer_device_iter(l) {
                let spec = &cluster.device(d).spec;
                seg_eff_flops.push(spec.effective_flops());
                seg_hbm_bw.push(spec.hbm_bw);
                seg_device.push(d as u32);
                device_set.insert(d);
            }
            seg_off.push(seg_eff_flops.len() as u32);
        }
        let primary_devices: Vec<usize> =
            (0..n).map(|l| placement.primary_device(l)).collect();
        let primary_set: Vec<usize> =
            primary_devices.iter().copied().collect::<BTreeSet<_>>().into_iter().collect();
        let mean_degree = (0..n).map(|l| placement.degree(l) as f64).sum::<f64>()
            / n.max(1) as f64;
        let mut kv_counts: BTreeMap<usize, u32> = BTreeMap::new();
        for l in 0..n {
            let d = placement.module_device(ModuleId::layer(ModuleKind::KvCache, l));
            *kv_counts.entry(d).or_insert(0) += 1;
        }
        let head_device = primary_devices.first().copied().unwrap_or(0);
        PlacementProfile {
            n_layers: n,
            epoch,
            seg_off,
            seg_eff_flops,
            seg_hbm_bw,
            seg_device,
            transitions: placement.transition_count(),
            link_bw0: cluster.device(0).spec.link_bw,
            head_eff_flops: cluster.device(head_device).spec.effective_flops(),
            mean_degree,
            device_set: device_set.into_iter().collect(),
            primary_set,
            primary_devices,
            kv_groups: kv_counts.into_iter().collect(),
        }
    }

    /// Device ids of layer `l`'s segment (primary first) — tests/debug.
    pub fn layer_segment(&self, l: usize) -> &[u32] {
        &self.seg_device[self.seg_off[l] as usize..self.seg_off[l + 1] as usize]
    }

    /// Effective FLOPs of the slowest device hosting any module of this
    /// placement — the pipeline bottleneck. Heterogeneous-fleet capacity
    /// math scales instance-equivalents by this against a reference
    /// device, so a V100-hosted instance prices below an H100-hosted one
    /// (on a homogeneous fleet the ratio is exactly 1.0 and every legacy
    /// number is bit-identical).
    pub fn min_eff_flops(&self) -> f64 {
        self.seg_eff_flops
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
            .min(self.head_eff_flops)
    }

    /// Per-layer prefill time across replicas: batch split (Fig. 4), max
    /// over replicas, plus scatter/gather per dataflow transition and the
    /// embed/lm_head term. Allocation-free; bit-identical to the
    /// uncompiled reference walk.
    pub fn prefill_step_time(
        &self,
        cost: &CostModel,
        dtype_bytes: usize,
        batch: usize,
        seq: usize,
    ) -> f64 {
        let d = cost.cfg.d_model as f64;
        let dt = dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..self.n_layers {
            let (a, b) = (self.seg_off[l] as usize, self.seg_off[l + 1] as usize);
            let degree = b - a;
            let (base, extra) = (batch / degree, batch % degree);
            let mut worst: f64 = 0.0;
            for (i, eff) in self.seg_eff_flops[a..b].iter().enumerate() {
                let share = base + usize::from(i < extra);
                if share == 0 {
                    continue;
                }
                let sh = Shape { batch: share, seq, dtype_bytes };
                let flops = cost.flops(ModuleKind::DecoderLayer, sh);
                worst = worst.max(flops / eff);
            }
            t += worst;
        }
        // communication at non-consecutive boundaries (§3.2)
        let bytes = batch as f64 * seq as f64 * d * dt;
        t += self.transitions as f64 * (bytes / self.link_bw0 + TRANSITION_LAUNCH_S);
        // embed + lm head (primary device)
        let sh = Shape { batch, seq, dtype_bytes };
        t += cost.flops(ModuleKind::LmHead, sh) / self.head_eff_flops;
        t
    }

    /// Decode-iteration time: roofline max(compute, HBM bytes) per layer.
    /// Allocation-free; bit-identical to the uncompiled reference walk.
    pub fn decode_step_time(
        &self,
        cost: &CostModel,
        dtype_bytes: usize,
        batch: usize,
        mean_ctx: usize,
    ) -> f64 {
        let d = cost.cfg.d_model as f64;
        let dt = dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..self.n_layers {
            let (a, b) = (self.seg_off[l] as usize, self.seg_off[l + 1] as usize);
            let degree = b - a;
            let (base, extra) = (batch / degree, batch % degree);
            let mut worst: f64 = 0.0;
            for i in 0..degree {
                let share = base + usize::from(i < extra);
                if share == 0 {
                    continue;
                }
                let flops = cost.decode_flops(ModuleKind::DecoderLayer, share, mean_ctx);
                let bytes = cost.decode_bytes_read(share, mean_ctx, dtype_bytes);
                worst = worst
                    .max(flops / self.seg_eff_flops[a + i])
                    .max(bytes / self.seg_hbm_bw[a + i]);
            }
            t += worst;
        }
        t += self.transitions as f64
            * ((batch as f64 * d * dt) / self.link_bw0 + TRANSITION_LAUNCH_S);
        t += cost.decode_flops(ModuleKind::LmHead, batch, mean_ctx) / self.head_eff_flops;
        t
    }

    /// [`PlacementProfile::decode_step_time`] with a set of layers swapped
    /// to a narrower weight precision (the memory-pressure governor's
    /// `SwapPrecision` state): a quantized layer reads its weights at
    /// `quant_dtype_bytes` while its KV cache — and every unquantized
    /// layer — stays at `dtype_bytes`. FLOPs are unchanged (conservative:
    /// int8 decode is bandwidth-bound, the win is the bytes term).
    ///
    /// With `quantized` empty this performs exactly the same f64 operations
    /// in the same order as [`PlacementProfile::decode_step_time`] (the
    /// unquantized arm *is* that code), so callers may branch on emptiness
    /// without risking bit divergence — but the ungoverned serving path
    /// still calls `decode_step_time` directly.
    pub fn decode_step_time_mixed(
        &self,
        cost: &CostModel,
        dtype_bytes: usize,
        batch: usize,
        mean_ctx: usize,
        quantized: &BTreeSet<usize>,
        quant_dtype_bytes: usize,
    ) -> f64 {
        let d = cost.cfg.d_model as f64;
        let dt = dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..self.n_layers {
            let (a, b) = (self.seg_off[l] as usize, self.seg_off[l + 1] as usize);
            let degree = b - a;
            let (base, extra) = (batch / degree, batch % degree);
            let quant = quantized.contains(&l);
            let mut worst: f64 = 0.0;
            for i in 0..degree {
                let share = base + usize::from(i < extra);
                if share == 0 {
                    continue;
                }
                let flops = cost.decode_flops(ModuleKind::DecoderLayer, share, mean_ctx);
                let bytes = if quant {
                    // weights at the swapped precision; KV stays full-width
                    cost.weight_bytes(
                        ModuleKind::DecoderLayer,
                        Shape { batch: share, seq: 1, dtype_bytes: quant_dtype_bytes },
                    ) + cost.kv_cache_bytes(share, mean_ctx, dtype_bytes)
                } else {
                    cost.decode_bytes_read(share, mean_ctx, dtype_bytes)
                };
                worst = worst
                    .max(flops / self.seg_eff_flops[a + i])
                    .max(bytes / self.seg_hbm_bw[a + i]);
            }
            t += worst;
        }
        t += self.transitions as f64
            * ((batch as f64 * d * dt) / self.link_bw0 + TRANSITION_LAUNCH_S);
        t += cost.decode_flops(ModuleKind::LmHead, batch, mean_ctx) / self.head_eff_flops;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::scheduler::split_batch;

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        (cm, Cluster::paper_testbed(), Placement::single_device(40, 0))
    }

    /// The uncompiled reference: the exact per-layer walk the simulator
    /// performed before profiles existed.
    fn reference_prefill(
        pl: &Placement,
        cl: &Cluster,
        cost: &CostModel,
        dtype_bytes: usize,
        batch: usize,
        seq: usize,
    ) -> f64 {
        let d = cost.cfg.d_model as f64;
        let dt = dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..pl.n_layers {
            let devs = pl.layer_devices(l);
            let shares = split_batch(batch, devs.len());
            let mut worst: f64 = 0.0;
            for (dev, share) in devs.iter().zip(&shares) {
                if *share == 0 {
                    continue;
                }
                let sh = Shape { batch: *share, seq, dtype_bytes };
                let flops = cost.flops(ModuleKind::DecoderLayer, sh);
                worst = worst.max(flops / cl.device(*dev).spec.effective_flops());
            }
            t += worst;
        }
        let bytes = batch as f64 * seq as f64 * d * dt;
        t += pl.transition_count() as f64
            * (bytes / cl.device(0).spec.link_bw + TRANSITION_LAUNCH_S);
        let sh = Shape { batch, seq, dtype_bytes };
        t += cost.flops(ModuleKind::LmHead, sh)
            / cl.device(pl.primary_device(0)).spec.effective_flops();
        t
    }

    #[test]
    fn compiled_prefill_bit_equals_reference() {
        let (cm, cl, mut pl) = setup();
        pl.add_replica(3, 1);
        pl.add_replica(4, 1);
        pl.add_replica(20, 2);
        let prof = PlacementProfile::compile(&pl, &cl, 0);
        for (batch, seq) in [(1, 8), (15, 256), (32, 64), (7, 512)] {
            let a = prof.prefill_step_time(&cm, 2, batch, seq);
            let b = reference_prefill(&pl, &cl, &cm, 2, batch, seq);
            assert_eq!(a.to_bits(), b.to_bits(), "batch={batch} seq={seq}");
        }
    }

    #[test]
    fn segments_follow_layer_device_order() {
        let (_, cl, mut pl) = setup();
        pl.add_replica(5, 2);
        pl.add_replica(5, 1); // creation order: primary 0, then 2, then 1
        let prof = PlacementProfile::compile(&pl, &cl, 7);
        assert_eq!(prof.layer_segment(5), &[0, 2, 1]);
        assert_eq!(prof.layer_segment(0), &[0]);
        assert_eq!(prof.epoch, 7);
        assert_eq!(prof.device_set, vec![0, 1, 2]);
        assert_eq!(prof.primary_set, vec![0]);
        assert_eq!(prof.transitions, pl.transition_count());
    }

    #[test]
    fn mean_degree_and_kv_groups_match_placement() {
        let (_, cl, mut pl) = setup();
        pl.add_replica(0, 1);
        pl.add_replica(1, 1);
        pl.migrate_module(ModuleId::layer(ModuleKind::KvCache, 2), 3);
        let prof = PlacementProfile::compile(&pl, &cl, 0);
        let expect = (0..40).map(|l| pl.degree(l) as f64).sum::<f64>() / 40.0;
        assert_eq!(prof.mean_degree.to_bits(), expect.to_bits());
        // 39 KV layers on the primary device, 1 migrated to device 3
        assert_eq!(prof.kv_groups, vec![(0, 39), (3, 1)]);
    }

    #[test]
    fn decode_monotone_in_batch_and_context() {
        let (cm, cl, pl) = setup();
        let prof = PlacementProfile::compile(&pl, &cl, 0);
        let d1 = prof.decode_step_time(&cm, 2, 1, 64);
        let d2 = prof.decode_step_time(&cm, 2, 16, 256);
        assert!(d2 > d1);
        assert!(d1 > 0.0);
    }

    #[test]
    fn mixed_decode_empty_set_bit_equals_plain() {
        let (cm, cl, mut pl) = setup();
        pl.add_replica(3, 1);
        pl.add_replica(20, 2);
        let prof = PlacementProfile::compile(&pl, &cl, 0);
        let none = BTreeSet::new();
        for (batch, ctx) in [(1, 8), (16, 256), (7, 512)] {
            assert_eq!(
                prof.decode_step_time_mixed(&cm, 2, batch, ctx, &none, 1).to_bits(),
                prof.decode_step_time(&cm, 2, batch, ctx).to_bits(),
                "batch={batch} ctx={ctx}"
            );
        }
    }

    #[test]
    fn quantized_layers_speed_up_decode_monotonically() {
        let (cm, cl, pl) = setup();
        let prof = PlacementProfile::compile(&pl, &cl, 0);
        // short context: decode is dominated by the weight-bytes term, so
        // halving weight reads must shorten the step — and more swapped
        // layers shorten it further
        let plain = prof.decode_step_time(&cm, 2, 8, 64);
        let few: BTreeSet<usize> = (36..40).collect();
        let many: BTreeSet<usize> = (30..40).collect();
        let t_few = prof.decode_step_time_mixed(&cm, 2, 8, 64, &few, 1);
        let t_many = prof.decode_step_time_mixed(&cm, 2, 8, 64, &many, 1);
        assert!(t_few < plain, "{t_few} !< {plain}");
        assert!(t_many < t_few, "{t_many} !< {t_few}");
        // KV reads stay full-width: the quantized step is still slower
        // than a hypothetical all-int8 run of the plain roofline
        let all_int8 = prof.decode_step_time(&cm, 1, 8, 64);
        assert!(t_many > all_int8);
    }

    #[test]
    fn replica_speeds_up_prefill() {
        let (cm, cl, mut pl) = setup();
        let before = PlacementProfile::compile(&pl, &cl, 0)
            .prefill_step_time(&cm, 2, 16, 128);
        for l in 0..40 {
            pl.add_replica(l, 1);
        }
        let after = PlacementProfile::compile(&pl, &cl, 1)
            .prefill_step_time(&cm, 2, 16, 128);
        assert!(after < before, "{after} !< {before}");
    }
}
