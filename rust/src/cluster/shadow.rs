//! Copy-on-write shadow ledger — planning without cloning the cluster.
//!
//! The pure planners (Algorithm 1/2) and [`crate::plan::ScalePlan::dry_run`]
//! must observe the state evolution their own ops produce (destination
//! fill, freed bytes) without touching the live ledgers. They used to deep-
//! clone the whole [`Cluster`] — every tag of every instance on every
//! device — per planning round. A [`ShadowLedger`] keeps only what
//! planning can change: a per-device `used` counter seeded from the live
//! value, plus a sparse per-device tag overlay. Reads fall through to the
//! borrowed base cluster; writes land in the overlay.
//!
//! ### Parity contract
//!
//! The shadow applies the **same arithmetic in the same order** as
//! [`super::Device`]'s mutators (`alloc` adds, `free` subtracts with the
//! same `max(0.0)` clamp, `resize` adds the delta), and its `used` starts
//! from the live device's exact f64 value — so `mem_frac` trajectories,
//! and therefore transfer times and plan costs, are bit-identical to what
//! execution against the live cluster produces. That is what keeps the
//! dry-run == executed (Table 2) parity intact after the clone removal;
//! the `profile_cache` test suite asserts it property-style.

use std::collections::BTreeMap;

use super::{AllocError, Cluster, Ledger, LedgerView};
use crate::model::cost::MIB;

/// A lightweight mutable view over a borrowed [`Cluster`]: free-bytes +
/// tag-residency deltas only. Dropping it discards every planned change.
#[derive(Debug)]
pub struct ShadowLedger<'a> {
    base: &'a Cluster,
    /// Evolved per-device used bytes (seeded from the live ledgers).
    used: Vec<f64>,
    /// Per-device tag overrides; absent tags read through to the base.
    /// `Some(bytes)` = tag present at that size, `None` = tag removed —
    /// presence matters because [`super::Device::free`] errors on an
    /// absent tag, and the shadow must refuse identically.
    overlays: Vec<BTreeMap<String, Option<f64>>>,
}

impl<'a> ShadowLedger<'a> {
    /// A fresh shadow over `base`: per-device `used` seeded from the live
    /// values, no overlays.
    pub fn new(base: &'a Cluster) -> ShadowLedger<'a> {
        ShadowLedger {
            used: (0..base.n()).map(|d| base.device(d).used_bytes()).collect(),
            overlays: vec![BTreeMap::new(); base.n()],
            base,
        }
    }

    // Convenience inherent mirrors of the [`LedgerView`] accessors, so
    // violation predicates (`|cl, _, _| cl.mem_frac(0) > 0.9`) need no
    // trait import.

    /// Number of devices (mirrors [`LedgerView::n`]).
    pub fn n(&self) -> usize {
        LedgerView::n(self)
    }

    /// Shadowed resident bytes (mirrors [`LedgerView::used_bytes`]).
    pub fn used_bytes(&self, device: usize) -> f64 {
        LedgerView::used_bytes(self, device)
    }

    /// Shadowed free bytes (mirrors [`LedgerView::free_bytes`]).
    pub fn free_bytes(&self, device: usize) -> f64 {
        LedgerView::free_bytes(self, device)
    }

    /// Shadowed memory fraction (mirrors [`LedgerView::mem_frac`]).
    pub fn mem_frac(&self, device: usize) -> f64 {
        LedgerView::mem_frac(self, device)
    }

    /// Shadowed vacancy rate (mirrors [`LedgerView::vacancy_rate`]).
    pub fn vacancy_rate(&self, device: usize) -> f64 {
        LedgerView::vacancy_rate(self, device)
    }

    /// Number of tags the planning session has touched (diagnostics).
    pub fn touched_tags(&self) -> usize {
        self.overlays.iter().map(|o| o.len()).sum()
    }
}

impl LedgerView for ShadowLedger<'_> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn used_bytes(&self, device: usize) -> f64 {
        self.used[device]
    }

    fn mem_bytes(&self, device: usize) -> f64 {
        self.base.device(device).spec.mem_bytes
    }

    fn link_bw(&self, a: usize, b: usize) -> f64 {
        self.base.link_bw(a, b)
    }

    fn alloc_bytes(&self, device: usize, tag: &str) -> f64 {
        match self.overlays[device].get(tag) {
            Some(&Some(b)) => b,
            Some(&None) => 0.0,
            None => self.base.device(device).alloc_bytes(tag),
        }
    }
}

impl ShadowLedger<'_> {
    /// Is the tag currently present (overlay first, base fallback)?
    fn tag_present(&self, device: usize, tag: &str) -> bool {
        match self.overlays[device].get(tag) {
            Some(o) => o.is_some(),
            None => self.base.device(device).has_alloc(tag),
        }
    }
}

impl Ledger for ShadowLedger<'_> {
    fn alloc(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError> {
        debug_assert!(bytes >= 0.0);
        if bytes > self.free_bytes(device) {
            return Err(AllocError::Oom {
                device,
                requested_mib: bytes / MIB,
                free_mib: self.free_bytes(device) / MIB,
            });
        }
        let cur = self.alloc_bytes(device, tag);
        self.overlays[device].insert(tag.to_string(), Some(cur + bytes));
        self.used[device] += bytes;
        Ok(())
    }

    fn free(&mut self, device: usize, tag: &str) -> Result<f64, AllocError> {
        if !self.tag_present(device, tag) {
            return Err(AllocError::UnknownTag(tag.to_string()));
        }
        let cur = self.alloc_bytes(device, tag);
        self.overlays[device].insert(tag.to_string(), None);
        self.used[device] = (self.used[device] - cur).max(0.0);
        Ok(cur)
    }

    fn resize(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError> {
        let cur = self.alloc_bytes(device, tag);
        if bytes > cur && bytes - cur > self.free_bytes(device) {
            return Err(AllocError::Oom {
                device,
                requested_mib: (bytes - cur) / MIB,
                free_mib: self.free_bytes(device) / MIB,
            });
        }
        self.used[device] += bytes - cur;
        // Device::resize drops the entry entirely at size 0.
        let entry = if bytes == 0.0 { None } else { Some(bytes) };
        self.overlays[device].insert(tag.to_string(), entry);
        Ok(())
    }

    fn restore_alloc(&mut self, device: usize, tag: &str, prev_bytes: f64) {
        let cur = self.alloc_bytes(device, tag);
        let entry = if prev_bytes == 0.0 { None } else { Some(prev_bytes) };
        self.overlays[device].insert(tag.to_string(), entry);
        self.used[device] = (self.used[device] + prev_bytes - cur).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DeviceSpec, GIB};
    use crate::util::{prop, rng::Rng};

    fn base() -> Cluster {
        let mut c = Cluster::homogeneous(3, DeviceSpec::a100_40gb());
        c.device_mut(0).alloc("w", 10.0 * GIB).unwrap();
        c.device_mut(1).alloc("kv", 2.5 * GIB).unwrap();
        c
    }

    #[test]
    fn reads_fall_through_to_base() {
        let c = base();
        let s = ShadowLedger::new(&c);
        assert_eq!(s.used_bytes(0).to_bits(), c.device(0).used_bytes().to_bits());
        assert_eq!(s.alloc_bytes(1, "kv"), 2.5 * GIB);
        assert_eq!(s.alloc_bytes(1, "nope"), 0.0);
        assert_eq!(s.mem_frac(2), 0.0);
        assert_eq!(s.touched_tags(), 0);
    }

    #[test]
    fn writes_never_touch_the_base() {
        let c = base();
        let mut s = ShadowLedger::new(&c);
        s.alloc(2, "plan", 5.0 * GIB).unwrap();
        Ledger::free(&mut s, 0, "w").unwrap();
        s.resize(1, "kv", 4.0 * GIB).unwrap();
        assert_eq!(c.device(2).used_bytes(), 0.0);
        assert_eq!(c.device(0).alloc_bytes("w"), 10.0 * GIB);
        assert_eq!(c.device(1).alloc_bytes("kv"), 2.5 * GIB);
        assert_eq!(s.used_bytes(2), 5.0 * GIB);
        assert_eq!(s.alloc_bytes(0, "w"), 0.0);
        assert_eq!(s.alloc_bytes(1, "kv"), 4.0 * GIB);
    }

    #[test]
    fn oom_refused_like_a_device() {
        let c = base();
        let mut s = ShadowLedger::new(&c);
        assert!(matches!(s.alloc(0, "x", 31.0 * GIB), Err(AllocError::Oom { .. })));
        assert_eq!(s.used_bytes(0), 10.0 * GIB, "failed alloc leaves no trace");
        assert!(matches!(
            Ledger::free(&mut s, 0, "absent"),
            Err(AllocError::UnknownTag(_))
        ));
    }

    #[test]
    fn prop_shadow_tracks_cloned_cluster_bit_for_bit() {
        // Random op sequences applied both to a ShadowLedger over the base
        // and to a deep clone of the base must produce identical
        // free/used/mem_frac/alloc_bytes trajectories — the parity that
        // lets planners drop the clone without changing any planned cost.
        prop::check(
            "shadow-parity",
            |r: &mut Rng| {
                (0..40)
                    .map(|_| {
                        (
                            r.below(4) as u8,
                            r.below(3) as usize,
                            r.below(4),
                            r.f64() * 8.0,
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |ops| {
                let c = base();
                let mut clone = c.clone();
                let mut shadow = ShadowLedger::new(&c);
                for &(op, d, tag_i, gib) in ops {
                    let tag = format!("t{tag_i}");
                    let bytes = gib * GIB;
                    match op {
                        0 => {
                            let a = clone.device_mut(d).alloc(&tag, bytes).is_ok();
                            let b = shadow.alloc(d, &tag, bytes).is_ok();
                            if a != b {
                                return Err(format!("alloc diverged: {a} vs {b}"));
                            }
                        }
                        1 => {
                            let a = clone.device_mut(d).free(&tag).ok();
                            let b = Ledger::free(&mut shadow, d, &tag).ok();
                            if a.map(f64::to_bits) != b.map(f64::to_bits) {
                                return Err("free diverged".into());
                            }
                        }
                        2 => {
                            let a = clone.device_mut(d).resize(&tag, bytes).is_ok();
                            let b = shadow.resize(d, &tag, bytes).is_ok();
                            if a != b {
                                return Err("resize diverged".into());
                            }
                        }
                        _ => {
                            clone.device_mut(d).restore_alloc(&tag, bytes);
                            shadow.restore_alloc(d, &tag, bytes);
                        }
                    }
                    for dev in 0..3 {
                        if clone.device(dev).used_bytes().to_bits()
                            != shadow.used_bytes(dev).to_bits()
                        {
                            return Err(format!("used diverged on device {dev}"));
                        }
                        if clone.device(dev).mem_frac().to_bits()
                            != shadow.mem_frac(dev).to_bits()
                        {
                            return Err(format!("mem_frac diverged on device {dev}"));
                        }
                        if clone.device(dev).alloc_bytes(&tag).to_bits()
                            != shadow.alloc_bytes(dev, &tag).to_bits()
                        {
                            return Err(format!("tag bytes diverged on device {dev}"));
                        }
                    }
                }
                // the borrowed base never moved
                for dev in 0..3 {
                    if c.device(dev).used_bytes() != base().device(dev).used_bytes() {
                        return Err("base mutated".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn eligible_nodes_and_ordering_match_cluster() {
        let mut c = Cluster::homogeneous(4, DeviceSpec::a100_40gb());
        c.device_mut(0).alloc("x", 30.0 * GIB).unwrap();
        c.device_mut(1).alloc("x", 10.0 * GIB).unwrap();
        let s = ShadowLedger::new(&c);
        assert_eq!(LedgerView::eligible_nodes(&s, 0.5), c.eligible_nodes(0.5));
        assert_eq!(LedgerView::by_free_memory(&s), c.by_free_memory());
    }
}
