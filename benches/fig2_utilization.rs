//! Fig. 2 — GPU resource utilization of HFT vs vLLM across request rates.
//!
//! Paper setup: single LLaMA-13B instance on one A100, RPS sweep, 5 repeats.
//! Claim to reproduce: at low rates (RPS ≤ 10) both frameworks leave
//! ~20–40% of GPU resources idle (static allocation), utilization climbs
//! with RPS.
//!
//! Event-kernel port under the golden-replay discipline:
//! (a) every cell runs the deterministic event kernel with telemetry on,
//!     sourcing utilization from the streaming `timeline` block — the
//!     per-window `busy_frac` series the tracing layer samples as the
//!     kernel advances — rather than a single end-of-run aggregate,
//! (b) a per-window utilization timeline is printed for one low-rate cell
//!     (the paper's "idle at RPS ≤ 10" claim is visible window by window),
//! (c) one stateful cell is re-run and its full metrics JSON (timeline
//!     included) byte-compared — golden replay.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::telemetry::TelemetryConfig;
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: [f64; 6] = [1.0, 5.0, 10.0, 20.0, 35.0, 50.0];
const REPEATS: u64 = 5;
const DURATION_S: f64 = 20.0;

fn run_cell(policy: SimPolicy, rps: f64, seed: u64) -> SimReport {
    let mut cfg = SimConfig::paper_13b();
    cfg.telemetry = Some(TelemetryConfig::ring(1024));
    let cluster = Cluster::homogeneous(1, DeviceSpec::a100_40gb());
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
    let trace = Trace::generate(
        Arrival::Poisson { rps },
        LengthDist::alpaca(),
        DURATION_S,
        seed,
    );
    sim.run(&trace, DURATION_S)
}

/// Mean device-busy fraction over the telemetry timeline windows, and the
/// end-of-run memory utilization (memory is a level, not a rate — the
/// device ledger's aggregate is the right summary for it).
fn utilization(report: &SimReport) -> (f64, f64) {
    let tl = report.timeline.as_ref().expect("telemetry timeline on");
    let n = tl.windows.len().max(1) as f64;
    let compute = tl.windows.iter().map(|w| w.busy_frac).sum::<f64>() / n;
    let (_, _, mem) = report.device_util[0];
    (compute, mem)
}

fn main() {
    println!("Fig. 2 — utilization vs RPS (13B on 1×A100, mean of {REPEATS} seeds)\n");
    let mut t = Table::new(&["rps", "hft compute%", "hft mem%", "vllm compute%", "vllm mem%"]);
    let mut rep = Report::new("fig2_utilization");
    let mut series: Vec<Vec<f64>> = vec![vec![]; 4];
    let mut low_rate_windows: Option<Vec<f64>> = None;
    for &rps in &RPS {
        let mut acc = [0.0f64; 4];
        for seed in 0..REPEATS {
            let hr = run_cell(baselines::hft(16), rps, 100 + seed);
            let vr = run_cell(baselines::vllm_like(16), rps, 100 + seed);
            let (hc, hm) = utilization(&hr);
            let (vc, vm) = utilization(&vr);
            acc[0] += hc;
            acc[1] += hm;
            acc[2] += vc;
            acc[3] += vm;
            if rps == 10.0 && seed == 0 {
                let tl = vr.timeline.as_ref().unwrap();
                low_rate_windows = Some(tl.windows.iter().map(|w| w.busy_frac * 100.0).collect());
            }
        }
        for a in &mut acc {
            *a = *a / REPEATS as f64 * 100.0;
        }
        for (s, a) in series.iter_mut().zip(&acc) {
            s.push(*a);
        }
        t.row(&[
            format!("{rps:.0}"),
            format!("{:.1}", acc[0]),
            format!("{:.1}", acc[1]),
            format!("{:.1}", acc[2]),
            format!("{:.1}", acc[3]),
        ]);
    }
    t.print();

    // per-window view of the low-rate cell: idle capacity window by window
    let windows = low_rate_windows.expect("RPS=10 cell ran");
    println!("\nvLLM-like @ RPS=10, seed 100 — per-window compute utilization %:");
    println!(
        "  {}",
        windows
            .iter()
            .map(|w| format!("{w:.0}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // the paper's headline claim: ≥20% idle at RPS ≤ 10
    let low_idx = RPS.iter().position(|&r| r == 10.0).unwrap();
    let max_util_at_low = series[0][low_idx].max(series[2][low_idx]);
    println!(
        "\ncompute utilization at RPS=10: {:.1}% → {:.1}% idle (paper: 20–40% idle)",
        max_util_at_low,
        100.0 - max_util_at_low
    );

    // golden replay: identical seed ⇒ byte-identical metrics JSON,
    // timeline block included
    let a = run_cell(baselines::vllm_like(16), 10.0, 100).to_json().to_string();
    let b = run_cell(baselines::vllm_like(16), 10.0, 100).to_json().to_string();
    assert_eq!(a, b, "fig2 cell failed golden replay");
    println!("golden replay (vllm @ RPS=10): byte-identical ✓");

    rep.set("rps", json::arr(RPS.iter().map(|&x| json::num(x))));
    for (name, s) in ["hft_compute", "hft_mem", "vllm_compute", "vllm_mem"]
        .iter()
        .zip(&series)
    {
        rep.series(name, s);
    }
    rep.series("vllm_rps10_window_util", &windows);
    let path = rep.write().expect("report");
    println!("report: {}", path.display());
}
