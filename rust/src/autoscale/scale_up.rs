//! Algorithm 1 — Scale-Up via layer replication (§4.1), as a **pure
//! planner**.
//!
//! Greedy search over (eligible device, continuity-sorted candidate layer)
//! pairs: a replica is planned iff the Eq. 4 speedup strictly improves and
//! the destination has room. The search runs against a copy-on-write
//! [`ShadowLedger`] (free-bytes + residency deltas — the cluster is never
//! cloned) plus a shadow placement — the caller's state is never touched;
//! the returned [`ScaleUpPlan`] is applied through
//! [`crate::ops::PlanExecutor`] (atomically) or executed in flight by the
//! simulation kernel. Guarantees from the paper, kept as tested
//! invariants:
//!
//! * (a) monotonic speedup improvement (greedy local optimality),
//! * (b) communication efficiency via continuity-first candidate order,
//! * (c) the plan's dry-run cost equals its executed cost (the shadow
//!   replay and the executor walk the same state evolution).

use crate::cluster::{Cluster, LedgerView, ShadowLedger};
use crate::ops::{ModuleOps, PlanExecution};
use crate::placement::Placement;
use crate::plan::{ModuleOp, PlanCost, ScalePlan};

use super::speedup::s_homo_from_norm;

/// Tuning knobs for Algorithm 1.
#[derive(Debug, Clone, Copy)]
pub struct ScaleUpConfig {
    /// γ — cluster configuration coefficient of Eq. 4.
    pub gamma: f64,
    /// Vacancy-rate filter of `GetEligibleNodes` (T_up in §5).
    pub min_vacancy: f64,
    /// Cap on replicas planned per invocation (keeps each control-loop
    /// tick bounded; the loop converges over successive ticks).
    pub max_ops_per_round: usize,
}

impl Default for ScaleUpConfig {
    fn default() -> Self {
        ScaleUpConfig { gamma: 0.05, min_vacancy: 0.3, max_ops_per_round: usize::MAX }
    }
}

/// What one scale-up planning round proposes.
#[derive(Debug, Clone, Default)]
pub struct ScaleUpPlan {
    /// The executable plan (replications only).
    pub plan: ScalePlan,
    /// (layer, destination device) for each planned replication.
    pub planned: Vec<(usize, usize)>,
    /// Eq. 4 speedup of the placement before the round.
    pub speedup_before: f64,
    /// Eq. 4 speedup the placement reaches when the plan lands.
    pub speedup_after: f64,
    /// Dry-run cost against the planning-time state — equals the executed
    /// cost when the plan is applied to that same state.
    pub cost: PlanCost,
}

/// `SortCandidatesByContinuity` (§4.1): layers not yet resident on `dst`,
/// ordered by descending continuity (longest consecutive run including the
/// candidate), ties by ascending layer id; truncated to `max_replicas`.
pub fn sort_candidates_by_continuity(
    placement: &Placement,
    dst: usize,
    max_replicas: usize,
) -> Vec<usize> {
    let mut cands: Vec<usize> = (0..placement.n_layers)
        .filter(|&l| !placement.holds(l, dst))
        .collect();
    cands.sort_by_key(|&l| {
        (std::cmp::Reverse(placement.continuity_with(dst, l)), l)
    });
    cands.truncate(max_replicas);
    cands
}

/// Algorithm 1. Pure: reads `cluster` + `placement`, returns the plan; no
/// mutation happens here.
pub fn scale_up(
    ops: &ModuleOps<'_>,
    cluster: &Cluster,
    placement: &Placement,
    cfg: &ScaleUpConfig,
) -> ScaleUpPlan {
    let n = placement.n_layers;
    let replica_bytes = ops.module_bytes(crate::model::ModuleKind::DecoderLayer);

    // Shadow state: the greedy must observe its own accepted replications
    // (destination fill, placement degrees) without touching the caller's.
    // The ledger is a copy-on-write view — no cluster clone per round.
    let mut shadow_cl = ShadowLedger::new(cluster);
    let mut shadow_pl = placement.clone();
    let mut exec = PlanExecution::eager();

    // line 1: sp_best ← 1 / (γ + (1−γ)/n · ‖1 ⊘ P‖₁)
    let mut inv_norm = shadow_pl.inv_p_norm();
    let mut sp_best = s_homo_from_norm(cfg.gamma, n, inv_norm);
    let mut out = ScaleUpPlan {
        speedup_before: sp_best,
        speedup_after: sp_best,
        ..Default::default()
    };

    // line 2: for g_dst ∈ GetEligibleNodes(G)
    for dst in LedgerView::eligible_nodes(&shadow_cl, cfg.min_vacancy) {
        // line 3: max_replicas ← available / r
        let max_replicas = (shadow_cl.free_bytes(dst) / replica_bytes) as usize;
        if max_replicas == 0 {
            continue;
        }
        // line 4: continuity-sorted candidates
        let candidates =
            sort_candidates_by_continuity(&shadow_pl, dst, max_replicas);
        // lines 5–12: greedy accept while speedup strictly improves
        for layer in candidates {
            if out.planned.len() >= cfg.max_ops_per_round {
                out.cost = exec.into_cost();
                return out;
            }
            let p_old = shadow_pl.degree(layer) as f64;
            let new_norm = inv_norm - 1.0 / p_old + 1.0 / (p_old + 1.0);
            let sp = s_homo_from_norm(cfg.gamma, n, new_norm);
            if sp > sp_best {
                let op = ModuleOp::Replicate { layer, dst };
                match exec.apply_next(ops, &mut shadow_cl, &mut shadow_pl, &op) {
                    Ok(_) => {
                        inv_norm = new_norm;
                        sp_best = sp;
                        out.speedup_after = sp;
                        out.planned.push((layer, dst));
                        out.plan.push(op);
                    }
                    Err(_) => break, // destination full — next device
                }
            }
        }
    }
    out.cost = exec.into_cost();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, GIB};
    use crate::model::cost::CostModel;
    use crate::model::ModelConfig;
    use crate::ops::PlanExecutor;
    use crate::util::{prop, rng::Rng};

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let mut cluster = Cluster::paper_testbed();
        // instance weights resident on device 0 (~24 GiB)
        cluster.device_mut(0).alloc("inst0/model", 24.2 * GIB).unwrap();
        (cm, cluster, Placement::single_device(40, 0))
    }

    #[test]
    fn planner_leaves_inputs_untouched() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let used: Vec<f64> = (0..cl.n()).map(|d| cl.device(d).used_bytes()).collect();
        let out = scale_up(&ops, &cl, &pl, &ScaleUpConfig::default());
        assert!(!out.plan.is_empty());
        for d in 0..cl.n() {
            assert_eq!(cl.device(d).used_bytes(), used[d], "planner mutated device {d}");
        }
        assert_eq!(pl.inv_p_norm(), 40.0, "planner mutated placement");
    }

    #[test]
    fn speedup_monotonically_improves() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let out = scale_up(&ops, &cl, &pl, &ScaleUpConfig::default());
        assert!(!out.planned.is_empty());
        assert!(out.speedup_after > out.speedup_before);
        PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
        pl.validate(cl.n()).unwrap();
    }

    #[test]
    fn fills_eligible_devices_up_to_capacity() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let out = scale_up(&ops, &cl, &pl, &ScaleUpConfig::default());
        // 3 empty A100s × (40960/608 ≈ 67 layers capacity) but only 40
        // layers exist per device — expect 120 replicas (40 on each).
        assert_eq!(out.planned.len(), 120, "{}", out.planned.len());
        PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
        for l in 0..40 {
            assert_eq!(pl.degree(l), 4);
        }
    }

    #[test]
    fn dry_run_cost_matches_planner_cost() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let out = scale_up(&ops, &cl, &pl, &ScaleUpConfig::default());
        let dry = out.plan.dry_run(&ops, &cl, &pl).unwrap();
        assert_eq!(dry, out.cost, "planner shadow cost == dry-run cost");
    }

    #[test]
    fn executed_cost_matches_dry_run_exactly() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let cfg = ScaleUpConfig { max_ops_per_round: 12, ..Default::default() };
        let out = scale_up(&ops, &cl, &pl, &cfg);
        let dry = out.plan.dry_run(&ops, &cl, &pl).unwrap();
        let executed =
            PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
        assert_eq!(dry, executed, "Table 2 parity: dry-run == executed");
    }

    #[test]
    fn respects_max_ops_per_round() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let cfg = ScaleUpConfig { max_ops_per_round: 5, ..Default::default() };
        let out = scale_up(&ops, &cl, &pl, &cfg);
        assert_eq!(out.planned.len(), 5);
        assert_eq!(out.cost.per_op.len(), 5);
    }

    #[test]
    fn no_eligible_nodes_means_empty_plan() {
        let (cm, mut cl, pl) = setup();
        for d in 1..4 {
            cl.device_mut(d).alloc("hog", 35.0 * GIB).unwrap();
        }
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let cfg = ScaleUpConfig { min_vacancy: 0.3, ..Default::default() };
        let out = scale_up(&ops, &cl, &pl, &cfg);
        assert!(out.plan.is_empty());
        assert_eq!(out.speedup_before, out.speedup_after);
    }

    #[test]
    fn continuity_order_prefers_runs() {
        let mut pl = Placement::single_device(10, 0);
        pl.add_replica(4, 1);
        pl.add_replica(5, 1);
        let c = sort_candidates_by_continuity(&pl, 1, 3);
        // 3 and 6 extend the [4,5] run (continuity 3); 3 wins ties by id.
        assert_eq!(&c[..2], &[3, 6]);
    }

    #[test]
    fn continuity_reduces_transitions_vs_random() {
        // Ablation seed (see benches/ablation_continuity.rs): replicating
        // with the continuity order yields fewer dataflow transitions than
        // an id-shuffled order with the same budget.
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let cfg = ScaleUpConfig { max_ops_per_round: 10, ..Default::default() };
        let out = scale_up(&ops, &cl, &pl, &cfg);
        assert_eq!(out.planned.len(), 10);
        PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
        let continuity_transitions = pl.transition_count();

        // random order baseline
        let (cm2, mut cl2, mut pl2) = setup();
        let ops2 = ModuleOps::new(&cm2, 2, "inst0");
        let mut rng = Rng::new(99);
        let mut layers: Vec<usize> = (0..40).collect();
        rng.shuffle(&mut layers);
        let random: Vec<usize> = layers.into_iter().take(10).collect();
        PlanExecutor::new(&ops2)
            .execute(&mut cl2, &mut pl2, &ScalePlan::replicate_batch(&random, 1))
            .unwrap();
        let random_transitions = pl2.transition_count();
        assert!(
            continuity_transitions <= random_transitions,
            "{continuity_transitions} > {random_transitions}"
        );
    }

    #[test]
    fn prop_scale_up_plans_stay_valid_and_monotone() {
        prop::check(
            "scale-up-valid",
            |r: &mut Rng| {
                // random pre-fill of devices + random layer count
                let n_layers = 4 + r.below(44) as usize;
                let fills: Vec<f64> = (0..4).map(|_| r.f64() * 38.0).collect();
                (n_layers, fills)
            },
            |(n_layers, fills)| {
                let cm = CostModel::new(ModelConfig::llama2_13b());
                let mut cl = Cluster::paper_testbed();
                for (i, gib) in fills.iter().enumerate() {
                    cl.device_mut(i).alloc("fill", gib * GIB).unwrap();
                }
                let mut pl = Placement::single_device(*n_layers, 0);
                let ops = ModuleOps::new(&cm, 2, "inst0");
                let before = s_homo_from_norm(0.05, *n_layers, pl.inv_p_norm());
                let out = scale_up(&ops, &cl, &pl, &ScaleUpConfig::default());
                // the plan validates and executes against the same state
                out.plan
                    .validate(&ops, &cl, &pl)
                    .map_err(|e| format!("planned plan invalid: {e}"))?;
                let executed = PlanExecutor::new(&ops)
                    .execute(&mut cl, &mut pl, &out.plan)
                    .map_err(|e| format!("planned plan failed: {e}"))?;
                if executed != out.cost {
                    return Err("executed cost != planned cost".into());
                }
                pl.validate(cl.n())?;
                if out.speedup_after + 1e-12 < before {
                    return Err("speedup regressed".into());
                }
                // ledger consistency: every replica has resident bytes
                for l in 0..*n_layers {
                    for d in pl.layer_devices(l).into_iter().skip(1) {
                        let tag = format!("inst0/layers.{l}.decoder_layer@{d}");
                        if cl.device(d).alloc_bytes(&tag) <= 0.0 {
                            return Err(format!("replica {l}@{d} has no bytes"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
