//! Compiled-profile cache contracts, tested through the public API.
//!
//! * **Bit-identical step costs** — a [`PlacementProfile`] compiled after
//!   any sequence of plan mutations (replicate / migrate-layer /
//!   migrate-module / evict, applied *and* rolled back) must price
//!   prefill and decode steps bit-for-bit (`f64::to_bits`) equal to an
//!   uncompiled reference walk over the live `Placement` + `Cluster` —
//!   the determinism argument that keeps golden-replay JSON byte-stable
//!   across the compiled-profile refactor.
//! * **Shadow-planning parity** — dry-run costing now runs over a
//!   copy-on-write [`ShadowLedger`] instead of a cluster clone; the
//!   priced cost must still equal the executed cost exactly, and pricing
//!   must leave the live ledgers untouched.

use cocoserve::cluster::Cluster;
use cocoserve::model::cost::{CostModel, Shape};
use cocoserve::model::{ModelConfig, ModuleId, ModuleKind};
use cocoserve::ops::{ModuleOps, PlanExecution, PlanExecutor};
use cocoserve::placement::{Placement, PlacementProfile};
use cocoserve::plan::{ModuleOp, ScalePlan};
use cocoserve::scheduler::split_batch;
use cocoserve::util::{prop, rng::Rng};

const N_LAYERS: usize = 16;

fn setup() -> (CostModel, Cluster, Placement) {
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let mut cl = Cluster::paper_testbed();
    let mut pl = Placement::single_device(N_LAYERS, 0);
    let ops = ModuleOps::new(&cm, 2, "inst0");
    ops.deploy_instance(&mut cl, &pl).unwrap();
    // make placement non-trivial so mutations have varied sources
    PlanExecutor::new(&ops)
        .execute(&mut cl, &mut pl, &ScalePlan::migrate_batch(&[N_LAYERS - 1], 1))
        .unwrap();
    (cm, cl, pl)
}

/// The uncompiled reference prefill walk — the exact arithmetic the
/// simulator performed before profiles existed.
fn reference_prefill(
    pl: &Placement,
    cl: &Cluster,
    cost: &CostModel,
    dtype_bytes: usize,
    batch: usize,
    seq: usize,
) -> f64 {
    let d = cost.cfg.d_model as f64;
    let dt = dtype_bytes as f64;
    let mut t = 0.0;
    for l in 0..pl.n_layers {
        let devs = pl.layer_devices(l);
        let shares = split_batch(batch, devs.len());
        let mut worst: f64 = 0.0;
        for (dev, share) in devs.iter().zip(&shares) {
            if *share == 0 {
                continue;
            }
            let sh = Shape { batch: *share, seq, dtype_bytes };
            let flops = cost.flops(ModuleKind::DecoderLayer, sh);
            worst = worst.max(flops / cl.device(*dev).spec.effective_flops());
        }
        t += worst;
    }
    let bytes = batch as f64 * seq as f64 * d * dt;
    t += pl.transition_count() as f64 * (bytes / cl.device(0).spec.link_bw + 20e-6);
    let sh = Shape { batch, seq, dtype_bytes };
    t += cost.flops(ModuleKind::LmHead, sh)
        / cl.device(pl.primary_device(0)).spec.effective_flops();
    t
}

/// The uncompiled reference decode walk.
fn reference_decode(
    pl: &Placement,
    cl: &Cluster,
    cost: &CostModel,
    dtype_bytes: usize,
    batch: usize,
    mean_ctx: usize,
) -> f64 {
    let d = cost.cfg.d_model as f64;
    let dt = dtype_bytes as f64;
    let mut t = 0.0;
    for l in 0..pl.n_layers {
        let devs = pl.layer_devices(l);
        let shares = split_batch(batch, devs.len());
        let mut worst: f64 = 0.0;
        for (dev, share) in devs.iter().zip(&shares) {
            if *share == 0 {
                continue;
            }
            let spec = &cl.device(*dev).spec;
            let flops = cost.decode_flops(ModuleKind::DecoderLayer, *share, mean_ctx);
            let bytes = cost.decode_bytes_read(*share, mean_ctx, dtype_bytes);
            worst = worst.max(flops / spec.effective_flops()).max(bytes / spec.hbm_bw);
        }
        t += worst;
    }
    t += pl.transition_count() as f64
        * ((batch as f64 * d * dt) / cl.device(0).spec.link_bw + 20e-6);
    t += cost.decode_flops(ModuleKind::LmHead, batch, mean_ctx)
        / cl.device(pl.primary_device(0)).spec.effective_flops();
    t
}

/// One randomized mutation drawn against the *current* placement so most
/// generated ops are applicable.
fn random_op(r: &mut Rng, pl: &Placement) -> ModuleOp {
    let layer = r.below(N_LAYERS as u64) as usize;
    let dst = r.below(4) as usize;
    match r.below(4) {
        0 => ModuleOp::Replicate { layer, dst },
        1 => ModuleOp::MigrateLayer { layer, dst },
        2 => ModuleOp::MigrateModule {
            module: ModuleId::layer(ModuleKind::KvCache, layer),
            dst,
            payload_bytes: r.f64() * 1e9,
        },
        _ => {
            // evict an existing replica when one exists, else a no-op evict
            let replicas = pl.replicas_on(dst);
            let layer = replicas.first().copied().unwrap_or(layer);
            ModuleOp::Evict { layer, device: dst }
        }
    }
}

#[test]
fn prop_profile_bit_equals_reference_after_random_mutations() {
    prop::check(
        "profile-cache-bit-identity",
        |r: &mut Rng| {
            let n_ops = 1 + r.below(12) as usize;
            let rollback_mask: Vec<bool> = (0..n_ops).map(|_| r.f64() < 0.3).collect();
            let seed = r.next_u64();
            (n_ops, rollback_mask, seed)
        },
        |&(n_ops, ref rollback_mask, seed)| {
            let (cm, mut cl, mut pl) = setup();
            let ops = ModuleOps::new(&cm, 2, "inst0");
            let mut r = Rng::new(seed);
            let mut epoch = 0u64;
            for k in 0..n_ops {
                let op = random_op(&mut r, &pl);
                // apply through the stepwise executor; a rollback_mask hit
                // unwinds the op again — both paths move (or restore) the
                // placement and must leave the compiled profile exact
                let mut exec = PlanExecution::new();
                match exec.apply_next(&ops, &mut cl, &mut pl, &op) {
                    Ok(_) if rollback_mask[k] => exec.rollback(&mut cl, &mut pl),
                    Ok(_) => {
                        exec.commit(&mut cl);
                    }
                    Err(_) => continue, // infeasible against current state
                }
                epoch += 1;
                let prof = PlacementProfile::compile(&pl, &cl, epoch);
                for &(batch, shape) in
                    &[(1usize, 8usize), (15, 128), (32, 256), (7, 64)]
                {
                    let a = prof.prefill_step_time(&cm, 2, batch, shape);
                    let b = reference_prefill(&pl, &cl, &cm, 2, batch, shape);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "prefill diverged after op {k} ({op:?}): {a} vs {b}"
                        ));
                    }
                    let a = prof.decode_step_time(&cm, 2, batch, shape);
                    let b = reference_decode(&pl, &cl, &cm, 2, batch, shape);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "decode diverged after op {k} ({op:?}): {a} vs {b}"
                        ));
                    }
                }
                if prof.transitions != pl.transition_count() {
                    return Err("transition count diverged".into());
                }
                pl.validate(cl.n())?;
            }
            Ok(())
        },
    );
}

#[test]
fn stale_profile_differs_after_replication() {
    // Non-vacuity: the bit-identity property above would pass trivially if
    // profiles never changed. A replication must change the decode cost.
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let stale = PlacementProfile::compile(&pl, &cl, 0);
    PlanExecutor::new(&ops)
        .execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[0, 1, 2], 2))
        .unwrap();
    let fresh = PlacementProfile::compile(&pl, &cl, 1);
    assert_ne!(
        stale.decode_step_time(&cm, 2, 15, 128).to_bits(),
        fresh.decode_step_time(&cm, 2, 15, 128).to_bits(),
        "replication must change the compiled decode cost"
    );
    assert_eq!(
        fresh.decode_step_time(&cm, 2, 15, 128).to_bits(),
        reference_decode(&pl, &cl, &cm, 2, 15, 128).to_bits()
    );
}

#[test]
fn prop_shadow_dry_run_equals_live_execution() {
    // dry_run prices over a ShadowLedger; executing the same plan against
    // the live cluster must produce the identical PlanCost (per-op and
    // total, PartialEq over f64), and pricing must not move the ledgers.
    prop::check(
        "shadow-dry-run-parity",
        |r: &mut Rng| {
            let n: usize = 1 + r.below(6) as usize;
            let dst = 1 + r.below(3) as usize;
            let layers: Vec<usize> =
                (0..n).map(|_| r.below(N_LAYERS as u64) as usize).collect();
            let migrate = r.f64() < 0.4;
            (layers, dst, migrate)
        },
        |&(ref layers, dst, migrate)| {
            let (cm, mut cl, mut pl) = setup();
            let ops = ModuleOps::new(&cm, 2, "inst0");
            let mut uniq = layers.clone();
            uniq.sort_unstable();
            uniq.dedup();
            let plan = if migrate {
                ScalePlan::migrate_batch(&uniq, dst)
            } else {
                ScalePlan::replicate_batch(&uniq, dst)
            };
            if plan.validate(&ops, &cl, &pl).is_err() {
                return Ok(()); // infeasible shapes are out of scope here
            }
            let used_before: Vec<u64> =
                (0..cl.n()).map(|d| cl.device(d).used_bytes().to_bits()).collect();
            let dry = plan.dry_run(&ops, &cl, &pl).map_err(|e| e.to_string())?;
            let used_after: Vec<u64> =
                (0..cl.n()).map(|d| cl.device(d).used_bytes().to_bits()).collect();
            if used_before != used_after {
                return Err("dry_run moved the live ledgers".into());
            }
            let executed = PlanExecutor::new(&ops)
                .execute(&mut cl, &mut pl, &plan)
                .map_err(|e| e.to_string())?;
            if dry != executed {
                return Err(format!("dry {dry:?} != executed {executed:?}"));
            }
            Ok(())
        },
    );
}
