//! Table 1 — module memory & computation analysis (§3.3).
//!
//! Regenerates the paper's table exactly from the cost model (LLaMA-13B,
//! batch 1, seq 256, bf16):
//!
//! | module                  | memory | computation  |
//! | self_attn.q/k/v/o_proj  |  50 MB | 13.42 GFLOPs |
//! | self_attn               | 200 MB | 55.02 GFLOPs |
//! | ffn.gate/up/down_proj   | 135 MB | 36.24 GFLOPs |
//! | decoder layer           | 605 MB | 127.5 GFLOPs |

use cocoserve::model::cost::{CostModel, Shape};
use cocoserve::model::{ModelConfig, ModuleKind};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;

fn main() {
    println!("Table 1 — module memory & computation (13B, bs=1, seq=256, bf16)\n");
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let sh = Shape::paper_standard();

    let rows: [(&str, ModuleKind, f64, f64); 4] = [
        ("self_attn.q/k/v/o_proj", ModuleKind::QProj, 50.0, 13.42),
        ("self_attn", ModuleKind::Attn, 200.0, 55.02),
        ("ffn.gate/up/down_proj", ModuleKind::GateProj, 135.0, 36.24),
        ("decoder layer", ModuleKind::DecoderLayer, 605.0, 127.5),
    ];

    let mut t = Table::new(&["module", "memory (MB)", "paper", "GFLOPs", "paper",
                             "density (GF/MB)"]);
    let mut rep = Report::new("table1_module_analysis");
    let mut max_err: f64 = 0.0;
    for (name, kind, p_mem, p_gf) in rows {
        let c = cm.cost(kind, sh);
        max_err = max_err
            .max(((c.mem_mib() - p_mem) / p_mem).abs())
            .max(((c.gflops() - p_gf) / p_gf).abs());
        t.row(&[
            name.to_string(),
            format!("{:.1}", c.mem_mib()),
            format!("{p_mem:.0}"),
            format!("{:.2}", c.gflops()),
            format!("{p_gf:.2}"),
            format!("{:.3}", c.density()),
        ]);
        rep.set(
            name,
            json::arr([json::num(c.mem_mib()), json::num(c.gflops())]),
        );
    }
    t.print();

    // KV cache — the memory-intensive module (§3.3 text).
    let kv_1 = cm.kv_cache_bytes(1, 256, 2) / (1024.0 * 1024.0);
    let kv_model = kv_1 * 40.0;
    println!(
        "\nkv cache: {kv_1:.1} MB/layer/seq (bs=1, seq=256) → {:.2} GB whole \
         model at bs=15 (the \"hundreds of MB to a few GB\" dynamic range)",
        kv_model * 15.0 / 1024.0
    );
    println!("max relative error vs paper: {:.2}%", max_err * 100.0);
    assert!(max_err < 0.01, "Table 1 must regenerate within 1%");
    rep.set("max_rel_err", json::num(max_err));
    println!("report: {}", rep.write().unwrap().display());
}
