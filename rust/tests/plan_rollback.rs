//! Plan atomicity contracts, tested through the public API.
//!
//! * **Rollback is byte-identical** — a plan that fails mid-execution
//!   (OOM-injected) or is rejected up front (validation) must leave the
//!   cluster's allocation ledgers and the `Placement` exactly as they
//!   were: serialized before/after snapshots compare equal, with f64
//!   sizes compared by bit pattern.
//! * **Dry-run equals executed** — for any plan that lands, the
//!   `PlanCost` from `ScalePlan::dry_run` equals the executed cost
//!   bit for bit (the Table 2 parity contract).

use cocoserve::cluster::{Cluster, GIB};
use cocoserve::model::cost::CostModel;
use cocoserve::model::{ModelConfig, ModuleId, ModuleKind};
use cocoserve::ops::{ModuleOps, PlanExecution, PlanExecutor};
use cocoserve::placement::Placement;
use cocoserve::plan::{ModuleOp, PlanError, ScalePlan};
use cocoserve::util::{prop, rng::Rng};

/// Deterministic byte-exact snapshot of every ledger (f64 sizes as raw
/// bits) plus the placement's full debug state.
fn snapshot(cluster: &Cluster, placement: &Placement) -> String {
    let mut s = String::new();
    for d in 0..cluster.n() {
        s.push_str(&format!("device {d}:\n"));
        for (tag, bytes) in cluster.device(d).allocations() {
            s.push_str(&format!("  {tag} = {:016x}\n", bytes.to_bits()));
        }
    }
    s.push_str(&format!("placement: {placement:?}\n"));
    s
}

fn setup() -> (CostModel, Cluster, Placement) {
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let mut cl = Cluster::paper_testbed();
    let pl = Placement::single_device(40, 0);
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let deployed = ops.deploy_instance(&mut cl, &pl).unwrap();
    assert!(deployed > 0.0);
    (cm, cl, pl)
}

#[test]
fn validation_rejected_plan_touches_nothing() {
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let before = snapshot(&cl, &pl);
    // layer 0 already lives on device 0 — replicating it there is invalid
    let plan = ScalePlan {
        ops: vec![
            ModuleOp::Replicate { layer: 1, dst: 1 },
            ModuleOp::Replicate { layer: 0, dst: 0 },
        ],
    };
    let err = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &plan).unwrap_err();
    assert!(matches!(err, PlanError::Rejected { op_idx: 1, .. }), "{err}");
    assert_eq!(before, snapshot(&cl, &pl), "rejected plan must touch nothing");
}

#[test]
fn oom_injected_failure_rolls_back_byte_identically() {
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    // leave room on device 1 for exactly two layer replicas
    let layer_bytes = ops.module_bytes(ModuleKind::DecoderLayer);
    let hog = cl.device(1).free_bytes() - 2.5 * layer_bytes;
    cl.device_mut(1).alloc("hog", hog).unwrap();

    let before = snapshot(&cl, &pl);
    // five replications: ops 0-1 fit, op 2 OOMs mid-plan. Validation's
    // predictive capacity check rejects this plan outright; drive the
    // stepwise executor (the simulator's in-flight path) to exercise the
    // genuine mid-plan OOM + rollback.
    let plan = ScalePlan::replicate_batch(&[0, 1, 2, 3, 4], 1);
    let mut exec = PlanExecution::new();
    let mut failed_at = None;
    for (i, op) in plan.ops.iter().enumerate() {
        if exec.apply_next(&ops, &mut cl, &mut pl, op).is_err() {
            failed_at = Some(i);
            break;
        }
    }
    assert_eq!(failed_at, Some(2), "third replica must hit the injected OOM");
    assert_eq!(exec.applied(), 2);
    assert_ne!(before, snapshot(&cl, &pl), "two ops really landed");
    exec.rollback(&mut cl, &mut pl);
    assert_eq!(before, snapshot(&cl, &pl), "rollback must be byte-identical");
}

#[test]
fn validation_is_conservative_about_deferred_frees() {
    // Source frees happen at plan *commit* (copy-then-free), after every
    // allocation — so a plan that would only fit if an eviction's bytes
    // were reusable mid-plan is rejected up front, touching nothing.
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    PlanExecutor::new(&ops)
        .execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[7], 1))
        .unwrap();
    let layer_bytes = ops.module_bytes(ModuleKind::DecoderLayer);
    let hog = cl.device(1).free_bytes() - 0.5 * layer_bytes;
    cl.device_mut(1).alloc("hog", hog).unwrap();

    let before = snapshot(&cl, &pl);
    let plan = ScalePlan {
        ops: vec![
            ModuleOp::Evict { layer: 7, device: 1 },
            ModuleOp::Replicate { layer: 8, dst: 1 },
        ],
    };
    let err = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &plan).unwrap_err();
    assert!(matches!(err, PlanError::Rejected { op_idx: 1, .. }), "{err}");
    assert_eq!(before, snapshot(&cl, &pl), "rejected plan must touch nothing");
}

#[test]
fn mixed_op_rollback_restores_migrations_and_evictions() {
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    // pre-state: a replica on d1 and a migrated KV cache
    let kv = ModuleId::layer(ModuleKind::KvCache, 3);
    let prep = ScalePlan {
        ops: vec![
            ModuleOp::Replicate { layer: 5, dst: 1 },
            ModuleOp::MigrateModule { module: kv, dst: 2, payload_bytes: 1.0 * GIB },
        ],
    };
    PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &prep).unwrap();

    let before = snapshot(&cl, &pl);
    // apply a mixed plan stepwise, then roll the whole thing back
    let plan = ScalePlan {
        ops: vec![
            ModuleOp::MigrateLayer { layer: 9, dst: 2 },
            ModuleOp::Evict { layer: 5, device: 1 },
            ModuleOp::MigrateModule { module: kv, dst: 3, payload_bytes: 1.0 * GIB },
            ModuleOp::Replicate { layer: 6, dst: 1 },
        ],
    };
    let mut exec = PlanExecution::new();
    for op in &plan.ops {
        exec.apply_next(&ops, &mut cl, &mut pl, op).unwrap();
    }
    assert_eq!(pl.primary_device(9), 2);
    assert_eq!(pl.module_device(kv), 3);
    assert_eq!(pl.degree(5), 1);
    exec.rollback(&mut cl, &mut pl);
    assert_eq!(before, snapshot(&cl, &pl), "mixed-op rollback byte-identical");
    assert_eq!(pl.primary_device(9), 0);
    assert_eq!(pl.module_device(kv), 2);
    assert_eq!(pl.degree(5), 2);
}

#[test]
fn device_failure_mid_plan_rolls_back_survivors_byte_identically() {
    // Two replicas land on device 1, then the device dies under the
    // in-flight plan. The next op targeting it must fail with the
    // device-failed allocation error, and rollback must restore the
    // placement and every *surviving* ledger byte-identically — while
    // the dead device stays empty: its copies were lost with it, and
    // undo entries pointing at it are refused rather than re-acquired.
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let before = snapshot(&cl, &pl);

    let plan = ScalePlan::replicate_batch(&[0, 1, 2, 3, 4], 1);
    let mut exec = PlanExecution::new();
    for op in plan.ops.iter().take(2) {
        exec.apply_next(&ops, &mut cl, &mut pl, op).unwrap();
    }
    let lost = cl.device_mut(1).fail();
    assert!(lost > 0.0, "the two landed replicas die with the device");
    // the in-flight plan's next op targets the corpse
    let err = exec.apply_next(&ops, &mut cl, &mut pl, &plan.ops[2]);
    assert!(err.is_err(), "an op targeting a dead device must fail");
    assert_eq!(exec.applied(), 2);

    exec.rollback(&mut cl, &mut pl);
    // device 1 was empty before the plan and is empty (dead) after, so
    // the full snapshot — survivors byte-for-byte + placement — matches
    assert_eq!(before, snapshot(&cl, &pl), "post-failure rollback must restore");
    assert!(
        cl.device(1).allocations().is_empty(),
        "rollback must never re-acquire memory on a dead device"
    );
    assert_eq!(cl.device(1).free_bytes(), 0.0, "dead device refuses future work");
}

#[test]
fn rollback_after_failure_restores_moved_primaries_without_reacquiring() {
    // A migration moves layer 9's primary onto device 1, a replica lands
    // on device 2, then device 1 dies and the plan is aborted — the
    // simulator's recovery path (abort first, repair placement second).
    // Rollback must point the primary back at device 0 (the source copy
    // was never freed: copy-then-free defers frees to commit), drop the
    // device-2 replica byte-identically, and leave the corpse empty.
    let (cm, mut cl, mut pl) = setup();
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let before = snapshot(&cl, &pl);

    let plan = ScalePlan {
        ops: vec![
            ModuleOp::MigrateLayer { layer: 9, dst: 1 },
            ModuleOp::Replicate { layer: 3, dst: 2 },
        ],
    };
    let mut exec = PlanExecution::new();
    for op in &plan.ops {
        exec.apply_next(&ops, &mut cl, &mut pl, op).unwrap();
    }
    assert_eq!(pl.primary_device(9), 1);
    let lost = cl.device_mut(1).fail();
    assert!(lost > 0.0);

    exec.rollback(&mut cl, &mut pl);
    assert_eq!(pl.primary_device(9), 0, "primary must fall back to the live source");
    assert_eq!(pl.degree(3), 1, "the replica must be undone");
    assert_eq!(before, snapshot(&cl, &pl), "survivor ledgers restore byte-identically");
    assert!(
        cl.device(1).allocations().is_empty(),
        "undo entries pointing at the corpse are refused, not re-acquired"
    );
}

#[test]
fn prop_failed_or_aborted_plans_leave_state_byte_identical() {
    // Random fills + random plans. Whatever happens — success, validation
    // rejection, or mid-plan failure — the invariants hold:
    //   success  ⇒ executed cost == dry-run cost (bit for bit)
    //   failure  ⇒ allocation ledgers + placement byte-identical
    prop::check(
        "plan-rollback",
        |r: &mut Rng| {
            let seed = r.next_u64();
            let fills: Vec<f64> = (0..4).map(|_| r.f64() * 14.0).collect();
            let n_ops = 1 + r.below(8) as usize;
            (seed, fills, n_ops)
        },
        |&(seed, ref fills, n_ops)| {
            let cm = CostModel::new(ModelConfig::llama2_13b());
            let mut cl = Cluster::paper_testbed();
            let pl0 = Placement::single_device(40, 0);
            let ops = ModuleOps::new(&cm, 2, "inst0");
            ops.deploy_instance(&mut cl, &pl0).map_err(|e| e.to_string())?;
            for (d, gib) in fills.iter().enumerate().skip(1) {
                cl.device_mut(d).alloc("fill", gib * GIB).map_err(|e| e.to_string())?;
            }
            let mut pl = pl0;
            // seed a couple of replicas so evictions have targets
            let seed_plan = ScalePlan::replicate_batch(&[0, 1], 1);
            PlanExecutor::new(&ops)
                .execute(&mut cl, &mut pl, &seed_plan)
                .map_err(|e| e.to_string())?;

            let mut rng = Rng::new(seed);
            let mut plan = ScalePlan::new();
            for _ in 0..n_ops {
                let layer = rng.below(40) as usize;
                let dst = rng.below(4) as usize;
                let op = match rng.below(4) {
                    0 => ModuleOp::Replicate { layer, dst },
                    1 => ModuleOp::MigrateLayer { layer, dst },
                    2 => ModuleOp::Evict { layer, device: dst },
                    _ => ModuleOp::MigrateModule {
                        module: ModuleId::layer(ModuleKind::KvCache, layer),
                        dst,
                        payload_bytes: rng.f64() * 2.0 * GIB,
                    },
                };
                plan.push(op);
            }

            let before = snapshot(&cl, &pl);
            let dry = plan.dry_run(&ops, &cl, &pl);
            match PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &plan) {
                Ok(executed) => {
                    let dry = dry.map_err(|e| format!("dry-run failed on ok plan: {e}"))?;
                    if dry != executed {
                        return Err(format!(
                            "parity broken: dry {dry:?} != executed {executed:?}"
                        ));
                    }
                    pl.validate(cl.n())?;
                }
                Err(_) => {
                    let after = snapshot(&cl, &pl);
                    if before != after {
                        return Err("failed plan left residue".into());
                    }
                }
            }
            Ok(())
        },
    );
}
