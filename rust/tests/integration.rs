//! End-to-end integration tests over the real artifacts (require
//! `make artifacts`). These exercise the full three-layer stack: Pallas
//! kernels → JAX modules → HLO text → PJRT compile → Rust execution,
//! and assert the golden token parity + the paper's semantic-preservation
//! contracts (§3.1) for replication and module-split execution.

use cocoserve::engine::{LayerExec, TinyEngine};
use cocoserve::runtime::{artifacts_available, default_artifacts_dir, PjrtEngine};
use cocoserve::util::json::Json;

fn engine() -> Option<TinyEngine> {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(TinyEngine::open(&default_artifacts_dir(), "tiny-llama").expect("engine opens"))
}

struct Goldens {
    prompts: Vec<Vec<i32>>,
    expected: Vec<Vec<i32>>,
    n_new: usize,
}

fn goldens() -> Option<Goldens> {
    let p = default_artifacts_dir().join("goldens_tiny-llama.json");
    let text = std::fs::read_to_string(p).ok()?;
    let j = Json::parse(&text).unwrap();
    let toks = |key: &str| -> Vec<Vec<i32>> {
        j.req(key)
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_f64().unwrap() as i32)
                    .collect()
            })
            .collect()
    };
    Some(Goldens {
        prompts: toks("prompts"),
        expected: toks("expected"),
        n_new: j.req("n_new").as_usize().unwrap(),
    })
}

#[test]
fn pjrt_loads_and_runs_a_raw_artifact() {
    if !artifacts_available() {
        return;
    }
    let eng = PjrtEngine::open(&default_artifacts_dir()).unwrap();
    // embed: tokens [1,16] i32, table [512,64] -> hidden [1,16,64]
    let toks: Vec<i32> = (0..16).collect();
    let table: Vec<f32> = (0..512 * 64).map(|i| (i % 7) as f32).collect();
    let out = eng
        .execute(
            "tiny-llama__embed__b1_s16",
            &[
                eng.lit_i32(&toks, &[1, 16]).unwrap(),
                eng.lit_f32(&table, &[512, 64]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let hidden: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(hidden.len(), 16 * 64);
    // row t of the output is row t of the table (tokens are 0..16)
    assert_eq!(&hidden[..64], &table[..64]);
    assert_eq!(&hidden[64..128], &table[64..128]);
}

#[test]
fn executables_are_cached_after_first_use() {
    if !artifacts_available() {
        return;
    }
    let eng = PjrtEngine::open(&default_artifacts_dir()).unwrap();
    assert_eq!(eng.compiled_count(), 0);
    assert!(!eng.ensure_compiled("tiny-llama__embed__b1_s16").unwrap());
    assert!(eng.ensure_compiled("tiny-llama__embed__b1_s16").unwrap());
    assert_eq!(eng.compiled_count(), 1);
}

#[test]
fn greedy_generation_matches_python_goldens_exactly() {
    let (Some(eng), Some(g)) = (engine(), goldens()) else { return };
    // batch them the way the goldens were produced (single batch)
    let got = eng.generate_greedy(&g.prompts, g.n_new).unwrap();
    assert_eq!(
        got, g.expected,
        "rust pipeline must reproduce the jax reference token-for-token"
    );
}

#[test]
fn split_module_execution_is_token_identical() {
    // §3.1: migrating attention/FFN sub-modules must preserve semantics.
    let (Some(mut eng), Some(g)) = (engine(), goldens()) else { return };
    eng.exec = LayerExec::Split;
    let got = eng.generate_greedy(&g.prompts, g.n_new).unwrap();
    assert_eq!(got, g.expected, "split attn+ffn path must match goldens");
}

#[test]
fn replicated_prefill_is_token_identical() {
    // Fig. 4: batch split across replicas + gather == unsplit execution.
    let (Some(eng), Some(g)) = (engine(), goldens()) else { return };
    let mut seqs: Vec<_> = g
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| eng.new_sequence(i as u64, p))
        .collect();
    let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
    let toks = eng.prefill_replicated(&mut refs, 2).unwrap();
    let expected_first: Vec<i32> = g.expected.iter()
        .zip(&g.prompts)
        .map(|(e, p)| e[p.len()])
        .collect();
    assert_eq!(toks, expected_first);
}

#[test]
fn decode_handles_mixed_sequence_lengths() {
    // continuous batching: sequences at different kv_lens decode together
    let Some(eng) = engine() else { return };
    let mut a = eng.new_sequence(0, &[5, 6, 7]);
    let mut b = eng.new_sequence(1, &[9, 10, 11, 12, 13, 14]);
    // prefill separately (different arrival times)
    eng.prefill(&mut [&mut a]).unwrap();
    eng.prefill(&mut [&mut b]).unwrap();
    let solo_a = {
        let mut a2 = a.clone();
        eng.decode(&mut [&mut a2]).unwrap()[0]
    };
    let solo_b = {
        let mut b2 = b.clone();
        eng.decode(&mut [&mut b2]).unwrap()[0]
    };
    let joint = eng.decode(&mut [&mut a, &mut b]).unwrap();
    assert_eq!(joint, vec![solo_a, solo_b],
               "batched decode must equal independent decodes");
}

#[test]
fn generation_is_deterministic() {
    let Some(eng) = engine() else { return };
    let p = vec![vec![3, 1, 4, 1, 5]];
    let a = eng.generate_greedy(&p, 6).unwrap();
    let b = eng.generate_greedy(&p, 6).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), 5 + 6);
    assert!(a[0].iter().all(|&t| (0..512).contains(&t)));
}
