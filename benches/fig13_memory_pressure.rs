//! Fig. 13 (extension) — the memory-pressure governor's claim check:
//! elastic KV resizing + quantized layer swapping shed strictly fewer
//! requests than the raw OOM policy, at equal-or-lower device-seconds.
//!
//! One 13B instance serves identical traces on a deliberately memory-
//! starved A100 (a ledger hog leaves ~3 GiB of post-deploy headroom, so
//! KV pressure — not compute — is the binding constraint). Two cells per
//! scenario:
//!
//! * **governor off** — the vLLM-like baseline's raw `Preempt` behaviour:
//!   every pressure episode immediately sheds the newest sequence.
//! * **governor on** — the same instance behind `MempressConfig::default()`:
//!   episodes first grow the pre-granted KV pool into device headroom,
//!   then swap the coldest decoder layers to int8 (freeing half their
//!   weight bytes as KV headroom, paid for as a per-step quality penalty
//!   in the metrics JSON), and only shed once the whole ladder is
//!   exhausted.
//!
//! Asserted per scenario (burst spike and two-tenant mix — the shapes
//! whose transient peaks a static reservation cannot ride out):
//! (a) governor-off sheds at least one request (the pressure is real);
//! (b) governor-on sheds strictly fewer requests;
//! (c) governor-on spends equal-or-lower device-seconds;
//! (d) the governor actually walked the ladder (episodes > 0, and at
//!     least one grow or swap landed);
//! (e) every cell golden-replays byte-identically.
//!
//! ```bash
//! cargo bench --bench fig13_memory_pressure              # full sweep
//! FIG13_SMOKE=1 cargo bench --bench fig13_memory_pressure  # CI smoke
//! ```

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::mempress::MempressConfig;
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::Trace;

const SEED: u64 = 130;
/// Post-deploy device headroom the hog leaves for KV (bytes). Small
/// enough that scenario peaks overrun it, large enough that the base
/// load fits — the regime where the ladder, not the shed, should absorb
/// the transient.
const KV_HEADROOM_BYTES: f64 = 3.0 * GIB;

struct BenchShape {
    rps: f64,
    duration_s: f64,
    smoke: bool,
}

impl BenchShape {
    fn from_env() -> BenchShape {
        let smoke = std::env::var("FIG13_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
            || std::env::args().any(|a| a == "--smoke");
        if smoke {
            BenchShape { rps: 15.0, duration_s: 24.0, smoke }
        } else {
            BenchShape { rps: 15.0, duration_s: 40.0, smoke }
        }
    }
}

fn run(governed: bool, trace: &Trace, duration_s: f64) -> SimReport {
    let mut cfg = SimConfig::paper_13b();
    if governed {
        cfg.mempress = Some(MempressConfig::default());
    }
    let cost = cfg.cost_model();
    let mut cluster = Cluster::homogeneous(1, DeviceSpec::a100_40gb());
    // Starve the device: after the 13B weights deploy, exactly
    // KV_HEADROOM_BYTES remain. Identical for both cells, so the only
    // difference between runs is the governor.
    let free = cluster.device(0).free_bytes();
    let hog = free - cost.model_bytes(cfg.dtype_bytes) - KV_HEADROOM_BYTES;
    cluster.device_mut(0).alloc("fig13-hog", hog).unwrap();
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    Simulation::new(cfg, cluster, vec![(placement, baselines::vllm_like(64))])
        .run(trace, duration_s)
}

fn main() {
    let shape = BenchShape::from_env();
    println!(
        "Fig. 13 — memory-pressure governor, 13B on 1×A100 with {:.0} GiB KV \
         headroom, {:.0} rps base, {:.0}s{}\n",
        KV_HEADROOM_BYTES / GIB,
        shape.rps,
        shape.duration_s,
        if shape.smoke { " (SMOKE)" } else { "" }
    );

    let scenarios: Vec<(&str, Trace)> = vec![
        ("burst", Trace::burst(shape.rps, shape.duration_s, SEED)),
        ("two_tenant", Trace::two_tenant(2.0 * shape.rps, shape.duration_s, SEED)),
    ];

    let mut table = Table::new(&[
        "scenario", "governor", "sheds", "dev·s", "SLO%", "grows", "swaps",
        "escalations", "quality",
    ]);
    let mut rep = Report::new("fig13_memory_pressure");
    let mut replay_ok = true;

    for (name, trace) in &scenarios {
        let mut cells = Vec::new();
        for governed in [false, true] {
            let r = run(governed, trace, shape.duration_s);
            // (e) golden replay per cell
            let again = run(governed, trace, shape.duration_s);
            let identical = r.to_json().to_string() == again.to_json().to_string();
            replay_ok &= identical;
            if !identical {
                eprintln!(
                    "WARNING: {name}/governor={governed} not replay-deterministic"
                );
            }
            let mp = r.mempress;
            table.row(&[
                name.to_string(),
                if governed { "on" } else { "off" }.to_string(),
                r.oom_victims.to_string(),
                format!("{:.0}", r.device_seconds),
                format!("{:.1}", r.slo_attainment() * 100.0),
                mp.map_or("-".into(), |m| m.kv_grows.to_string()),
                mp.map_or("-".into(), |m| m.swaps_applied.to_string()),
                mp.map_or("-".into(), |m| m.escalations.to_string()),
                mp.map_or("-".into(), |m| format!("{:.2}", m.quality_penalty)),
            ]);
            rep.set(
                &format!("{name}_{}", if governed { "on" } else { "off" }),
                json::obj(vec![
                    ("sheds", json::num(r.oom_victims as f64)),
                    ("device_seconds", json::num(r.device_seconds)),
                    ("slo_attainment", json::num(r.slo_attainment())),
                    ("completed", json::num(r.total_completed() as f64)),
                    ("kv_grows", json::num(mp.map_or(0.0, |m| m.kv_grows as f64))),
                    (
                        "swaps_applied",
                        json::num(mp.map_or(0.0, |m| m.swaps_applied as f64)),
                    ),
                    (
                        "sheds_averted",
                        json::num(mp.map_or(0.0, |m| m.sheds_averted as f64)),
                    ),
                    (
                        "quality_penalty",
                        json::num(mp.map_or(0.0, |m| m.quality_penalty)),
                    ),
                    ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
                ]),
            );
            cells.push(r);
        }

        let (off, on) = (&cells[0], &cells[1]);
        // (a) the scenario genuinely overruns the raw policy's memory
        assert!(
            off.oom_victims > 0,
            "{name}: governor-off shed nothing — the scenario is miscalibrated"
        );
        // (b) the ladder sheds strictly less
        assert!(
            on.oom_victims < off.oom_victims,
            "{name}: governed sheds ({}) must be strictly below raw ({})",
            on.oom_victims,
            off.oom_victims
        );
        // (c) at equal-or-lower device cost
        assert!(
            on.device_seconds <= off.device_seconds,
            "{name}: governed {:.1} dev·s must not exceed raw {:.1}",
            on.device_seconds,
            off.device_seconds
        );
        // (d) the relief was earned by the ladder, not by accident
        let mp = on.mempress.expect("governed cell carries a mempress block");
        assert!(mp.episodes > 0, "{name}: the governor never saw pressure");
        assert!(
            mp.kv_grows + mp.swaps_applied > 0,
            "{name}: no grow or swap landed — relief came from nowhere"
        );
        assert!(off.mempress.is_none(), "ungoverned cell must carry no block");
    }

    table.print();
    println!(
        "\ngolden replay across all cells: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
