//! KV-cache managers — paged (vLLM-style) and contiguous (HFT-style).
//!
//! The paper treats the KV cache as a first-class *module*: memory-intensive,
//! compute-free, migratable independently of its layer (§3.3). This module
//! provides the allocators whose fragmentation behaviour drives Fig. 9
//! (memory utilization / waste) and the OOM dynamics of Fig. 11a:
//!
//! * [`PagedKvCache`] — block-granular allocation; waste is bounded by one
//!   partial block per (sequence, layer).
//! * [`ContiguousKvCache`] — reserves max-sequence-length up front per
//!   sequence (what the paper attributes to HFT); waste = reserved − used.
//!
//! Both report identical accounting interfaces so the engine, simulator and
//! Fig. 9 bench can swap them per baseline policy.

use std::collections::BTreeMap;

/// Accounting snapshot used by the monitor and Fig. 9.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct KvStats {
    /// Bytes actually holding live K/V entries.
    pub live_bytes: f64,
    /// Bytes reserved from the device (>= live).
    pub reserved_bytes: f64,
    /// Sequences currently tracked.
    pub sequences: usize,
}

impl KvStats {
    /// Reserved-but-dead bytes — the paper's "wasted memory" (Fig. 9).
    pub fn waste_bytes(&self) -> f64 {
        (self.reserved_bytes - self.live_bytes).max(0.0)
    }

    /// Fragmentation ratio: reserved / live (1.0 = perfect).
    pub fn fragmentation(&self) -> f64 {
        if self.live_bytes == 0.0 {
            if self.reserved_bytes == 0.0 { 1.0 } else { f64::INFINITY }
        } else {
            self.reserved_bytes / self.live_bytes
        }
    }
}

/// Common interface: token-granular per-sequence cache accounting.
pub trait KvCache {
    /// Register a new sequence with `prompt_tokens` already cached.
    /// Returns Err(deficit_bytes) if the pool cannot hold it.
    fn add_sequence(&mut self, seq: u64, prompt_tokens: usize) -> Result<(), f64>;

    /// Append one decoded token to a sequence.
    fn append_token(&mut self, seq: u64) -> Result<(), f64>;

    /// Drop a finished sequence, releasing its reservation.
    fn remove_sequence(&mut self, seq: u64);

    /// Retarget the pool to `pool_bytes` total capacity.
    ///
    /// Shrinking reclaims only *free* capacity — live reservations are never
    /// evicted. Returns `Ok(freed_bytes)`, the bytes actually released back
    /// to the device (`0.0` when growing), or `Err(deficit_bytes)` when live
    /// reservations alone exceed the requested pool; on `Err` the pool is
    /// left untouched. Growing is always accepted here — bounding it by
    /// device headroom (via `LedgerView`) is the caller's job (the
    /// memory-pressure governor checks before asking).
    fn resize(&mut self, pool_bytes: f64) -> Result<f64, f64>;

    /// Total pool capacity in bytes — the pre-granted device reservation
    /// a governed instance mirrors into the ledger (reserved ≤ pool). For
    /// a paged pool this is the block-rounded capacity; unbounded pools
    /// report what they were constructed with.
    fn pool_bytes(&self) -> f64;

    /// Accounting snapshot (live/reserved bytes, sequence count).
    fn stats(&self) -> KvStats;

    /// Tokens currently cached for `seq`, or `None` if unknown.
    fn tokens_of(&self, seq: u64) -> Option<usize>;
}

/// Paged allocator: fixed-size blocks of `block_tokens` tokens.
#[derive(Debug, Clone)]
pub struct PagedKvCache {
    /// Bytes of K+V for ONE token across all layers of the instance.
    bytes_per_token: f64,
    block_tokens: usize,
    /// Total pool capacity in blocks.
    capacity_blocks: usize,
    free_blocks: usize,
    seqs: BTreeMap<u64, SeqAlloc>,
}

#[derive(Debug, Clone, Copy)]
struct SeqAlloc {
    tokens: usize,
    blocks: usize,
}

impl PagedKvCache {
    /// `pool_bytes` is the device memory granted to the cache pool.
    pub fn new(pool_bytes: f64, bytes_per_token: f64, block_tokens: usize) -> Self {
        assert!(block_tokens > 0 && bytes_per_token > 0.0);
        let block_bytes = bytes_per_token * block_tokens as f64;
        PagedKvCache {
            bytes_per_token,
            block_tokens,
            capacity_blocks: (pool_bytes / block_bytes) as usize,
            free_blocks: (pool_bytes / block_bytes) as usize,
            seqs: BTreeMap::new(),
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Bytes in one allocation block (`bytes_per_token * block_tokens`).
    pub fn block_bytes(&self) -> f64 {
        self.bytes_per_token * self.block_tokens as f64
    }

    /// Blocks currently unallocated.
    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    /// Total pool capacity in blocks.
    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }
}

impl KvCache for PagedKvCache {
    fn add_sequence(&mut self, seq: u64, prompt_tokens: usize) -> Result<(), f64> {
        // A duplicate id is an error, not a panic — same contract as
        // `append_token` on an unknown id: nothing is allocated, so the
        // reported deficit is zero.
        if self.seqs.contains_key(&seq) {
            return Err(0.0);
        }
        let need = self.blocks_for(prompt_tokens.max(1));
        if need > self.free_blocks {
            return Err((need - self.free_blocks) as f64 * self.block_bytes());
        }
        self.free_blocks -= need;
        self.seqs.insert(seq, SeqAlloc { tokens: prompt_tokens, blocks: need });
        Ok(())
    }

    fn append_token(&mut self, seq: u64) -> Result<(), f64> {
        // An unknown id (e.g. a sequence preempted/removed between the
        // decode decision and the append) is an error, not a panic: no
        // bytes are missing, so the reported deficit is zero.
        let Some(&a) = self.seqs.get(&seq) else {
            return Err(0.0);
        };
        let need = self.blocks_for(a.tokens + 1);
        if need > a.blocks {
            if self.free_blocks == 0 {
                return Err(self.block_bytes());
            }
            self.free_blocks -= 1;
        }
        let e = self.seqs.get_mut(&seq).expect("checked above");
        e.tokens += 1;
        e.blocks = need.max(a.blocks);
        Ok(())
    }

    fn remove_sequence(&mut self, seq: u64) {
        if let Some(a) = self.seqs.remove(&seq) {
            self.free_blocks += a.blocks;
        }
    }

    fn resize(&mut self, pool_bytes: f64) -> Result<f64, f64> {
        let new_capacity = (pool_bytes / self.block_bytes()) as usize;
        let used = self.capacity_blocks - self.free_blocks;
        if new_capacity < used {
            return Err((used - new_capacity) as f64 * self.block_bytes());
        }
        let freed = self.capacity_blocks.saturating_sub(new_capacity) as f64 * self.block_bytes();
        self.capacity_blocks = new_capacity;
        self.free_blocks = new_capacity - used;
        Ok(freed)
    }

    fn pool_bytes(&self) -> f64 {
        self.capacity_blocks as f64 * self.block_bytes()
    }

    fn stats(&self) -> KvStats {
        let live: usize = self.seqs.values().map(|a| a.tokens).sum();
        let blocks: usize = self.seqs.values().map(|a| a.blocks).sum();
        KvStats {
            live_bytes: live as f64 * self.bytes_per_token,
            reserved_bytes: blocks as f64 * self.block_bytes(),
            sequences: self.seqs.len(),
        }
    }

    fn tokens_of(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }
}

/// Contiguous allocator: reserves `max_seq_tokens` per sequence up front —
/// the static allocation the paper attributes to HFT (§2.3, Fig. 9).
#[derive(Debug, Clone)]
pub struct ContiguousKvCache {
    bytes_per_token: f64,
    max_seq_tokens: usize,
    pool_bytes: f64,
    reserved: f64,
    seqs: BTreeMap<u64, usize>,
}

impl ContiguousKvCache {
    /// `pool_bytes` is the device memory granted to the cache pool; every
    /// sequence reserves `bytes_per_token * max_seq_tokens` up front.
    pub fn new(pool_bytes: f64, bytes_per_token: f64, max_seq_tokens: usize) -> Self {
        ContiguousKvCache {
            bytes_per_token,
            max_seq_tokens,
            pool_bytes,
            reserved: 0.0,
            seqs: BTreeMap::new(),
        }
    }

    fn per_seq_bytes(&self) -> f64 {
        self.bytes_per_token * self.max_seq_tokens as f64
    }
}

impl KvCache for ContiguousKvCache {
    fn add_sequence(&mut self, seq: u64, prompt_tokens: usize) -> Result<(), f64> {
        // duplicate ids and over-length prompts are errors, not panics —
        // a duplicate allocates nothing (deficit 0), an over-length prompt
        // reports the bytes it would need beyond the fixed reservation
        if self.seqs.contains_key(&seq) {
            return Err(0.0);
        }
        if prompt_tokens > self.max_seq_tokens {
            return Err((prompt_tokens - self.max_seq_tokens) as f64 * self.bytes_per_token);
        }
        let need = self.per_seq_bytes();
        if self.reserved + need > self.pool_bytes {
            return Err(self.reserved + need - self.pool_bytes);
        }
        self.reserved += need;
        self.seqs.insert(seq, prompt_tokens);
        Ok(())
    }

    fn append_token(&mut self, seq: u64) -> Result<(), f64> {
        // same contract as the paged allocator: unknown ids report an
        // error (zero deficit) instead of panicking
        let Some(t) = self.seqs.get_mut(&seq) else {
            return Err(0.0);
        };
        if *t >= self.max_seq_tokens {
            return Err(self.bytes_per_token); // over pre-reserved length
        }
        *t += 1;
        Ok(())
    }

    fn remove_sequence(&mut self, seq: u64) {
        if self.seqs.remove(&seq).is_some() {
            self.reserved -= self.per_seq_bytes();
        }
    }

    fn resize(&mut self, pool_bytes: f64) -> Result<f64, f64> {
        if pool_bytes < self.reserved {
            return Err(self.reserved - pool_bytes);
        }
        let freed = (self.pool_bytes - pool_bytes).max(0.0);
        self.pool_bytes = pool_bytes;
        Ok(freed)
    }

    fn pool_bytes(&self) -> f64 {
        self.pool_bytes
    }

    fn stats(&self) -> KvStats {
        let live: usize = self.seqs.values().sum();
        KvStats {
            live_bytes: live as f64 * self.bytes_per_token,
            reserved_bytes: self.reserved,
            sequences: self.seqs.len(),
        }
    }

    fn tokens_of(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    const BPT: f64 = 1024.0; // bytes per token, test-sized

    #[test]
    fn paged_partial_block_waste_bounded() {
        let mut c = PagedKvCache::new(1e6, BPT, 16);
        c.add_sequence(1, 17).unwrap(); // 2 blocks, 15 tokens wasted
        let s = c.stats();
        assert_eq!(s.reserved_bytes, 2.0 * 16.0 * BPT);
        assert_eq!(s.live_bytes, 17.0 * BPT);
        assert!(s.waste_bytes() <= c.block_bytes());
    }

    #[test]
    fn paged_append_crosses_block_boundary() {
        let mut c = PagedKvCache::new(1e6, BPT, 4);
        c.add_sequence(1, 4).unwrap(); // exactly 1 block
        let before = c.free_blocks();
        c.append_token(1).unwrap(); // needs block 2
        assert_eq!(c.free_blocks(), before - 1);
        c.append_token(1).unwrap(); // fits in block 2
        assert_eq!(c.free_blocks(), before - 1);
    }

    #[test]
    fn paged_oom_reports_deficit() {
        let mut c = PagedKvCache::new(16.0 * BPT, BPT, 16); // 1 block total
        c.add_sequence(1, 8).unwrap();
        let e = c.add_sequence(2, 8).unwrap_err();
        assert!(e > 0.0);
    }

    #[test]
    fn append_to_unknown_sequence_errs_instead_of_panicking() {
        // regression: both allocators used to unwrap/expect on the seq
        // map, so appending to an unknown id took the process down
        let mut paged = PagedKvCache::new(1e6, BPT, 16);
        paged.add_sequence(1, 8).unwrap();
        let free_before = paged.free_blocks();
        assert!(paged.append_token(99).is_err());
        assert_eq!(paged.free_blocks(), free_before, "no blocks leaked");
        assert_eq!(paged.tokens_of(1), Some(8), "live sequences untouched");

        let mut cont = ContiguousKvCache::new(1e7, BPT, 256);
        cont.add_sequence(1, 8).unwrap();
        let reserved_before = cont.stats().reserved_bytes;
        assert!(cont.append_token(99).is_err());
        assert_eq!(cont.stats().reserved_bytes, reserved_before);
        // a removed sequence behaves exactly like a never-known one
        cont.remove_sequence(1);
        assert!(cont.append_token(1).is_err());
    }

    #[test]
    fn duplicate_add_errs_instead_of_panicking() {
        // regression: both allocators used to assert! on a duplicate id,
        // so a re-admitted request id took the process down
        let mut paged = PagedKvCache::new(1e6, BPT, 16);
        paged.add_sequence(1, 8).unwrap();
        let free_before = paged.free_blocks();
        assert_eq!(paged.add_sequence(1, 8), Err(0.0));
        assert_eq!(paged.free_blocks(), free_before, "no blocks leaked");
        assert_eq!(paged.tokens_of(1), Some(8), "original alloc untouched");

        let mut cont = ContiguousKvCache::new(1e7, BPT, 256);
        cont.add_sequence(1, 8).unwrap();
        let reserved_before = cont.stats().reserved_bytes;
        assert_eq!(cont.add_sequence(1, 8), Err(0.0));
        assert_eq!(cont.stats().reserved_bytes, reserved_before);
        assert_eq!(cont.tokens_of(1), Some(8));
        // over-length prompts report the excess bytes instead of asserting
        assert!(cont.add_sequence(2, 257).unwrap_err() > 0.0);
    }

    #[test]
    fn paged_resize_shrinks_only_free_blocks() {
        let mut c = PagedKvCache::new(64.0 * 16.0 * BPT, BPT, 16); // 64 blocks
        c.add_sequence(1, 160).unwrap(); // 10 blocks live
        // shrink to 16 blocks: 48 blocks of free capacity released
        let freed = c.resize(16.0 * 16.0 * BPT).unwrap();
        assert_eq!(freed, 48.0 * c.block_bytes());
        assert_eq!(c.capacity_blocks(), 16);
        assert_eq!(c.free_blocks(), 6);
        assert_eq!(c.pool_bytes(), 16.0 * 16.0 * BPT);
        // shrinking below live reservations reports the deficit and leaves
        // the pool untouched
        let deficit = c.resize(4.0 * 16.0 * BPT).unwrap_err();
        assert_eq!(deficit, 6.0 * c.block_bytes());
        assert_eq!(c.capacity_blocks(), 16);
        // growing is always accepted (headroom is the caller's check)
        assert_eq!(c.resize(64.0 * 16.0 * BPT).unwrap(), 0.0);
        assert_eq!(c.free_blocks(), 54);
    }

    #[test]
    fn resize_round_trip_is_bit_identical() {
        let mut paged = PagedKvCache::new(64.0 * 16.0 * BPT, BPT, 16);
        paged.add_sequence(1, 33).unwrap();
        let before = paged.stats();
        let free_before = paged.free_blocks();
        paged.resize(16.0 * 16.0 * BPT).unwrap();
        paged.resize(64.0 * 16.0 * BPT).unwrap();
        assert_eq!(paged.stats(), before);
        assert_eq!(paged.free_blocks(), free_before);

        let mut cont = ContiguousKvCache::new(1e7, BPT, 256);
        cont.add_sequence(1, 33).unwrap();
        let before = cont.stats();
        cont.resize(512.0 * BPT).unwrap();
        cont.resize(1e7).unwrap();
        assert_eq!(cont.stats(), before);
        // shrinking below live reservations is refused with the deficit
        let deficit = cont.resize(128.0 * BPT).unwrap_err();
        assert_eq!(deficit, 128.0 * BPT);
    }

    #[test]
    fn paged_remove_releases_blocks() {
        let mut c = PagedKvCache::new(1e6, BPT, 16);
        let total = c.free_blocks();
        c.add_sequence(1, 40).unwrap();
        c.add_sequence(2, 10).unwrap();
        c.remove_sequence(1);
        c.remove_sequence(2);
        assert_eq!(c.free_blocks(), total);
        assert_eq!(c.stats().sequences, 0);
    }

    #[test]
    fn contiguous_reserves_max_length() {
        let mut c = ContiguousKvCache::new(1e7, BPT, 256);
        c.add_sequence(1, 20).unwrap();
        let s = c.stats();
        assert_eq!(s.reserved_bytes, 256.0 * BPT);
        assert_eq!(s.live_bytes, 20.0 * BPT);
        // the Fig. 9 story: waste is huge relative to live for short seqs
        assert!(s.waste_bytes() > 10.0 * s.live_bytes);
    }

    #[test]
    fn contiguous_admits_fewer_sequences_than_paged() {
        // Same pool: paged fits many short sequences, contiguous few —
        // the mechanism behind HFT's early OOM (Fig. 11a).
        let pool = 1024.0 * BPT;
        let mut paged = PagedKvCache::new(pool, BPT, 16);
        let mut cont = ContiguousKvCache::new(pool, BPT, 256);
        let mut n_paged = 0;
        let mut n_cont = 0;
        for i in 0..100 {
            if paged.add_sequence(i, 20).is_ok() {
                n_paged += 1;
            }
            if cont.add_sequence(i, 20).is_ok() {
                n_cont += 1;
            }
        }
        assert!(n_paged > 3 * n_cont, "paged {n_paged} vs cont {n_cont}");
    }

    #[test]
    fn fragmentation_ratios_ordered() {
        let mut paged = PagedKvCache::new(1e7, BPT, 16);
        let mut cont = ContiguousKvCache::new(1e7, BPT, 256);
        for i in 0..8 {
            paged.add_sequence(i, 30).unwrap();
            cont.add_sequence(i, 30).unwrap();
        }
        assert!(paged.stats().fragmentation() < cont.stats().fragmentation());
        assert!(paged.stats().fragmentation() >= 1.0);
    }

    /// Property: block accounting is conserved under random workloads.
    #[test]
    fn prop_paged_block_conservation() {
        prop::check(
            "paged-conservation",
            |r: &mut Rng| {
                let ops: Vec<(u8, u64, usize)> = (0..60)
                    .map(|_| (r.below(3) as u8, r.below(6), 1 + r.below(40) as usize))
                    .collect();
                ops
            },
            |ops| {
                let mut c = PagedKvCache::new(5e5, BPT, 16);
                let cap = c.free_blocks();
                let mut live: std::collections::BTreeSet<u64> = Default::default();
                for &(op, seq, tok) in ops {
                    match op {
                        0 if !live.contains(&seq) => {
                            if c.add_sequence(seq, tok).is_ok() {
                                live.insert(seq);
                            }
                        }
                        1 if live.contains(&seq) => {
                            let _ = c.append_token(seq);
                        }
                        2 => {
                            c.remove_sequence(seq);
                            live.remove(&seq);
                        }
                        _ => {}
                    }
                    let used: usize = cap - c.free_blocks();
                    let s = c.stats();
                    let expect = (s.reserved_bytes / c.block_bytes()).round() as usize;
                    if used != expect {
                        return Err(format!("blocks {used} != reserved {expect}"));
                    }
                    if s.live_bytes > s.reserved_bytes + 1e-9 {
                        return Err("live exceeds reserved".into());
                    }
                }
                for s in live.iter() {
                    c.remove_sequence(*s);
                }
                if c.free_blocks() != cap {
                    return Err("leak after removing all".into());
                }
                Ok(())
            },
        );
    }
}
