//! Deterministic PRNG + distribution sampling (std-only `rand` replacement).
//!
//! xoshiro256** seeded via SplitMix64 — the standard construction. All
//! simulation and workload generation flows through [`Rng`], so every
//! experiment in `benches/` is reproducible from its seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any u64 seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (n > 0), via rejection-free Lemire-ish mul.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with the given *underlying* normal mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx above).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let x = mean + mean.sqrt() * self.normal();
            return x.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Derive an independent child stream (for per-component determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let c = Rng::new(43).next_u64();
        assert_ne!(a[0], c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_matches() {
        let mut r = Rng::new(4);
        for &m in &[0.5, 3.0, 20.0, 100.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(m)).sum();
            let got = s as f64 / n as f64;
            assert!((got - m).abs() < 0.05 * m + 0.05, "mean {m} got {got}");
        }
    }

    #[test]
    fn exponential_mean_matches() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let s: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let got = s / n as f64;
        assert!((got - 0.25).abs() < 0.01, "got {got}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(7);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
