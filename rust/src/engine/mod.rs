//! The real-path serving engine: tiny model, real tensors, Python-free.
//!
//! Drives the per-module PJRT executables ([`runtime`]) through the full
//! prefill + decode pipeline with host-owned KV caches — the end-to-end
//! proof that the three layers compose (DESIGN.md §E2E). The engine:
//!
//! * pads request batches to the manifest's shape buckets,
//! * owns per-sequence KV caches (the migratable module — host buffers
//!   moved between per-device stores by the coordinator),
//! * can execute a decoder layer **fused** or **split** into its
//!   attention/FFN sub-modules ([`LayerExec`]) — the execution-path
//!   equivalent of §3.3 module migration, asserted token-identical,
//! * can run prefill **replicated**: the batch split across replica shares
//!   (Fig. 4) and re-gathered, asserted token-identical.
//!
//! [`runtime`]: crate::runtime

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::model::ModelConfig;
use crate::runtime::{Manifest, PjrtEngine, WeightStore};
use crate::scheduler::split_batch;

/// How a decoder layer executes (semantics must be identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LayerExec {
    /// One fused `layer_*` artifact per layer.
    #[default]
    Fused,
    /// `attn_*` then `ffn_*` artifacts — the migrated-module path.
    Split,
}

/// One sequence being served.
#[derive(Debug, Clone)]
pub struct SeqState {
    /// Request id (the scheduler's key for this sequence).
    pub id: u64,
    /// Prompt tokens followed by everything generated so far.
    pub tokens: Vec<i32>,
    /// Tokens currently in the KV cache.
    pub kv_len: usize,
    /// Per-layer K cache, host-resident: [n_heads * max_seq * head_dim].
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl SeqState {
    /// KV bytes currently live (coordinator memory accounting).
    pub fn kv_bytes(&self, cfg: &ModelConfig) -> usize {
        2 * self.k.len() * self.kv_len * cfg.d_model * 4
    }
}

/// The engine over one model config's artifacts.
pub struct TinyEngine {
    /// PJRT client + loaded AOT executables.
    pub pjrt: PjrtEngine,
    /// Host-resident weight tensors for the active config.
    pub weights: WeightStore,
    /// The model config being served.
    pub cfg: ModelConfig,
    /// Maximum sequence length the artifacts were compiled for.
    pub max_seq: usize,
    /// Fused vs split layer execution (see [`LayerExec`]).
    pub exec: LayerExec,
    name: String,
    /// Weight literals cached per tensor name (perf pass #1: building a
    /// Literal from host data on *every* execute dominated the decode hot
    /// path — weights are immutable, upload once). See EXPERIMENTS.md §Perf.
    lit_cache: RefCell<HashMap<String, xla::Literal>>,
    /// Scratch buffer for batch-KV assembly (perf pass #2: avoid a fresh
    /// zeroed allocation per layer per decode step).
    kv_scratch: RefCell<Vec<f32>>,
}

impl TinyEngine {
    /// Open the artifact directory and load weights for `config`.
    pub fn open(artifacts_dir: &std::path::Path, config: &str) -> Result<TinyEngine> {
        let pjrt = PjrtEngine::open(artifacts_dir)?;
        let weights = WeightStore::load(artifacts_dir, pjrt.manifest(), config)?;
        let cfg = pjrt
            .manifest()
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("unknown config {config}"))?
            .clone();
        let max_seq = pjrt.manifest().max_seq_len;
        Ok(TinyEngine {
            pjrt,
            weights,
            cfg,
            max_seq,
            exec: LayerExec::Fused,
            name: config.to_string(),
            lit_cache: RefCell::new(HashMap::new()),
            kv_scratch: RefCell::new(Vec::new()),
        })
    }

    fn manifest(&self) -> &Manifest {
        self.pjrt.manifest()
    }

    /// Allocate a sequence with empty KV caches for `prompt`.
    pub fn new_sequence(&self, id: u64, prompt: &[i32]) -> SeqState {
        let per_layer = self.cfg.n_heads * self.max_seq * self.cfg.head_dim();
        SeqState {
            id,
            tokens: prompt.to_vec(),
            kv_len: 0,
            k: vec![vec![0.0; per_layer]; self.cfg.n_layers],
            v: vec![vec![0.0; per_layer]; self.cfg.n_layers],
        }
    }

    // ---- literal builders ---------------------------------------------------

    /// Cached literal for a named weight tensor (uploaded once).
    fn cached_lit(&self, key: &str) -> Result<xla::Literal> {
        if let Some(l) = self.lit_cache.borrow().get(key) {
            return Ok(l.clone());
        }
        let t = self.weights.get(key)?;
        let lit = self.pjrt.lit_f32(&t.data, &t.shape)?;
        self.lit_cache.borrow_mut().insert(key.to_string(), lit.clone());
        Ok(lit)
    }

    fn weight_lits(&self, layer: usize, names: &[&str]) -> Result<Vec<xla::Literal>> {
        names
            .iter()
            .map(|n| self.cached_lit(&format!("layer{layer}.{n}")))
            .collect()
    }

    /// Batch KV-cache literal [B, h, S, hd] for `layer` over `seqs`
    /// (padded rows zero).
    fn kv_literal(&self, seqs: &[&SeqState], b: usize, layer: usize, k: bool) -> Result<xla::Literal> {
        let per = self.cfg.n_heads * self.max_seq * self.cfg.head_dim();
        let mut buf = self.kv_scratch.borrow_mut();
        buf.clear();
        buf.resize(b * per, 0.0);
        for (i, s) in seqs.iter().enumerate() {
            let src = if k { &s.k[layer] } else { &s.v[layer] };
            buf[i * per..(i + 1) * per].copy_from_slice(src);
        }
        self.pjrt.lit_f32(
            &buf,
            &[b, self.cfg.n_heads, self.max_seq, self.cfg.head_dim()],
        )
    }

    // ---- prefill --------------------------------------------------------------

    /// Prefill a batch of sequences, appending each sequence's first
    /// generated token. Batch is padded to (batch bucket, seq bucket).
    pub fn prefill(&self, seqs: &mut [&mut SeqState]) -> Result<Vec<i32>> {
        anyhow::ensure!(!seqs.is_empty());
        let n = seqs.len();
        let max_len = seqs.iter().map(|s| s.tokens.len()).max().unwrap();
        let b = self
            .manifest()
            .batch_bucket(n)
            .ok_or_else(|| anyhow!("batch {n} exceeds buckets"))?;
        let s_bucket = self
            .manifest()
            .seq_bucket(max_len)
            .ok_or_else(|| anyhow!("prompt {max_len} exceeds buckets"))?;

        // tokens + positions, padded
        let mut toks = vec![0i32; b * s_bucket];
        let mut pos = vec![0i32; b * s_bucket];
        for (i, seq) in seqs.iter().enumerate() {
            for (j, &t) in seq.tokens.iter().enumerate() {
                toks[i * s_bucket + j] = t;
            }
            for j in 0..s_bucket {
                pos[i * s_bucket + j] = j as i32;
            }
        }
        for i in n..b {
            for j in 0..s_bucket {
                pos[i * s_bucket + j] = j as i32;
            }
        }
        let mut hidden = {
            let name = format!("{}__embed__b{b}_s{s_bucket}", self.name);
            let out = self.pjrt.execute(
                &name,
                &[
                    self.pjrt.lit_i32(&toks, &[b, s_bucket])?,
                    self.cached_lit("emb")?,
                ],
            )?;
            out.into_iter().next().unwrap()
        };
        let pos_lit = self.pjrt.lit_i32(&pos, &[b, s_bucket])?;

        for layer in 0..self.cfg.n_layers {
            let (h2, k, v) = self.prefill_layer(layer, hidden, &pos_lit, b, s_bucket)?;
            hidden = h2;
            // scatter K/V into host caches (each sequence its true length)
            let kv: Vec<f32> = k.to_vec()?;
            let vv: Vec<f32> = v.to_vec()?;
            let hd = self.cfg.head_dim();
            let h = self.cfg.n_heads;
            for (i, seq) in seqs.iter_mut().enumerate() {
                let len = seq.tokens.len();
                for head in 0..h {
                    for t in 0..len {
                        let src = ((i * h + head) * s_bucket + t) * hd;
                        let dst = (head * self.max_seq + t) * hd;
                        seq.k[layer][dst..dst + hd]
                            .copy_from_slice(&kv[src..src + hd]);
                        seq.v[layer][dst..dst + hd]
                            .copy_from_slice(&vv[src..src + hd]);
                    }
                }
                seq.kv_len = len;
            }
        }

        // lm head over true last positions
        let mut lens = vec![1i32; b];
        for (i, seq) in seqs.iter().enumerate() {
            lens[i] = seq.tokens.len() as i32;
        }
        let out = self.pjrt.execute(
            &format!("{}__lm_head_prefill__b{b}_s{s_bucket}", self.name),
            &[
                hidden,
                self.pjrt.lit_i32(&lens, &[b])?,
                self.cached_lit("rms_f")?,
                self.cached_lit("w_out")?,
            ],
        )?;
        let next: Vec<i32> = out[0].to_vec()?;
        let mut produced = Vec::with_capacity(n);
        for (i, seq) in seqs.iter_mut().enumerate() {
            seq.tokens.push(next[i]);
            produced.push(next[i]);
        }
        Ok(produced)
    }

    /// One decoder layer of prefill — fused or split per `self.exec`.
    fn prefill_layer(
        &self,
        layer: usize,
        hidden: xla::Literal,
        pos: &xla::Literal,
        b: usize,
        s: usize,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        match self.exec {
            LayerExec::Fused => {
                let mut args = vec![hidden, pos.clone()];
                args.extend(self.weight_lits(
                    layer,
                    &crate::runtime::weights::LAYER_WEIGHT_NAMES,
                )?);
                let mut out = self
                    .pjrt
                    .execute(&format!("{}__layer_prefill__b{b}_s{s}", self.name), &args)?;
                anyhow::ensure!(out.len() == 3);
                let v = out.pop().unwrap();
                let k = out.pop().unwrap();
                let h = out.pop().unwrap();
                Ok((h, k, v))
            }
            LayerExec::Split => {
                // attention block (migratable module #1)
                let mut args = vec![hidden, pos.clone()];
                args.extend(self.weight_lits(layer, &["rms1", "wq", "wk", "wv", "wo"])?);
                let mut out = self
                    .pjrt
                    .execute(&format!("{}__attn_prefill__b{b}_s{s}", self.name), &args)?;
                anyhow::ensure!(out.len() == 3);
                let v = out.pop().unwrap();
                let k = out.pop().unwrap();
                let mid = out.pop().unwrap();
                // FFN block (migratable module #2)
                let mut args = vec![mid];
                args.extend(self.weight_lits(
                    layer,
                    &["rms2", "w_gate", "w_up", "w_down"],
                )?);
                let out = self
                    .pjrt
                    .execute(&format!("{}__ffn_prefill__b{b}_s{s}", self.name), &args)?;
                Ok((out.into_iter().next().unwrap(), k, v))
            }
        }
    }

    /// Prefill with the batch *split across `degree` replicas* (Fig. 4):
    /// each share executes the same layer artifacts independently (on its
    /// own replica in a real cluster); results are gathered in order.
    /// Token-identical to `prefill` — the semantic-preservation contract.
    pub fn prefill_replicated(
        &self,
        seqs: &mut [&mut SeqState],
        degree: usize,
    ) -> Result<Vec<i32>> {
        anyhow::ensure!(degree >= 1);
        let shares = split_batch(seqs.len(), degree);
        let mut produced = Vec::with_capacity(seqs.len());
        let mut off = 0;
        let mut rest = seqs;
        for share in shares {
            if share == 0 {
                continue;
            }
            let (head, tail) = rest.split_at_mut(share);
            produced.extend(self.prefill(head)?);
            rest = tail;
            off += share;
        }
        let _ = off;
        Ok(produced)
    }

    // ---- decode ----------------------------------------------------------------

    /// One decode iteration over a batch; appends one token per sequence.
    pub fn decode(&self, seqs: &mut [&mut SeqState]) -> Result<Vec<i32>> {
        anyhow::ensure!(!seqs.is_empty());
        let n = seqs.len();
        let b = self
            .manifest()
            .batch_bucket(n)
            .ok_or_else(|| anyhow!("batch {n} exceeds buckets"))?;
        for s in seqs.iter() {
            anyhow::ensure!(
                s.kv_len < self.max_seq,
                "sequence {} exceeds max_seq {}",
                s.id,
                self.max_seq
            );
        }

        let mut toks = vec![0i32; b];
        let mut lens = vec![0i32; b];
        for (i, s) in seqs.iter().enumerate() {
            toks[i] = *s.tokens.last().unwrap();
            lens[i] = s.kv_len as i32;
        }
        let mut hidden = self
            .pjrt
            .execute(
                &format!("{}__embed_decode__b{b}", self.name),
                &[
                    self.pjrt.lit_i32(&toks, &[b, 1])?,
                    self.cached_lit("emb")?,
                ],
            )?
            .into_iter()
            .next()
            .unwrap();
        let lens_lit = self.pjrt.lit_i32(&lens, &[b])?;

        for layer in 0..self.cfg.n_layers {
            let (kc, vc) = {
                let seq_refs: Vec<&SeqState> =
                    seqs.iter().map(|s| &**s).collect();
                (
                    self.kv_literal(&seq_refs, b, layer, true)?,
                    self.kv_literal(&seq_refs, b, layer, false)?,
                )
            };
            let (h2, k_new, v_new) = match self.exec {
                LayerExec::Fused => {
                    let mut args = vec![hidden, kc, vc, lens_lit.clone()];
                    args.extend(self.weight_lits(
                        layer,
                        &crate::runtime::weights::LAYER_WEIGHT_NAMES,
                    )?);
                    let mut out = self
                        .pjrt
                        .execute(&format!("{}__layer_decode__b{b}", self.name), &args)?;
                    anyhow::ensure!(out.len() == 3);
                    let v = out.pop().unwrap();
                    let k = out.pop().unwrap();
                    (out.pop().unwrap(), k, v)
                }
                LayerExec::Split => {
                    let mut args = vec![hidden, kc, vc, lens_lit.clone()];
                    args.extend(
                        self.weight_lits(layer, &["rms1", "wq", "wk", "wv", "wo"])?,
                    );
                    let mut out = self
                        .pjrt
                        .execute(&format!("{}__attn_decode__b{b}", self.name), &args)?;
                    let v = out.pop().unwrap();
                    let k = out.pop().unwrap();
                    let mid = out.pop().unwrap();
                    let mut args = vec![mid];
                    args.extend(self.weight_lits(
                        layer,
                        &["rms2", "w_gate", "w_up", "w_down"],
                    )?);
                    let out = self
                        .pjrt
                        .execute(&format!("{}__ffn_decode__b{b}", self.name), &args)?;
                    (out.into_iter().next().unwrap(), k, v)
                }
            };
            hidden = h2;
            // write the new K/V row into host caches at position kv_len
            let kn: Vec<f32> = k_new.to_vec()?;
            let vn: Vec<f32> = v_new.to_vec()?;
            let hd = self.cfg.head_dim();
            let h = self.cfg.n_heads;
            for (i, seq) in seqs.iter_mut().enumerate() {
                let t = seq.kv_len;
                for head in 0..h {
                    let src = (i * h + head) * hd;
                    let dst = (head * self.max_seq + t) * hd;
                    seq.k[layer][dst..dst + hd].copy_from_slice(&kn[src..src + hd]);
                    seq.v[layer][dst..dst + hd].copy_from_slice(&vn[src..src + hd]);
                }
            }
        }

        let out = self.pjrt.execute(
            &format!("{}__lm_head_decode__b{b}", self.name),
            &[
                hidden,
                self.cached_lit("rms_f")?,
                self.cached_lit("w_out")?,
            ],
        )?;
        let next: Vec<i32> = out[0].to_vec()?;
        let mut produced = Vec::with_capacity(n);
        for (i, seq) in seqs.iter_mut().enumerate() {
            seq.kv_len += 1;
            seq.tokens.push(next[i]);
            produced.push(next[i]);
        }
        Ok(produced)
    }

    /// Greedy generation: prefill once, then decode `n_new − 1` iterations.
    pub fn generate_greedy(&self, prompts: &[Vec<i32>], n_new: usize) -> Result<Vec<Vec<i32>>> {
        let mut seqs: Vec<SeqState> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| self.new_sequence(i as u64, p))
            .collect();
        {
            let mut refs: Vec<&mut SeqState> = seqs.iter_mut().collect();
            self.prefill(&mut refs)?;
        }
        for _ in 1..n_new {
            let mut refs: Vec<&mut SeqState> = seqs.iter_mut().collect();
            self.decode(&mut refs)?;
        }
        Ok(seqs.into_iter().map(|s| s.tokens).collect())
    }
}
