//! Fig. 10 / §6.3 — multi-instance: CoCoServe×2 vs HFT×2 vs HFT×4.
//!
//! Paper claims (shape): CoCo×2 beats HFT×2 (−14%/−27% latency low/high
//! load, +17%/+39% throughput); HFT×4 beats CoCo×2 but only modestly
//! (≈11–16% latency) while using ~2× the memory — CoCo×2 delivers ≈90% of
//! HFT×4 at 53.5% of its footprint (the 46% cost-reduction claim).

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const LOW_RPS: [f64; 2] = [10.0, 25.0];
const HIGH_RPS: [f64; 2] = [35.0, 50.0];

fn run(n: usize, policy: SimPolicy, rps: f64) -> (f64, f64, f64) {
    let cfg = SimConfig::paper_13b();
    let placements: Vec<_> = (0..n)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i % 4), policy))
        .collect();
    let sim = Simulation::new(cfg, Cluster::paper_testbed(), placements);
    let trace = Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), 20.0, 13);
    let r = sim.run(&trace, 20.0);
    (
        r.merged_latency().mean(),
        r.total_throughput_tps(),
        r.peak_mem_bytes / GIB,
    )
}

fn main() {
    println!("Fig. 10 — multi-instance (13B on 4×A100)\n");
    let mut t = Table::new(&["rps", "hft×2 lat", "hft×4 lat", "coco×2 lat",
                             "hft×2 thr", "hft×4 thr", "coco×2 thr"]);
    let mut rep = Report::new("fig10_multi_instance");
    let mut mem = (0.0f64, 0.0f64, 0.0f64);
    let mut last_ratio = (0.0, 0.0);
    for &rps in LOW_RPS.iter().chain(&HIGH_RPS) {
        let (l2, t2, m2) = run(2, baselines::hft(16), rps);
        let (l4, t4, m4) = run(4, baselines::hft(16), rps);
        let (lc, tc, mc) = run(2, baselines::cocoserve(64), rps);
        mem = (mem.0.max(m2), mem.1.max(m4), mem.2.max(mc));
        t.row(&[
            format!("{rps:.0}"),
            format!("{l2:.2}"),
            format!("{l4:.2}"),
            format!("{lc:.2}"),
            format!("{t2:.0}"),
            format!("{t4:.0}"),
            format!("{tc:.0}"),
        ]);
        last_ratio = (tc / t4, lc / l2);
        rep.set(
            &format!("rps{}", rps as u64),
            json::arr([l2, l4, lc, t2, t4, tc].into_iter().map(json::num)),
        );
    }
    t.print();
    println!(
        "\npeak memory: HFT×2 {:.1} GiB · HFT×4 {:.1} GiB · CoCo×2 {:.1} GiB \
         → CoCo×2 = {:.1}% of HFT×4 (paper: 53.5%)",
        mem.0,
        mem.1,
        mem.2,
        mem.2 / mem.1 * 100.0
    );
    println!(
        "at the highest load CoCo×2 reaches {:.0}% of HFT×4 throughput \
         (paper: ≈90%) with {:.0}% of HFT×2's latency",
        last_ratio.0 * 100.0,
        last_ratio.1 * 100.0
    );
    rep.set("peak_mem_gib", json::arr([mem.0, mem.1, mem.2].into_iter().map(json::num)));
    println!("report: {}", rep.write().unwrap().display());
}
