//! Fig. 9 — memory utilization / fragmentation comparison.
//!
//! Paper claims: CoCoServe wastes 5.3 GB less than HFT and 3.2 GB less than
//! vLLM on a 40 GB A100; fragmentation reduced 3.12× vs HFT and 2.28× vs
//! vLLM; 37.5 GB effectively usable for serving.
//!
//! Mechanisms reproduced: HFT's contiguous max-length KV reservation wastes
//! (max_len − actual) per sequence; vLLM's paged allocator wastes only
//! partial blocks but cannot use the fragments *across* devices; CoCoServe
//! pages *and* harvests cross-device fragments via module placement.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn run(policy: SimPolicy, devices: usize) -> (f64, f64, f64) {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(devices, DeviceSpec::a100_40gb());
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
    let trace = Trace::generate(
        Arrival::Poisson { rps: 30.0 },
        LengthDist::alpaca(),
        20.0,
        9,
    );
    let r = sim.run(&trace, 20.0);
    let kv = r.kv_stats[0];
    (
        kv.waste_bytes() / GIB,
        kv.fragmentation(),
        r.peak_mem_bytes / GIB,
    )
}

fn main() {
    println!("Fig. 9 — KV memory waste & fragmentation (13B @ 30 RPS)\n");
    let mut t = Table::new(&["system", "kv waste (GiB)", "fragmentation",
                             "peak resident (GiB)"]);
    let mut rep = Report::new("fig9_memory");
    let mut rows = vec![];
    for (name, policy) in [
        ("HFT (contiguous)", baselines::hft(16)),
        ("vLLM (paged)", baselines::vllm_like(64)),
        ("CoCoServe", baselines::cocoserve(64)),
    ] {
        let (waste, frag, peak) = run(policy, 4);
        t.row(&[
            name.to_string(),
            format!("{waste:.2}"),
            format!("{frag:.2}"),
            format!("{peak:.2}"),
        ]);
        rep.set(name, json::arr([waste, frag, peak].into_iter().map(json::num)));
        rows.push((name, waste, frag, peak));
    }
    t.print();
    let (_, hft_w, hft_f, _) = rows[0];
    let (_, _, _, vllm_peak) = rows[1];
    let (_, coco_w, coco_f, coco_peak) = rows[2];
    // vs vLLM the win is not allocator waste (both page) but *idle-fragment
    // harvesting*: vLLM's instance-level scaling strands the other devices'
    // free memory; CoCoServe's module replication puts it to work.
    let harvested = coco_peak - vllm_peak;
    println!(
        "\nallocator waste: CoCoServe {:.1} GiB below HFT (paper: 5.3 GB); \
         fragmentation improves {:.2}× vs HFT (paper: 3.12×).\n\
         idle-memory harvesting vs vLLM: CoCoServe puts {harvested:.1} GiB \
         of otherwise-stranded cross-device memory to work as layer \
         replicas (the paper's 3.2 GB effective-memory edge, amplified \
         here by 3 idle devices).",
        hft_w - coco_w,
        hft_f / coco_f
    );
    println!("report: {}", rep.write().unwrap().display());
}
