//! Simulated device cluster — the testbed substitute (DESIGN.md
//! §Substitutions).
//!
//! The paper ran on 4× NVIDIA A100-40GB PCIe. We model each accelerator as a
//! resource ledger: memory capacity with explicit allocation/OOM semantics,
//! a compute capacity used by the event simulator's roofline latency model,
//! link bandwidth for replication/migration transfers, and busy-time
//! accounting from which the monitor derives utilization — the same signals
//! NVML gave the paper's monitor.

pub mod shadow;

use std::collections::BTreeMap;

use crate::model::cost::MIB;

pub use shadow::ShadowLedger;

/// One gibibyte in bytes.
pub const GIB: f64 = 1024.0 * MIB;
/// 10¹² floating-point operations per second.
pub const TFLOPS: f64 = 1e12;

/// Static description of a device type.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Human-readable device name (e.g. "A100-40GB").
    pub name: String,
    /// Total device memory in bytes.
    pub mem_bytes: f64,
    /// Dense matmul throughput (FLOPs/s) at serving precision.
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s) — the decode-roofline denominator.
    pub hbm_bw: f64,
    /// Device-to-device link bandwidth (bytes/s) for module transfers.
    pub link_bw: f64,
    /// Achievable fraction of peak on serving GEMMs (MFU).
    pub mfu: f64,
    /// Spot/preemptible capacity: the provider may reclaim this device at
    /// any instant (a [`crate::workload::scenarios::FailureSchedule`]
    /// decides when). On-demand devices never preempt, though hardware
    /// failure can still be injected explicitly.
    pub preemptible: bool,
}

impl DeviceSpec {
    /// NVIDIA A100-40GB PCIe, the paper's testbed device. `link_bw` is
    /// calibrated so the Table 2 replication times reproduce (≈100 GB/s
    /// effective pinned-P2P, see `ops::cost`); MFU 0.45 is a typical
    /// serving-GEMM efficiency.
    pub fn a100_40gb() -> DeviceSpec {
        DeviceSpec {
            name: "A100-40GB".into(),
            mem_bytes: 40.0 * GIB,
            peak_flops: 312.0 * TFLOPS,
            hbm_bw: 1.555e12,
            link_bw: 100.0e9,
            mfu: 0.45,
            preemptible: false,
        }
    }

    /// NVIDIA H100-80GB PCIe — the newer FLOPs/HBM generation for
    /// heterogeneous-fleet experiments: ~2.4× the dense bf16 throughput
    /// and ~1.3× the HBM bandwidth of the A100 testbed device.
    pub fn h100_80gb() -> DeviceSpec {
        DeviceSpec {
            name: "H100-80GB".into(),
            mem_bytes: 80.0 * GIB,
            peak_flops: 756.0 * TFLOPS,
            hbm_bw: 2.0e12,
            link_bw: 128.0e9,
            mfu: 0.45,
            preemptible: false,
        }
    }

    /// NVIDIA V100-32GB — the older generation: slower GEMMs, slower HBM,
    /// half-speed links. The cheap long-tail capacity a mixed fleet
    /// back-fills with.
    pub fn v100_32gb() -> DeviceSpec {
        DeviceSpec {
            name: "V100-32GB".into(),
            mem_bytes: 32.0 * GIB,
            peak_flops: 112.0 * TFLOPS,
            hbm_bw: 0.9e12,
            link_bw: 50.0e9,
            mfu: 0.40,
            preemptible: false,
        }
    }

    /// The same device sold as spot/preemptible capacity.
    pub fn spot(mut self) -> DeviceSpec {
        self.preemptible = true;
        self
    }

    /// Effective sustained GEMM throughput.
    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.mfu
    }
}

/// Why an allocation was refused.
#[derive(Debug, Clone, PartialEq)]
pub enum AllocError {
    /// The device lacked the requested free bytes (an OOM event was
    /// recorded on its ledger).
    Oom {
        /// Device whose ledger refused the allocation.
        device: usize,
        /// Requested size, in MiB.
        requested_mib: f64,
        /// Free bytes at refusal time, in MiB.
        free_mib: f64,
    },
    /// `free`/`resize` named a tag the ledger does not hold.
    UnknownTag(String),
    /// The device has failed (preempted or lost): no allocation can ever
    /// succeed on it again. Distinct from OOM so recovery paths and the
    /// audit trail can tell "no room" from "no device".
    DeviceFailed {
        /// The dead device.
        device: usize,
    },
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Oom { device, requested_mib, free_mib } => write!(
                f,
                "device {device} OOM: requested {requested_mib:.1} MiB, free {free_mib:.1} MiB"
            ),
            AllocError::UnknownTag(tag) => write!(f, "unknown allocation tag `{tag}`"),
            AllocError::DeviceFailed { device } => {
                write!(f, "device {device} has failed")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// One device's ledger: tagged allocations + busy-time accounting.
#[derive(Debug, Clone)]
pub struct Device {
    /// Cluster-wide device index.
    pub id: usize,
    /// Static hardware description.
    pub spec: DeviceSpec,
    /// Tagged allocations (tag -> bytes), e.g. "inst0/layers.3.weights".
    allocs: BTreeMap<String, f64>,
    used: f64,
    /// High-water mark of `used` over the device's lifetime — the capacity
    /// invariant the simulator's property tests assert (peak ≤ capacity).
    peak_used: f64,
    /// Total busy seconds (simulated) — utilization numerator.
    busy_s: f64,
    /// Monotone per-device OOM event counter (Fig. 11a).
    pub oom_events: u64,
    /// Has this device failed (preemption or hardware loss)? A failed
    /// device holds no memory, accepts no allocation, and reports zero
    /// vacancy, so every placement/routing filter skips it.
    failed: bool,
}

impl Device {
    /// An empty ledger for one device of the given spec.
    pub fn new(id: usize, spec: DeviceSpec) -> Device {
        Device {
            id,
            spec,
            allocs: BTreeMap::new(),
            used: 0.0,
            peak_used: 0.0,
            busy_s: 0.0,
            oom_events: 0,
            failed: false,
        }
    }

    /// Kill this device: every resident allocation vanishes (the memory
    /// physically no longer exists), and all future allocations are
    /// refused with [`AllocError::DeviceFailed`]. Returns the bytes that
    /// were resident at the failure instant (for the audit trail).
    /// Idempotent — failing a dead device frees nothing.
    pub fn fail(&mut self) -> f64 {
        let lost = self.used;
        self.allocs.clear();
        self.used = 0.0;
        self.failed = true;
        lost
    }

    /// Has this device failed?
    pub fn is_failed(&self) -> bool {
        self.failed
    }

    /// Bytes currently resident on this device.
    pub fn used_bytes(&self) -> f64 {
        self.used
    }

    /// Peak bytes ever resident on this device.
    pub fn peak_used_bytes(&self) -> f64 {
        self.peak_used
    }

    /// Bytes still allocatable (zero once failed).
    pub fn free_bytes(&self) -> f64 {
        if self.failed {
            return 0.0;
        }
        (self.spec.mem_bytes - self.used).max(0.0)
    }

    /// Fraction of device memory in use. A failed device reports fully
    /// used: it can host nothing, so every headroom consumer (vacancy
    /// filters, spin-up candidates, transfer-time contention) must see no
    /// room rather than a freshly emptied ledger.
    pub fn mem_frac(&self) -> f64 {
        if self.failed {
            return 1.0;
        }
        self.used / self.spec.mem_bytes
    }

    /// §4.1 `GetEligibleNodes` filter signal: fraction of memory vacant.
    pub fn vacancy_rate(&self) -> f64 {
        1.0 - self.mem_frac()
    }

    /// Allocate `bytes` under `tag`, or record an OOM event and fail.
    /// Refused outright (no OOM event — the device is gone, not full) once
    /// the device has failed.
    pub fn alloc(&mut self, tag: &str, bytes: f64) -> Result<(), AllocError> {
        debug_assert!(bytes >= 0.0);
        if self.failed {
            return Err(AllocError::DeviceFailed { device: self.id });
        }
        if bytes > self.free_bytes() {
            self.oom_events += 1;
            return Err(AllocError::Oom {
                device: self.id,
                requested_mib: bytes / MIB,
                free_mib: self.free_bytes() / MIB,
            });
        }
        *self.allocs.entry(tag.to_string()).or_insert(0.0) += bytes;
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        Ok(())
    }

    /// Free the whole allocation under `tag`.
    pub fn free(&mut self, tag: &str) -> Result<f64, AllocError> {
        match self.allocs.remove(tag) {
            Some(b) => {
                self.used = (self.used - b).max(0.0);
                Ok(b)
            }
            None => Err(AllocError::UnknownTag(tag.to_string())),
        }
    }

    /// Shrink/grow an existing tag to an exact size (KV caches grow).
    /// Refused once the device has failed — there is nothing to resize.
    pub fn resize(&mut self, tag: &str, new_bytes: f64) -> Result<(), AllocError> {
        if self.failed {
            return Err(AllocError::DeviceFailed { device: self.id });
        }
        let cur = self.allocs.get(tag).copied().unwrap_or(0.0);
        if new_bytes > cur && new_bytes - cur > self.free_bytes() {
            self.oom_events += 1;
            return Err(AllocError::Oom {
                device: self.id,
                requested_mib: (new_bytes - cur) / MIB,
                free_mib: self.free_bytes() / MIB,
            });
        }
        self.used += new_bytes - cur;
        self.peak_used = self.peak_used.max(self.used);
        if new_bytes == 0.0 {
            self.allocs.remove(tag);
        } else {
            self.allocs.insert(tag.to_string(), new_bytes);
        }
        Ok(())
    }

    /// Restore `tag` to an exact previously-observed size, bypassing the
    /// OOM check — the plan executor's rollback primitive. Rollback
    /// re-establishes a state that *was* valid (it only ever shrinks
    /// plan-made allocations back), so it must be infallible. `used` is
    /// adjusted incrementally — the exact inverse of the `alloc` that is
    /// being undone — rather than re-summed, so the restored value stays
    /// in the same accumulation regime as the rest of the ledger.
    ///
    /// A **failed** device makes this a no-op: rollback must never
    /// re-acquire memory on a device that no longer exists — the failure
    /// already released every byte, and the undo log's view of the device
    /// predates its death.
    pub(crate) fn restore_alloc(&mut self, tag: &str, prev_bytes: f64) {
        if self.failed {
            return;
        }
        let cur = self.allocs.get(tag).copied().unwrap_or(0.0);
        if prev_bytes == 0.0 {
            self.allocs.remove(tag);
        } else {
            self.allocs.insert(tag.to_string(), prev_bytes);
        }
        self.used = (self.used + prev_bytes - cur).max(0.0);
    }

    /// Current bytes under `tag` (0.0 when absent).
    pub fn alloc_bytes(&self, tag: &str) -> f64 {
        self.allocs.get(tag).copied().unwrap_or(0.0)
    }

    /// Is an allocation entry present under `tag` (even at zero bytes)?
    pub fn has_alloc(&self, tag: &str) -> bool {
        self.allocs.contains_key(tag)
    }

    /// Every tagged allocation on this device, in tag order.
    pub fn allocations(&self) -> impl Iterator<Item = (&str, f64)> {
        self.allocs.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Record simulated busy time (the simulator calls this per event).
    pub fn add_busy(&mut self, seconds: f64) {
        self.busy_s += seconds;
    }

    /// Total simulated busy seconds recorded so far.
    pub fn busy_seconds(&self) -> f64 {
        self.busy_s
    }

    /// Compute utilization over a window of `wall_s` simulated seconds.
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            0.0
        } else {
            (self.busy_s / wall_s).min(1.0)
        }
    }
}

/// Read-only memory-ledger view: everything the pure planners and the
/// plan costing need to observe about a cluster. Implemented by
/// [`Cluster`] (the live ledgers) and [`ShadowLedger`] (a copy-on-write
/// overlay), so planning and execution observe state through one
/// interface and therefore price operations identically — the Table 2
/// dry-run == executed parity contract.
///
/// The default implementations mirror [`Device`]'s formulas exactly;
/// implementors must keep `used_bytes`/`mem_bytes` in the same
/// accumulation regime as the live ledger so derived fractions stay
/// bit-identical.
pub trait LedgerView {
    /// Number of devices in the cluster.
    fn n(&self) -> usize;
    /// Bytes currently resident on `device`.
    fn used_bytes(&self, device: usize) -> f64;
    /// Device memory capacity in bytes.
    fn mem_bytes(&self, device: usize) -> f64;
    /// Link bandwidth between two devices (bytes/s).
    fn link_bw(&self, a: usize, b: usize) -> f64;
    /// Current bytes under `tag` on `device` (0.0 when absent).
    fn alloc_bytes(&self, device: usize, tag: &str) -> f64;

    /// Bytes still allocatable on `device`.
    fn free_bytes(&self, device: usize) -> f64 {
        (self.mem_bytes(device) - self.used_bytes(device)).max(0.0)
    }

    /// Fraction of `device`'s memory in use.
    fn mem_frac(&self, device: usize) -> f64 {
        self.used_bytes(device) / self.mem_bytes(device)
    }

    /// §4.1 `GetEligibleNodes` filter signal: fraction of memory vacant.
    fn vacancy_rate(&self, device: usize) -> f64 {
        1.0 - self.mem_frac(device)
    }

    /// Devices sorted by descending free memory (placement preference).
    fn by_free_memory(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..self.n()).collect();
        ids.sort_by(|&a, &b| {
            self.free_bytes(b).partial_cmp(&self.free_bytes(a)).unwrap()
        });
        ids
    }

    /// §4.1 `GetEligibleNodes`: devices whose vacancy rate ≥ threshold,
    /// most-vacant first.
    fn eligible_nodes(&self, min_vacancy: f64) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.n())
            .filter(|&i| self.vacancy_rate(i) >= min_vacancy)
            .collect();
        v.sort_by(|&a, &b| {
            self.vacancy_rate(b).partial_cmp(&self.vacancy_rate(a)).unwrap()
        });
        v
    }
}

/// A [`LedgerView`] that can also be mutated — the interface
/// [`crate::ops::PlanExecution`] drives, live ([`Cluster`]) or shadowed
/// ([`ShadowLedger`]). `restore_alloc` is the rollback primitive: it
/// re-establishes a previously observed tag size bypassing the OOM check
/// (rollback only ever shrinks plan-made allocations back).
pub trait Ledger: LedgerView {
    /// Allocate `bytes` under `tag` on `device`, or fail with OOM.
    fn alloc(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError>;
    /// Free the whole allocation under `tag`, returning its size.
    fn free(&mut self, device: usize, tag: &str) -> Result<f64, AllocError>;
    /// Shrink/grow an existing tag to an exact size.
    fn resize(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError>;
    /// Restore `tag` to a previously observed size, bypassing the OOM
    /// check (the rollback primitive — see the trait docs).
    fn restore_alloc(&mut self, device: usize, tag: &str, prev_bytes: f64);
}

/// The cluster: a set of devices plus the interconnect description.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Per-device ledgers, indexed by device id.
    pub devices: Vec<Device>,
}

impl Cluster {
    /// `n` identical devices of the given spec.
    pub fn homogeneous(n: usize, spec: DeviceSpec) -> Cluster {
        Cluster { devices: (0..n).map(|i| Device::new(i, spec.clone())).collect() }
    }

    /// The paper's testbed: 4× A100-40GB.
    pub fn paper_testbed() -> Cluster {
        Cluster::homogeneous(4, DeviceSpec::a100_40gb())
    }

    /// A heterogeneous cluster: one device per spec, in order. The
    /// failure-domain experiments mix generations (and spot capacity)
    /// through this constructor; [`Cluster::homogeneous`] stays the
    /// byte-identical legacy path.
    pub fn mixed(specs: Vec<DeviceSpec>) -> Cluster {
        Cluster {
            devices: specs
                .into_iter()
                .enumerate()
                .map(|(i, s)| Device::new(i, s))
                .collect(),
        }
    }

    /// Device ids sold as spot/preemptible capacity (failure-schedule
    /// targets).
    pub fn preemptible_devices(&self) -> Vec<usize> {
        self.devices
            .iter()
            .filter(|d| d.spec.preemptible)
            .map(|d| d.id)
            .collect()
    }

    /// Device ids that have not failed.
    pub fn live_devices(&self) -> Vec<usize> {
        self.devices
            .iter()
            .filter(|d| !d.is_failed())
            .map(|d| d.id)
            .collect()
    }

    /// Number of devices.
    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Borrow one device's ledger.
    pub fn device(&self, id: usize) -> &Device {
        &self.devices[id]
    }

    /// Mutably borrow one device's ledger.
    pub fn device_mut(&mut self, id: usize) -> &mut Device {
        &mut self.devices[id]
    }

    /// Link bandwidth between two devices (min of endpoints' links).
    pub fn link_bw(&self, a: usize, b: usize) -> f64 {
        self.devices[a].spec.link_bw.min(self.devices[b].spec.link_bw)
    }

    /// Devices sorted by descending free memory (placement preference).
    pub fn by_free_memory(&self) -> Vec<usize> {
        LedgerView::by_free_memory(self)
    }

    /// §4.1 `GetEligibleNodes`: devices whose vacancy rate ≥ threshold.
    /// Most-vacant first, so replicas land where the most room is.
    pub fn eligible_nodes(&self, min_vacancy: f64) -> Vec<usize> {
        LedgerView::eligible_nodes(self, min_vacancy)
    }

    /// Bytes resident across the whole cluster.
    pub fn total_used_bytes(&self) -> f64 {
        self.devices.iter().map(|d| d.used_bytes()).sum()
    }

    /// OOM events recorded across every device ledger.
    pub fn total_oom_events(&self) -> u64 {
        self.devices.iter().map(|d| d.oom_events).sum()
    }
}

impl LedgerView for Cluster {
    fn n(&self) -> usize {
        self.devices.len()
    }

    fn used_bytes(&self, device: usize) -> f64 {
        self.devices[device].used_bytes()
    }

    fn mem_bytes(&self, device: usize) -> f64 {
        self.devices[device].spec.mem_bytes
    }

    fn link_bw(&self, a: usize, b: usize) -> f64 {
        Cluster::link_bw(self, a, b)
    }

    fn alloc_bytes(&self, device: usize, tag: &str) -> f64 {
        self.devices[device].alloc_bytes(tag)
    }

    fn free_bytes(&self, device: usize) -> f64 {
        self.devices[device].free_bytes()
    }

    fn mem_frac(&self, device: usize) -> f64 {
        self.devices[device].mem_frac()
    }

    fn vacancy_rate(&self, device: usize) -> f64 {
        self.devices[device].vacancy_rate()
    }
}

impl Ledger for Cluster {
    fn alloc(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError> {
        self.devices[device].alloc(tag, bytes)
    }

    fn free(&mut self, device: usize, tag: &str) -> Result<f64, AllocError> {
        self.devices[device].free(tag)
    }

    fn resize(&mut self, device: usize, tag: &str, bytes: f64) -> Result<(), AllocError> {
        self.devices[device].resize(tag, bytes)
    }

    fn restore_alloc(&mut self, device: usize, tag: &str, prev_bytes: f64) {
        self.devices[device].restore_alloc(tag, prev_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_spec_sane() {
        let s = DeviceSpec::a100_40gb();
        assert_eq!(s.mem_bytes, 40.0 * GIB);
        assert!(s.effective_flops() < s.peak_flops);
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.alloc("w", 10.0 * GIB).unwrap();
        assert_eq!(d.used_bytes(), 10.0 * GIB);
        assert_eq!(d.free("w").unwrap(), 10.0 * GIB);
        assert_eq!(d.used_bytes(), 0.0);
        assert!(d.free("w").is_err());
    }

    #[test]
    fn oom_counted_and_rejected() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.alloc("a", 39.0 * GIB).unwrap();
        let e = d.alloc("b", 2.0 * GIB);
        assert!(matches!(e, Err(AllocError::Oom { .. })));
        assert_eq!(d.oom_events, 1);
        // ledger unchanged on failure
        assert_eq!(d.used_bytes(), 39.0 * GIB);
    }

    #[test]
    fn resize_grows_and_shrinks() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.alloc("kv", 1.0 * GIB).unwrap();
        d.resize("kv", 3.0 * GIB).unwrap();
        assert_eq!(d.used_bytes(), 3.0 * GIB);
        d.resize("kv", 0.5 * GIB).unwrap();
        assert_eq!(d.used_bytes(), 0.5 * GIB);
        d.resize("kv", 0.0).unwrap();
        assert_eq!(d.alloc_bytes("kv"), 0.0);
    }

    #[test]
    fn resize_respects_capacity() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.alloc("kv", 1.0 * GIB).unwrap();
        assert!(d.resize("kv", 45.0 * GIB).is_err());
        assert_eq!(d.oom_events, 1);
        assert_eq!(d.alloc_bytes("kv"), 1.0 * GIB);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.alloc("a", 10.0 * GIB).unwrap();
        d.alloc("b", 5.0 * GIB).unwrap();
        d.free("a").unwrap();
        assert_eq!(d.used_bytes(), 5.0 * GIB);
        assert_eq!(d.peak_used_bytes(), 15.0 * GIB);
        d.resize("b", 12.0 * GIB).unwrap();
        assert_eq!(d.peak_used_bytes(), 15.0 * GIB);
        d.resize("b", 20.0 * GIB).unwrap();
        assert_eq!(d.peak_used_bytes(), 20.0 * GIB);
        assert!(d.peak_used_bytes() <= d.spec.mem_bytes);
    }

    #[test]
    fn utilization_from_busy_time() {
        let mut d = Device::new(0, DeviceSpec::a100_40gb());
        d.add_busy(2.5);
        assert!((d.utilization(10.0) - 0.25).abs() < 1e-12);
        assert_eq!(d.utilization(0.0), 0.0);
        d.add_busy(100.0);
        assert_eq!(d.utilization(10.0), 1.0); // clamped
    }

    #[test]
    fn eligible_nodes_sorted_by_vacancy() {
        let mut c = Cluster::paper_testbed();
        c.device_mut(0).alloc("x", 30.0 * GIB).unwrap();
        c.device_mut(1).alloc("x", 10.0 * GIB).unwrap();
        let elig = c.eligible_nodes(0.5);
        assert!(!elig.contains(&0)); // only 25% vacant
        assert_eq!(elig[0], 2.min(3)); // fully-free devices first
        assert!(elig.contains(&1));
        assert_eq!(*elig.last().unwrap(), 1);
    }

    #[test]
    fn by_free_memory_order() {
        let mut c = Cluster::homogeneous(3, DeviceSpec::a100_40gb());
        c.device_mut(1).alloc("x", 5.0 * GIB).unwrap();
        c.device_mut(2).alloc("x", 20.0 * GIB).unwrap();
        assert_eq!(c.by_free_memory(), vec![0, 1, 2]);
    }

    #[test]
    fn mixed_cluster_carries_generations_and_spot_flags() {
        let c = Cluster::mixed(vec![
            DeviceSpec::a100_40gb(),
            DeviceSpec::h100_80gb(),
            DeviceSpec::v100_32gb().spot(),
        ]);
        assert_eq!(c.n(), 3);
        assert!(c.device(1).spec.effective_flops() > c.device(0).spec.effective_flops());
        assert!(c.device(2).spec.effective_flops() < c.device(0).spec.effective_flops());
        assert_eq!(c.preemptible_devices(), vec![2]);
        assert_eq!(c.live_devices(), vec![0, 1, 2]);
        // link bandwidth is the min of the endpoints' generations
        assert_eq!(c.link_bw(1, 2), c.device(2).spec.link_bw);
    }

    #[test]
    fn failed_device_releases_everything_and_refuses_all_work() {
        let mut d = Device::new(3, DeviceSpec::a100_40gb());
        d.alloc("w", 10.0 * GIB).unwrap();
        d.alloc("kv", 2.0 * GIB).unwrap();
        let lost = d.fail();
        assert_eq!(lost, 12.0 * GIB);
        assert!(d.is_failed());
        assert_eq!(d.used_bytes(), 0.0);
        assert_eq!(d.free_bytes(), 0.0, "a dead device has no headroom");
        assert_eq!(d.mem_frac(), 1.0);
        assert_eq!(d.vacancy_rate(), 0.0);
        // no allocation path works, and none records an OOM event
        assert!(matches!(d.alloc("x", 1.0), Err(AllocError::DeviceFailed { device: 3 })));
        assert!(matches!(d.resize("w", 1.0), Err(AllocError::DeviceFailed { device: 3 })));
        assert_eq!(d.oom_events, 0);
        // rollback never re-acquires on a dead device
        d.restore_alloc("w", 10.0 * GIB);
        assert_eq!(d.used_bytes(), 0.0);
        assert!(!d.has_alloc("w"));
        // idempotent
        assert_eq!(d.fail(), 0.0);
    }

    #[test]
    fn failed_device_drops_out_of_placement_filters() {
        let mut c = Cluster::paper_testbed();
        c.device_mut(1).fail();
        assert_eq!(c.live_devices(), vec![0, 2, 3]);
        assert!(!c.eligible_nodes(0.1).contains(&1));
        assert_eq!(*c.by_free_memory().last().unwrap(), 1);
    }
}
