//! Fleet control-plane contracts, tested through the public simulation API.
//!
//! * **Fleet golden replay** — the full fleet configuration (routing
//!   policies, admission backpressure, shed re-routing, instance
//!   spin-up/drain) must be byte-identically replayable per scenario and
//!   per routing policy, exactly like the fixed-fleet kernel.
//! * **Routing invariants** — every trace arrival is routed exactly once
//!   (the `routes` counter equals the trace length no matter how much
//!   backpressure parking happened), and conservation holds across
//!   OOM-shed re-routes: no request ever completes twice.
//! * **Lifecycle** — under burst pressure an elastic fleet spins new
//!   instances up, and the device-seconds bill stays strictly below the
//!   every-device-always-on ceiling.

use std::collections::BTreeSet;

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::coordinator::{FleetConfig, FleetPhase, RoutePolicy, RouterConfig};
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::workload::{Request, Trace};

fn run_fleet(
    n_seed: usize,
    n_devices: usize,
    policy: SimPolicy,
    setup: FleetSetup,
    trace: &Trace,
    duration_s: f64,
) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..n_seed)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % n_devices),
                policy,
            )
        })
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, setup).run(trace, duration_s)
}

fn elastic_setup(route: RoutePolicy, policy: SimPolicy) -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy: route,
            admission_limit: Some(64),
            reroute_on_shed: true,
        },
        fleet: Some(FleetConfig::elastic(2, 5, policy)),
        ..Default::default()
    }
}

/// Unique completed request ids across every monitor; panics on a
/// duplicate (a request that completed twice would break conservation).
fn completed_ids(r: &SimReport) -> BTreeSet<u64> {
    let mut seen = BTreeSet::new();
    for m in &r.monitors {
        for c in m.completions() {
            assert!(
                seen.insert(c.request_id),
                "request {} completed more than once",
                c.request_id
            );
        }
    }
    seen
}

#[test]
fn fleet_golden_replay_across_scenarios() {
    for (name, trace) in Trace::scenario_sweep(18.0, 12.0, 91) {
        let setup = elastic_setup(RoutePolicy::KvHeadroom, baselines::cocoserve(32));
        let a = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0);
        let b = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "fleet scenario `{name}` not replay-deterministic"
        );
        assert!(a.total_completed() > 0, "fleet scenario `{name}` served nothing");
    }
}

#[test]
fn fleet_golden_replay_holds_for_every_route_policy() {
    let trace = Trace::burst(20.0, 12.0, 17);
    for policy in [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstanding,
        RoutePolicy::KvHeadroom,
    ] {
        let setup = elastic_setup(policy, baselines::cocoserve(32));
        let a = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0)
            .to_json()
            .to_string();
        let b = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0)
            .to_json()
            .to_string();
        assert_eq!(a, b, "route policy {policy:?} not replay-deterministic");
    }
}

#[test]
fn every_arrival_is_routed_exactly_once() {
    // A tight admission limit forces the router to park requests; parked
    // requests are first-time routes when they finally deliver, so the
    // counter still comes out to exactly one route per arrival — and at
    // light load everything drains.
    let trace = Trace::steady(10.0, 12.0, 33);
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(4),
            reroute_on_shed: false,
        },
        ..Default::default()
    };
    let r = run_fleet(2, 2, baselines::vllm_like(16), setup, &trace, 12.0);
    assert_eq!(r.routes, trace.len() as u64, "each arrival routed exactly once");
    assert_eq!(r.reroutes, 0);
    let ids = completed_ids(&r);
    assert_eq!(ids.len(), trace.len(), "light load must fully drain");
    assert_eq!(r.total_completed(), trace.len());
}

#[test]
fn oom_shed_requests_reroute_without_double_completion() {
    // Memory-tight HFT fleet: FailBatch OOM handling sheds whole batches;
    // in fleet mode those requests go back through the router. Every
    // arrival is still routed exactly once as a first-time route, the
    // shed deliveries show up as reroutes, and no request completes on
    // two instances.
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::homogeneous(2, DeviceSpec::a100_40gb());
    for d in 0..2 {
        cluster.device_mut(d).alloc("co-tenant", 12.0 * GIB).unwrap();
    }
    let policy = baselines::hft(16);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: true,
        },
        ..Default::default()
    };
    let trace = Trace::burst(30.0, 15.0, 29);
    let r = Simulation::with_fleet(cfg, cluster, placements, setup).run(&trace, 15.0);
    assert_eq!(r.routes, trace.len() as u64, "first-time routes == arrivals");
    assert!(r.reroutes > 0, "memory-tight HFT fleet must shed and re-route");
    let ids = completed_ids(&r); // panics on any double completion
    assert!(ids.len() <= trace.len());
    assert!(
        r.total_completed() >= trace.len() * 8 / 10,
        "re-routing must keep most requests alive: {}/{}",
        r.total_completed(),
        trace.len()
    );
}

#[test]
fn burst_pressure_spins_instances_up_and_bills_less_than_static() {
    // Elastic fleet with module replication disabled (replica_budget 0):
    // the arbitration's only capacity option is whole-instance spin-up,
    // so burst pressure must produce SpinUp fleet events. The
    // device-seconds bill stays strictly below the every-device-always-on
    // ceiling that a static over-provisioned deployment would pay.
    let mut cfg = SimConfig::paper_13b();
    cfg.replica_budget = 0;
    let n_devices = 6;
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let policy = baselines::cocoserve_no_autoscale(32);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let mut fleet = FleetConfig::elastic(2, 6, policy);
    fleet.cooldown_ticks = 1;
    fleet.scale_out_queue = 12.0;
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: true,
        },
        fleet: Some(fleet),
        ..Default::default()
    };
    let trace = Trace::burst(30.0, 30.0, 57);
    let r = Simulation::with_fleet(cfg, cluster, placements, setup).run(&trace, 30.0);
    assert!(
        r.fleet_events.iter().any(|e| e.phase == FleetPhase::SpinUp),
        "burst pressure must spin up at least one instance: {:?}",
        r.fleet_events
    );
    let ceiling = n_devices as f64 * r.duration_s;
    assert!(
        r.device_seconds < ceiling,
        "elastic bill {} must undercut the static ceiling {}",
        r.device_seconds,
        ceiling
    );
    assert!(r.device_seconds > 0.0);
}

#[test]
fn a_single_request_trace_completes() {
    // Regression: delivery happens via a same-timestamp Routed event, so
    // the kernel must count routed-but-undelivered requests as live —
    // otherwise the run loop breaks before the lone arrival lands.
    let trace = Trace {
        requests: vec![Request {
            id: 0,
            arrival_s: 0.5,
            prompt_tokens: 16,
            output_tokens: 4,
        }],
    };
    let r = run_fleet(2, 2, baselines::vllm_like(16), FleetSetup::default(), &trace, 5.0);
    assert_eq!(r.total_completed(), 1, "the lone arrival must be delivered and served");
    assert_eq!(r.routes, 1);
}

#[test]
fn default_setup_reproduces_the_fixed_fleet_kernel() {
    // Simulation::new must behave exactly like with_fleet + defaults —
    // the legacy least-outstanding routing with no lifecycle management.
    let trace = Trace::steady(15.0, 10.0, 3);
    let cfg = SimConfig::paper_13b();
    let make_placements = |cfg: &SimConfig| {
        (0..2)
            .map(|i| {
                (
                    Placement::single_device(cfg.model.n_layers, i),
                    baselines::vllm_like(16),
                )
            })
            .collect::<Vec<_>>()
    };
    let a = Simulation::new(
        cfg.clone(),
        Cluster::homogeneous(2, DeviceSpec::a100_40gb()),
        make_placements(&cfg),
    )
    .run(&trace, 10.0);
    let b = Simulation::with_fleet(
        cfg.clone(),
        Cluster::homogeneous(2, DeviceSpec::a100_40gb()),
        make_placements(&cfg),
        FleetSetup::default(),
    )
    .run(&trace, 10.0);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert!(a.fleet_events.is_empty(), "no lifecycle events without a fleet config");
    assert_eq!(a.reroutes, 0);
}
