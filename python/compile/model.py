"""L2: the serving model as *per-module* jax functions (weights as arguments).

CoCoServe's contribution is module-level scaling: decoder layers, attention,
FFN, projections and KV caches are the units of replication and migration.
We mirror that in the compile path — every module kind below is lowered to
its own HLO artifact with **weights passed as runtime arguments**, so:

  * one compiled executable serves *any* layer (layer identity lives in the
    weight literals the Rust coordinator owns), and
  * replicating or migrating a module is moving bytes, never recompiling.

All functions are shape-static (PJRT requirement); the Rust scheduler pads
to the shape buckets in `configs.py`. Hot paths call the L1 Pallas kernels
(`flash_attention`, `fused_rmsnorm_matmul`); everything is f32 on the CPU
interpret path (bf16 is a TPU-only concern, see DESIGN.md).

Argument conventions (shared with rust/src/runtime via manifest.json):

  layer weights, in order: rms1[d], wq[d,d], wk[d,d], wv[d,d], wo[d,d],
                           rms2[d], w_gate[d,ff], w_up[d,ff], w_down[ff,d]
  seq_lens[b] i32 — tokens already cached per sequence (decode), or the
                    true (un-padded) prompt length (lm_head_prefill).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.flash_attention import flash_attention
from .kernels.fused_rmsnorm_matmul import fused_rmsnorm_matmul

LAYER_WEIGHT_NAMES = (
    "rms1", "wq", "wk", "wv", "wo", "rms2", "w_gate", "w_up", "w_down",
)


def layer_weight_shapes(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "rms1": (d,), "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "rms2": (d,), "w_gate": (d, ff), "w_up": (d, ff), "w_down": (ff, d),
    }


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------

def embed(tokens, emb_table):
    """tokens [b, s] i32, emb_table [vocab, d] -> hidden [b, s, d]."""
    return (emb_table[tokens],)


def lm_head_prefill(hidden, seq_lens, rms_f, w_out):
    """Greedy next token from the last *real* prompt position.

    hidden [b, s, d]; seq_lens [b] i32 (true prompt lengths; the last real
    token of sequence i sits at index seq_lens[i]-1). Returns
    (next_token [b] i32, logits [b, vocab]).
    """
    last = jnp.take_along_axis(
        hidden, (seq_lens - 1)[:, None, None], axis=1)  # [b, 1, d]
    x = ref.rmsnorm(last[:, 0, :], rms_f)
    logits = x @ w_out
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits


def lm_head_decode(hidden, rms_f, w_out):
    """hidden [b, 1, d] -> (next_token [b] i32, logits [b, vocab])."""
    x = ref.rmsnorm(hidden[:, 0, :], rms_f)
    logits = x @ w_out
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits


# --------------------------------------------------------------------------
# Sub-module building blocks (projection granularity — §3.3 migration units)
# --------------------------------------------------------------------------

def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def qkv_proj(hidden, positions, rms1, wq, wk, wv, *, n_heads):
    """RMSNorm + Q/K/V projections + RoPE. hidden [b,s,d], positions [b,s].

    Returns (q, k, v) each [b, h, s, hd]. Uses the fused rmsnorm-matmul
    Pallas kernel for the three projections.
    """
    q = fused_rmsnorm_matmul(hidden, rms1, wq)
    k = fused_rmsnorm_matmul(hidden, rms1, wk)
    v = fused_rmsnorm_matmul(hidden, rms1, wv)
    q = ref.rope(_split_heads(q, n_heads), positions)
    k = ref.rope(_split_heads(k, n_heads), positions)
    return q, k, _split_heads(v, n_heads)


def attn_core_prefill(q, k, v):
    """Causal flash attention over a prompt chunk -> [b, s, d] merged."""
    return (_merge_heads(flash_attention(q, k, v, causal=True)),)


def o_proj(hidden, attn_out, wo):
    """Output projection + residual add. hidden/attn_out [b, s, d]."""
    return (hidden + attn_out @ wo,)


def attn_prefill(hidden, positions, rms1, wq, wk, wv, wo, *, n_heads):
    """Whole attention block (prefill): returns (hidden', k, v)."""
    q, k, v = qkv_proj(hidden, positions, rms1, wq, wk, wv, n_heads=n_heads)
    (attn_out,) = attn_core_prefill(q, k, v)
    (hidden,) = o_proj(hidden, attn_out, wo)
    return hidden, k, v


def attn_decode(hidden, k_cache, v_cache, seq_lens,
                rms1, wq, wk, wv, wo, *, n_heads):
    """Whole attention block (one decode step).

    hidden [b,1,d]; k_cache/v_cache [b,h,S,hd]; seq_lens [b] i32 = number of
    cached tokens (new token lands at slot seq_lens[i]). Returns
    (hidden', k_new [b,h,hd], v_new [b,h,hd]) — the caller owns the cache
    and scatters k_new/v_new host-side; attention here sees the updated
    cache via an in-graph functional scatter (never shipped back out).
    """
    b, _, d = hidden.shape
    pos = seq_lens[:, None]
    q = ref.rope(_split_heads(
        fused_rmsnorm_matmul(hidden, rms1, wq), n_heads), pos)
    k = ref.rope(_split_heads(
        fused_rmsnorm_matmul(hidden, rms1, wk), n_heads), pos)
    v = _split_heads(fused_rmsnorm_matmul(hidden, rms1, wv), n_heads)

    bidx = jnp.arange(b)
    S = k_cache.shape[2]
    kc = k_cache.at[bidx, :, seq_lens, :].set(k[:, :, 0, :])
    vc = v_cache.at[bidx, :, seq_lens, :].set(v[:, :, 0, :])
    idx = jnp.arange(S)[None, None, None, :]
    mask = idx <= seq_lens[:, None, None, None]
    attn = ref.attention(q, kc, vc, mask)
    hidden = hidden + _merge_heads(attn) @ wo
    return hidden, k[:, :, 0, :], v[:, :, 0, :]


def ffn(hidden, rms2, w_gate, w_up, w_down):
    """SwiGLU FFN block with residual. hidden [b, s, d] (s may be 1)."""
    g = fused_rmsnorm_matmul(hidden, rms2, w_gate)
    u = fused_rmsnorm_matmul(hidden, rms2, w_up)
    silu = g * (1.0 / (1.0 + jnp.exp(-g)))
    return (hidden + (silu * u) @ w_down,)


# --------------------------------------------------------------------------
# Whole decoder layer (the paper's primary scaling unit)
# --------------------------------------------------------------------------

def layer_prefill(hidden, positions, rms1, wq, wk, wv, wo,
                  rms2, w_gate, w_up, w_down, *, n_heads):
    """Full decoder layer over a prompt chunk.

    Returns (hidden' [b,s,d], k [b,h,s,hd], v [b,h,s,hd]) — K/V handed to
    the coordinator, which owns cache placement (a migratable module).
    """
    hidden, k, v = attn_prefill(hidden, positions, rms1, wq, wk, wv, wo,
                                n_heads=n_heads)
    (hidden,) = ffn(hidden, rms2, w_gate, w_up, w_down)
    return hidden, k, v


def layer_decode(hidden, k_cache, v_cache, seq_lens, rms1, wq, wk, wv, wo,
                 rms2, w_gate, w_up, w_down, *, n_heads):
    """Full decoder layer, one decode step.

    Returns (hidden' [b,1,d], k_new [b,h,hd], v_new [b,h,hd]).
    """
    hidden, k_new, v_new = attn_decode(
        hidden, k_cache, v_cache, seq_lens, rms1, wq, wk, wv, wo,
        n_heads=n_heads)
    (hidden,) = ffn(hidden, rms2, w_gate, w_up, w_down)
    return hidden, k_new, v_new


# --------------------------------------------------------------------------
# Reference whole-model forward (pytest only — never lowered)
# --------------------------------------------------------------------------

def init_weights(cfg, seed: int = 0):
    """Deterministic synthetic weights, scaled for stable activations."""
    key = jax.random.PRNGKey(seed)
    shapes = layer_weight_shapes(cfg)
    layers = []
    for _ in range(cfg.n_layers):
        w = {}
        for name, shape in shapes.items():
            key, sub = jax.random.split(key)
            if name.startswith("rms"):
                w[name] = jnp.ones(shape, jnp.float32)
            else:
                fan_in = shape[0]
                w[name] = (jax.random.normal(sub, shape, jnp.float32)
                           / jnp.sqrt(jnp.float32(fan_in)))
        layers.append(w)
    key, k1, k2 = jax.random.split(key, 3)
    emb = jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), jnp.float32)
    w_out = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size), jnp.float32)
             / jnp.sqrt(jnp.float32(cfg.d_model)))
    rms_f = jnp.ones((cfg.d_model,), jnp.float32)
    return {"layers": layers, "emb": emb, "w_out": w_out, "rms_f": rms_f}


def forward_greedy(cfg, weights, tokens, n_new: int):
    """Greedy generation via the *reference* layer fns (oracle for the full
    Rust pipeline; see python/tests/test_model.py and the Rust integration
    test, which must produce identical token ids)."""
    toks = [list(t) for t in tokens]
    for _ in range(n_new):
        b = len(toks)
        max_len = max(len(t) for t in toks)
        ids = jnp.asarray(
            [t + [0] * (max_len - len(t)) for t in toks], jnp.int32)
        hidden = weights["emb"][ids]
        positions = jnp.broadcast_to(
            jnp.arange(max_len, dtype=jnp.int32)[None, :], (b, max_len))
        for lw in weights["layers"]:
            wd = dict(lw)
            wd["n_heads"] = cfg.n_heads
            hidden, _, _ = ref.decoder_layer_prefill(hidden, positions, wd)
        lens = jnp.asarray([len(t) for t in toks], jnp.int32)
        nxt, _ = lm_head_prefill(hidden, lens, weights["rms_f"],
                                 weights["w_out"])
        for i, t in enumerate(toks):
            t.append(int(nxt[i]))
    return toks
