//! Eq. 4 — the homogeneous speedup model, validated two ways.
//!
//! (1) Analytic behaviour: S_homo rises with replication count n_rep and
//!     degree p, with diminishing returns — §4.1's stated properties.
//! (2) Cross-validation against the simulator: the model's *predicted*
//!     speedup ordering over candidate strategies must match the measured
//!     throughput ordering (that is all Algorithm 1 needs from it).

use cocoserve::autoscale::speedup::{gamma, s_homo};
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::placement::Placement;
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::sim::{OomBehavior, SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{replicated_placement_13b as placement_with, Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn measured_throughput(p: &Placement) -> f64 {
    let cfg = SimConfig::paper_13b();
    let policy = SimPolicy {
        scheduler: SchedulerConfig::continuous(16),
        paged_kv: true,
        autoscale: false,
        oom: OomBehavior::Preempt,
    };
    let sim = Simulation::new(cfg, Cluster::paper_testbed(), vec![(p.clone(), policy)]);
    let trace = Trace::generate(Arrival::Poisson { rps: 45.0 }, LengthDist::alpaca(), 15.0, 3);
    sim.run(&trace, 15.0).total_throughput_tps()
}

fn main() {
    println!("Eq. 4 — S_homo(P) = 1 / (γ + (1−γ)/n · Σ 1/p_i)\n");
    let spec = DeviceSpec::a100_40gb();
    let g = gamma(0.3, spec.effective_flops(), 5120.0, spec.link_bw);
    println!("γ (A100 cluster constants, δ=0.3) = {g:.4}\n");

    // analytic sweep
    let mut t = Table::new(&["n_rep", "p=2", "p=3", "p=4"]);
    let mut rep = Report::new("eq4_speedup_model");
    for n_rep in [0usize, 10, 20, 30, 40] {
        let mut row = vec![format!("{n_rep}")];
        for p in [2usize, 3, 4] {
            let mut pv = vec![1usize; 40];
            for v in pv.iter_mut().take(n_rep) {
                *v = p;
            }
            let s = s_homo(g, &pv);
            row.push(format!("{s:.3}"));
            rep.set(&format!("s_rep{n_rep}_p{p}"), json::num(s));
        }
        t.row(&row);
    }
    println!("analytic speedup S_homo:");
    t.print();

    // cross-validation: model ordering vs simulator ordering
    println!("\ncross-validation against the simulator (45 RPS):");
    let strategies = [(0usize, 1usize), (10, 2), (20, 2), (40, 2), (20, 4), (40, 4)];
    let mut t2 = Table::new(&["strategy", "S_homo", "measured tok/s"]);
    let mut pairs: Vec<(f64, f64)> = vec![];
    for &(n_rep, dop) in &strategies {
        let mut pv = vec![1usize; 40];
        for v in pv.iter_mut().take(n_rep) {
            *v = dop;
        }
        let s = s_homo(g, &pv);
        let thr = measured_throughput(&placement_with(n_rep, dop));
        pairs.push((s, thr));
        t2.row(&[
            format!("rep{n_rep} dop{dop}"),
            format!("{s:.3}"),
            format!("{thr:.0}"),
        ]);
        rep.set(
            &format!("xval_rep{n_rep}_dop{dop}"),
            json::arr([s, thr].into_iter().map(json::num)),
        );
    }
    t2.print();

    // rank correlation (Kendall tau on the strategy pairs)
    let mut concordant = 0;
    let mut total = 0;
    for i in 0..pairs.len() {
        for j in i + 1..pairs.len() {
            if (pairs[i].0 - pairs[j].0).abs() < 1e-9 {
                continue;
            }
            total += 1;
            if (pairs[i].0 < pairs[j].0) == (pairs[i].1 < pairs[j].1) {
                concordant += 1;
            }
        }
    }
    let tau = concordant as f64 / total.max(1) as f64;
    println!(
        "\nmodel-vs-measurement rank agreement: {concordant}/{total} pairs \
         ({:.0}%) — Algorithm 1 only needs the ordering",
        tau * 100.0
    );
    rep.set("rank_agreement", json::num(tau));
    assert!(tau >= 0.8, "speedup model must rank strategies correctly");
    println!("report: {}", rep.write().unwrap().display());
}
