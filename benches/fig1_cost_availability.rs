//! Fig. 1 / §1 — the headline claim: CoCoServe "can reduce costs by 46 %
//! while maintaining availability".
//!
//! Cost is metered in **device-seconds**: a device bills for every
//! simulated second during which it holds at least one module of a live
//! instance (see `coordinator::fleet::CostLedger`). Availability is SLO
//! attainment. Three deployments serve the identical trace on the same
//! 8-device cluster, across the full five-scenario traffic library:
//!
//! * **static over-provisioned** — 8 instances, one per device, always on
//!   (capacity for the worst burst; bills every device for the whole run);
//! * **static tight** — 3 instances, always on (the cheap fixed fleet the
//!   elastic one should match on cost);
//! * **CoCo fleet-autoscaled** — starts at 3 instances; the fleet
//!   controller spins instances up under burst pressure (arbitrating
//!   module replication vs. whole-instance scaling by dry-run cost) and
//!   drains-then-releases them when load falls, with KV-headroom routing
//!   and OOM-shed re-routing.
//!
//! The bench asserts the tentpole acceptance bar: ≥ 30 % device-seconds
//! reduction vs. static over-provisioned at equal-or-better SLO
//! attainment (0.5 % tolerance), in every scenario — and that the fleet
//! configuration golden-replays byte-identically.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, FleetPhase, RoutePolicy, RouterConfig};
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::Trace;

const N_DEVICES: usize = 8;
const OVER_INSTANCES: usize = 8;
const TIGHT_INSTANCES: usize = 3;
const RPS: f64 = 18.0;
const DURATION_S: f64 = 48.0;
const SEED: u64 = 46;
/// Generous shared SLO: availability compares steady-state capacity, not
/// cold-start tails (every deployment is judged against the same bar).
const SLO_S: f64 = 30.0;
/// SLO-attainment tolerance for "equal-or-better" (half a percent).
const SLO_EPS: f64 = 0.005;

fn sim_config() -> SimConfig {
    let mut cfg = SimConfig::paper_13b();
    cfg.slo_latency_s = SLO_S;
    cfg
}

fn run_static(n_instances: usize, policy: SimPolicy, trace: &Trace) -> SimReport {
    let cfg = sim_config();
    let cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..n_instances)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % N_DEVICES),
                policy,
            )
        })
        .collect();
    Simulation::new(cfg, cluster, placements).run(trace, DURATION_S)
}

fn fleet_setup(policy: SimPolicy) -> FleetSetup {
    let mut fleet = FleetConfig::elastic(TIGHT_INSTANCES, N_DEVICES, policy);
    fleet.scale_out_queue = 16.0;
    fleet.cooldown_ticks = 2;
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::KvHeadroom,
            admission_limit: None,
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(fleet),
        // Cost-conscious posture: vacancy harvesting off (t_up unreachably
        // high) so idle devices stay unbilled; the fleet controller adds
        // capacity on demand instead, and SLO-pressure scale-downs still
        // run through the per-instance controllers.
        controller: cocoserve::autoscale::ControllerConfig {
            t_up: 2.0,
            ..Default::default()
        },
        predictor: None,
    }
}

fn run_fleet(trace: &Trace) -> SimReport {
    let cfg = sim_config();
    let cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    let policy = baselines::cocoserve(32);
    let placements: Vec<_> = (0..TIGHT_INSTANCES)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, fleet_setup(policy))
        .run(trace, DURATION_S)
}

fn main() {
    println!(
        "Fig. 1 — cost (device-seconds) vs availability (SLO ≤ {SLO_S:.0}s), \
         {N_DEVICES}×A100, {RPS:.0} rps aggregate, {DURATION_S:.0}s\n"
    );
    let mut t = Table::new(&[
        "scenario", "over dev·s", "tight dev·s", "fleet dev·s",
        "over SLO%", "tight SLO%", "fleet SLO%", "cost cut", "spin/drain",
    ]);
    let mut rep = Report::new("fig1_cost_availability");
    let mut replay_ok = true;
    let mut worst_cut = f64::INFINITY;

    for (name, trace) in Trace::scenario_sweep(RPS, DURATION_S, SEED) {
        let over = run_static(OVER_INSTANCES, baselines::vllm_like(32), &trace);
        let tight = run_static(TIGHT_INSTANCES, baselines::vllm_like(32), &trace);
        let fleet = run_fleet(&trace);

        // golden replay of the most stateful configuration
        let fleet_again = run_fleet(&trace);
        let identical = fleet.to_json().to_string() == fleet_again.to_json().to_string();
        replay_ok &= identical;
        if !identical {
            eprintln!("WARNING: scenario `{name}` was not replay-deterministic");
        }

        let cut = 1.0 - fleet.device_seconds / over.device_seconds.max(1e-9);
        worst_cut = worst_cut.min(cut);
        let (so, st, sf) = (
            over.slo_attainment(),
            tight.slo_attainment(),
            fleet.slo_attainment(),
        );
        let spins = fleet
            .fleet_events
            .iter()
            .filter(|e| e.phase == FleetPhase::SpinUp)
            .count();
        let drains = fleet
            .fleet_events
            .iter()
            .filter(|e| e.phase != FleetPhase::SpinUp)
            .count();
        t.row(&[
            name.to_string(),
            format!("{:.0}", over.device_seconds),
            format!("{:.0}", tight.device_seconds),
            format!("{:.0}", fleet.device_seconds),
            format!("{:.1}", so * 100.0),
            format!("{:.1}", st * 100.0),
            format!("{:.1}", sf * 100.0),
            format!("{:.0}%", cut * 100.0),
            format!("{spins}/{drains}"),
        ]);
        rep.set(
            name,
            json::obj(vec![
                (
                    "device_seconds",
                    json::arr(
                        [over.device_seconds, tight.device_seconds, fleet.device_seconds]
                            .into_iter()
                            .map(json::num),
                    ),
                ),
                ("slo_attainment", json::arr([so, st, sf].into_iter().map(json::num))),
                ("cost_reduction", json::num(cut)),
                ("fleet_spin_ups", json::num(spins as f64)),
                ("fleet_drains_releases", json::num(drains as f64)),
                ("fleet_routes", json::num(fleet.routes as f64)),
                ("fleet_reroutes", json::num(fleet.reroutes as f64)),
                ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
            ]),
        );

        // the acceptance bar, per scenario
        assert!(
            cut >= 0.30,
            "scenario `{name}`: fleet cost cut {:.1}% < 30% \
             (fleet {:.0} dev·s vs over-provisioned {:.0})",
            cut * 100.0,
            fleet.device_seconds,
            over.device_seconds
        );
        assert!(
            sf + SLO_EPS >= so,
            "scenario `{name}`: fleet SLO {:.3} worse than over-provisioned {:.3}",
            sf,
            so
        );
    }

    t.print();
    println!(
        "\nworst-scenario cost reduction at equal-or-better availability: {:.0}% \
         (paper claims 46%)",
        worst_cut * 100.0
    );
    println!(
        "golden replay across all scenarios: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("worst_cost_reduction", json::num(worst_cut));
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
