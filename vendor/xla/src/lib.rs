//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The real-path engine (`cocoserve::runtime` / `cocoserve::engine`)
//! executes AOT-compiled HLO artifacts through the PJRT C API. That native
//! closure is not available in this offline build environment, so this
//! stub provides the exact type/method surface the workspace compiles
//! against while failing cleanly at *runtime*: [`PjRtClient::cpu`] returns
//! an error, so every artifact-gated code path (they all check
//! `artifacts_available()` first, and artifacts cannot be produced without
//! the real toolchain) reports "PJRT unavailable" instead of executing.
//!
//! Swapping in real PJRT bindings is a Cargo-level substitution: point the
//! `xla` path dependency in the workspace root at the vendored real crate.
//! No source changes are needed — this stub exists so the simulator,
//! scheduler, autoscaler and bench suite (the paper-scale path) build and
//! test without the native toolchain.

use std::fmt;

/// Error type mirroring the binding crate's (callers format with `{:?}`).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable (stub `xla` crate; see vendor/xla)"
    )))
}

/// A PJRT client handle. The stub can never be constructed.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; result is indexed `[replica][output]`.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal (tensor value).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{e:?}").contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literal_surface_is_constructible() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.clone().to_tuple().is_err());
        let v: Result<Vec<f32>, _> = Literal::vec1(&[0i32]).to_vec();
        assert!(v.is_err());
    }
}
