//! Telemetry contract tests.
//!
//! The tracing layer is a pure observer of the event kernel, and these
//! tests pin the four load-bearing guarantees:
//!
//! 1. **Telemetry off is the golden baseline** — enabling telemetry must
//!    not perturb the simulation: on all five scenarios, the metrics JSON
//!    of a telemetry-on run minus its strictly-additive `timeline` key is
//!    byte-identical to the telemetry-off document (which carries no
//!    telemetry keys at all).
//! 2. **Replay determinism** — span timestamps are sim-time only, so two
//!    telemetry-on runs of the same seed produce byte-equal Chrome trace
//!    exports.
//! 3. **Span conservation** — every request that arrives gets exactly one
//!    `Arrival` span edge, every completion exactly one `Completed` edge,
//!    and the edge counts reconcile with the report's counters.
//! 4. **Shard invariance** — spans are recorded inside the shared
//!    dispatch body, so shards=1 and shards=4 export byte-identical
//!    traces.

use std::collections::HashMap;

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, RoutePolicy, RouterConfig};
use cocoserve::forecast::PredictConfig;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimReport, Simulation};
use cocoserve::telemetry::{ReqPhase, TelemetryConfig, TraceEvent};
use cocoserve::util::json::Json;
use cocoserve::workload::Trace;

const DURATION_S: f64 = 10.0;

fn setup() -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 5, baselines::cocoserve(32))),
        predictor: Some(PredictConfig::default()),
        ..Default::default()
    }
}

fn run(telemetry: Option<TelemetryConfig>, shards: usize, trace: &Trace) -> SimReport {
    let mut cfg = SimConfig::paper_13b();
    cfg.shards = shards;
    cfg.telemetry = telemetry;
    let n_devices = 5;
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..3)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % n_devices),
                baselines::cocoserve(32),
            )
        })
        .collect();
    let sim = Simulation::with_fleet(cfg, cluster, placements, setup());
    sim.run(trace, DURATION_S)
}

/// Render a metrics document with its `timeline` key (if any) removed.
fn without_timeline(doc: &str) -> String {
    let mut j = Json::parse(doc).expect("metrics JSON parses");
    if let Json::Obj(o) = &mut j {
        o.remove("timeline");
    }
    j.to_string()
}

/// 1. Enabling telemetry must not perturb the golden metrics surface:
/// off-document == on-document minus the strictly-additive timeline key,
/// on all five scenarios.
#[test]
fn telemetry_off_goldens_are_byte_identical_on_all_scenarios() {
    for (name, trace) in Trace::scenario_sweep(18.0, DURATION_S, 77) {
        let off = run(None, 1, &trace).to_json().to_string();
        let on = run(Some(TelemetryConfig::default()), 1, &trace).to_json().to_string();
        assert!(
            !off.contains("\"timeline\""),
            "scenario {name}: telemetry-off golden must carry no timeline key"
        );
        assert!(
            on.contains("\"timeline\""),
            "scenario {name}: telemetry-on golden must carry the timeline key"
        );
        assert_eq!(
            off,
            without_timeline(&on),
            "scenario {name}: telemetry perturbed the golden metrics surface"
        );
        // re-render the off document too, so the comparison above cannot
        // pass by accident of both sides being normalized
        assert_eq!(off, without_timeline(&off), "off-document not canonical");
    }
}

/// 2. Same seed ⇒ byte-equal Chrome trace export across two full runs.
#[test]
fn trace_export_is_seed_deterministic() {
    let trace = Trace::burst(20.0, DURATION_S, 13);
    let a = run(Some(TelemetryConfig::default()), 1, &trace);
    let b = run(Some(TelemetryConfig::default()), 1, &trace);
    let ta = a.chrome_trace().expect("trace captured").to_string();
    let tb = b.chrome_trace().expect("trace captured").to_string();
    assert!(!ta.is_empty());
    assert_eq!(ta, tb, "two runs of the same seed exported different traces");
    // and the export is valid JSON with the Chrome trace envelope
    let parsed = Json::parse(&ta).expect("trace export parses");
    assert!(parsed.req("traceEvents").as_arr().is_some());
}

/// 3. Span conservation: one Arrival edge per arriving request, one
/// Completed edge per completion, counts reconciled with the report.
#[test]
fn span_conservation_holds() {
    let trace = Trace::two_tenant(20.0, DURATION_S, 7);
    let report = run(Some(TelemetryConfig::default()), 1, &trace);
    let buf = report.trace.as_ref().expect("trace buffer captured");
    assert_eq!(buf.dropped, 0, "full sink must never drop");

    let mut arrivals: HashMap<u64, u32> = HashMap::new();
    let mut completions: HashMap<u64, u32> = HashMap::new();
    let mut routed = 0u64;
    for ev in &buf.events {
        if let TraceEvent::Req { id, phase, .. } = ev {
            match phase {
                ReqPhase::Arrival => *arrivals.entry(*id).or_insert(0) += 1,
                ReqPhase::Completed => *completions.entry(*id).or_insert(0) += 1,
                ReqPhase::Routed => routed += 1,
                _ => {}
            }
        }
    }
    assert!(
        arrivals.values().all(|&n| n == 1),
        "a request arrived more than once"
    );
    assert!(
        completions.values().all(|&n| n == 1),
        "a request completed more than once"
    );
    assert!(
        completions.keys().all(|id| arrivals.contains_key(id)),
        "a request completed without an arrival edge"
    );
    // every arriving request either routed immediately or parked; either
    // way it produced exactly one Arrival edge, so arrivals ≤ trace size
    assert!(arrivals.len() <= trace.len());
    assert!(routed as usize <= arrivals.len());
    assert_eq!(
        completions.len(),
        report.total_completed(),
        "Completed edges must equal the report's completion count"
    );
    assert!(
        completions.len() <= arrivals.len(),
        "completions exceeded arrivals"
    );
}

/// 4. Spans are recorded inside the shared dispatch body, so the export
/// is invariant under event-kernel sharding.
#[test]
fn trace_export_is_shard_invariant() {
    for (name, trace) in [
        ("steady", Trace::steady(18.0, DURATION_S, 5)),
        ("burst", Trace::burst(22.0, DURATION_S, 5)),
    ] {
        let seq = run(Some(TelemetryConfig::default()), 1, &trace);
        let sharded = run(Some(TelemetryConfig::default()), 4, &trace);
        assert_eq!(
            seq.chrome_trace().unwrap().to_string(),
            sharded.chrome_trace().unwrap().to_string(),
            "scenario {name}: shards=4 exported a different trace"
        );
        // metrics (timeline included) must agree too
        assert_eq!(
            seq.to_json().to_string(),
            sharded.to_json().to_string(),
            "scenario {name}: shards=4 diverged on metrics"
        );
    }
}

/// Ring sink: bounded capture keeps the newest records and reports the
/// overwrite count, and the export still parses.
#[test]
fn ring_sink_bounds_capture_and_reports_drops() {
    let trace = Trace::steady(25.0, DURATION_S, 11);
    let full = run(Some(TelemetryConfig::default()), 1, &trace);
    let n_full = full.trace.as_ref().unwrap().events.len();
    assert!(n_full > 64, "scenario too small to exercise the ring");

    let ring = run(Some(TelemetryConfig::ring(64)), 1, &trace);
    let buf = ring.trace.as_ref().unwrap();
    assert_eq!(buf.events.len(), 64, "ring must cap at capacity");
    assert_eq!(
        buf.dropped as usize,
        n_full - 64,
        "dropped must count every overwritten record"
    );
    // ring keeps the newest events in chronological order
    let times: Vec<f64> = buf.events.iter().map(|e| e.t()).collect();
    assert!(
        times.windows(2).all(|w| w[0] <= w[1]),
        "ring unroll must be chronological"
    );
    let parsed = Json::parse(&ring.chrome_trace().unwrap().to_string())
        .expect("ring export parses");
    assert_eq!(
        parsed.req("droppedEvents").as_u64(),
        Some(buf.dropped),
        "export must surface the drop count"
    );
}
