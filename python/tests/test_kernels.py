"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Includes hypothesis sweeps over shapes/dtypes — the required
kernel-vs-reference signal for the interpret-mode Pallas path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention, vmem_bytes
from compile.kernels.fused_rmsnorm_matmul import fused_rmsnorm_matmul

RNG = np.random.default_rng(1234)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("b,h,s,hd", [
        (1, 1, 16, 8), (2, 4, 32, 16), (1, 2, 64, 32), (3, 1, 48, 16),
    ])
    def test_causal_matches_ref(self, b, h, s, hd):
        q, k, v = randn(b, h, s, hd), randn(b, h, s, hd), randn(b, h, s, hd)
        mask = ref.causal_mask(s, s)[None, None]
        want = ref.attention(q, k, v, mask)
        got = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("b,h,s,hd", [(2, 2, 32, 16), (1, 4, 24, 8)])
    def test_non_causal_matches_ref(self, b, h, s, hd):
        q, k, v = randn(b, h, s, hd), randn(b, h, s, hd), randn(b, h, s, hd)
        want = ref.attention(q, k, v, mask=None)
        got = flash_attention(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("block_q,block_k", [(4, 4), (8, 16), (16, 8),
                                                 (32, 32), (5, 7)])
    def test_block_shape_invariance(self, block_q, block_k):
        """Output must not depend on the tiling — the core Pallas invariant."""
        b, h, s, hd = 2, 2, 32, 16
        q, k, v = randn(b, h, s, hd), randn(b, h, s, hd), randn(b, h, s, hd)
        base = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        got = flash_attention(q, k, v, causal=True,
                              block_q=block_q, block_k=block_k)
        np.testing.assert_allclose(got, base, rtol=2e-5, atol=2e-5)

    def test_q_shorter_than_k(self):
        """Chunked prefill: queries are the last sq positions of sk."""
        b, h, sq, sk, hd = 1, 2, 8, 32, 16
        q = randn(b, h, sq, hd)
        k, v = randn(b, h, sk, hd), randn(b, h, sk, hd)
        mask = ref.causal_mask(sq, sk)[None, None]
        want = ref.attention(q, k, v, mask)
        got = flash_attention(q, k, v, causal=True, q_offset=sk - sq)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_first_token_fully_masked_row_is_finite(self):
        """Causal row 0 attends to exactly one key; no NaN/inf anywhere."""
        q, k, v = randn(1, 1, 16, 8), randn(1, 1, 16, 8), randn(1, 1, 16, 8)
        got = flash_attention(q, k, v, causal=True, block_q=4, block_k=4)
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_scale_invariance_of_softmax_shift(self):
        """Large-magnitude scores must not overflow (online softmax)."""
        q = randn(1, 1, 16, 8) * 100.0
        k = randn(1, 1, 16, 8) * 100.0
        v = randn(1, 1, 16, 8)
        mask = ref.causal_mask(16, 16)[None, None]
        want = ref.attention(q, k, v, mask)
        got = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s_pow=st.integers(2, 6),
        hd_pow=st.integers(2, 5),
        bq_pow=st.integers(1, 4),
        bk_pow=st.integers(1, 4),
    )
    def test_hypothesis_shape_sweep(self, b, h, s_pow, hd_pow, bq_pow,
                                    bk_pow):
        s, hd = 2 ** s_pow, 2 ** hd_pow
        rng = np.random.default_rng(b * 1000 + h * 100 + s + hd)
        q = jnp.asarray(rng.standard_normal((b, h, s, hd), dtype=np.float32))
        k = jnp.asarray(rng.standard_normal((b, h, s, hd), dtype=np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, s, hd), dtype=np.float32))
        mask = ref.causal_mask(s, s)[None, None]
        want = ref.attention(q, k, v, mask)
        got = flash_attention(q, k, v, causal=True,
                              block_q=2 ** bq_pow, block_k=2 ** bk_pow)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_vmem_estimate_within_budget(self):
        """Structural perf check (DESIGN.md §Perf): default tiling at the
        paper-scale head_dim fits a 16 MB VMEM budget comfortably."""
        assert vmem_bytes(block_q=128, block_k=128, seq_k=4096,
                          head_dim=128) < 16 * 2 ** 20


# --------------------------------------------------------------------------
# fused rmsnorm + matmul
# --------------------------------------------------------------------------

class TestFusedRmsnormMatmul:
    @pytest.mark.parametrize("m,d,n", [(8, 64, 64), (16, 64, 172),
                                       (7, 32, 100), (1, 128, 344)])
    def test_matches_ref(self, m, d, n):
        x, g, w = randn(m, d), randn(d), randn(d, n)
        want = ref.rmsnorm_matmul(x, g, w)
        got = fused_rmsnorm_matmul(x, g, w)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_batched_leading_dims(self):
        x, g, w = randn(2, 5, 64), randn(64), randn(64, 32)
        want = ref.rmsnorm_matmul(x, g, w)
        got = fused_rmsnorm_matmul(x, g, w)
        assert got.shape == (2, 5, 32)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("block_m,block_n", [(4, 16), (16, 64), (3, 5),
                                                 (32, 128)])
    def test_block_shape_invariance(self, block_m, block_n):
        x, g, w = randn(16, 64), randn(64), randn(64, 172)
        want = ref.rmsnorm_matmul(x, g, w)
        got = fused_rmsnorm_matmul(x, g, w, block_m=block_m, block_n=block_n)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 20),
        d_pow=st.integers(3, 7),
        n=st.integers(1, 200),
        bm=st.integers(1, 32),
        bn=st.integers(1, 128),
    )
    def test_hypothesis_shape_sweep(self, m, d_pow, n, bm, bn):
        d = 2 ** d_pow
        rng = np.random.default_rng(m * 7919 + d + n)
        x = jnp.asarray(rng.standard_normal((m, d), dtype=np.float32))
        g = jnp.asarray(rng.standard_normal((d,), dtype=np.float32))
        w = jnp.asarray(rng.standard_normal((d, n), dtype=np.float32))
        want = ref.rmsnorm_matmul(x, g, w)
        got = fused_rmsnorm_matmul(x, g, w, block_m=bm, block_n=bn)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_zero_input_stays_finite(self):
        """eps keeps the rsqrt finite for all-zero rows."""
        x = jnp.zeros((4, 64))
        g, w = randn(64), randn(64, 16)
        got = fused_rmsnorm_matmul(x, g, w)
        assert bool(jnp.all(jnp.isfinite(got)))
        np.testing.assert_allclose(got, jnp.zeros((4, 16)), atol=1e-6)


# --------------------------------------------------------------------------
# reference self-consistency (the oracle itself must be trustworthy)
# --------------------------------------------------------------------------

class TestRefInternals:
    def test_rope_norm_preserving(self):
        """RoPE is a rotation: per-pair L2 norms are preserved."""
        x = randn(2, 2, 8, 16)
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
        y = ref.rope(x, pos)
        def pair_norms(t):
            half = t.shape[-1] // 2
            return jnp.sqrt(t[..., :half] ** 2 + t[..., half:] ** 2)
        np.testing.assert_allclose(pair_norms(y), pair_norms(x),
                                   rtol=1e-5, atol=1e-5)

    def test_rope_position_zero_identity(self):
        x = randn(1, 1, 4, 16)
        pos = jnp.zeros((1, 4), jnp.int32)
        np.testing.assert_allclose(ref.rope(x, pos), x, rtol=1e-6, atol=1e-6)

    def test_attention_rows_convex(self):
        """Each attention output row is a convex combination of V rows."""
        q, k = randn(1, 1, 8, 8), randn(1, 1, 8, 8)
        v = jnp.ones((1, 1, 8, 8))
        out = ref.attention(q, k, v)
        np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5)

    def test_causal_mask_shape_and_diag(self):
        m = ref.causal_mask(4, 4)
        assert m.shape == (4, 4)
        assert bool(jnp.all(jnp.diagonal(m)))
        assert not bool(m[0, 1])

    def test_causal_mask_offset(self):
        """Queries are the last sq of sk: row 0 sees the first sk-sq+1 keys."""
        m = ref.causal_mask(2, 5)
        np.testing.assert_array_equal(
            np.asarray(m),
            np.array([[True, True, True, True, False],
                      [True, True, True, True, True]]))

    def test_rmsnorm_unit_rows(self):
        x = randn(4, 64)
        y = ref.rmsnorm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, jnp.ones(4), rtol=1e-3)
