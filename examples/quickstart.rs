//! Quickstart — the required end-to-end driver (DESIGN.md §E2E).
//!
//! Loads the real tiny-llama artifacts (AOT-compiled from JAX + Pallas),
//! serves a Poisson request stream through the full Rust stack (scheduler →
//! continuous batching → PJRT module pipeline → KV caches), and reports
//! latency/throughput. Python is not running — check your process table.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use cocoserve::coordinator::{serve_trace, ServeConfig};
use cocoserve::engine::TinyEngine;
use cocoserve::runtime::{artifacts_available, default_artifacts_dir};
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::util::bench::fmt_secs;
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    println!("== CoCoServe quickstart: real model, real tokens, no Python ==\n");
    let t0 = std::time::Instant::now();
    let engine = TinyEngine::open(&default_artifacts_dir(), "tiny-llama")?;
    println!(
        "loaded {}: {} layers · d_model {} · {} heads · vocab {}  ({})",
        engine.cfg.name, engine.cfg.n_layers, engine.cfg.d_model,
        engine.cfg.n_heads, engine.cfg.vocab_size, fmt_secs(t0.elapsed().as_secs_f64())
    );

    // 1. single-prompt generation
    let out = engine.generate_greedy(&[vec![1, 2, 3, 4]], 12)?;
    println!("\ngreedy continuation of [1,2,3,4]: {:?}", &out[0][4..]);

    // 2. live batched serving: Poisson arrivals, continuous batching
    let rps = 6.0;
    let duration = 10.0;
    let trace = Trace::generate(
        Arrival::Poisson { rps },
        LengthDist::tiny(),
        duration,
        7,
    );
    println!(
        "\nserving {} requests ({rps} rps Poisson, {duration}s, outputs ≤32 tokens)…",
        trace.len()
    );
    let report = serve_trace(
        &engine,
        &trace,
        ServeConfig {
            scheduler: SchedulerConfig::continuous(8),
            slo_latency_s: 2.0,
            realtime: true,
        },
    )?;

    let mut lat = report.monitor.latency_summary();
    println!("\n-- results ------------------------------------------");
    println!("completed requests : {}", report.completed);
    println!("generated tokens   : {}", report.generated_tokens);
    println!("wall time          : {:.2}s", report.duration_s);
    println!("throughput         : {:.1} tok/s", report.tokens_per_s());
    println!(
        "latency mean/p50/p95: {} / {} / {}",
        fmt_secs(lat.mean()),
        fmt_secs(lat.p50()),
        fmt_secs(lat.p95())
    );
    println!(
        "SLO(≤2s) attainment : {:.1}%",
        report.monitor.slo_attainment() * 100.0
    );
    println!("PJRT executions    : {}", report.executions);
    println!("\nall three layers composed: Pallas kernel → JAX module → HLO");
    println!("text → PJRT CPU → Rust coordinator. See EXPERIMENTS.md §E2E.");
    Ok(())
}
