//! The Auto-Scaling Controller (§5): threshold decisions + cooldown,
//! closing the loop as **plans**.
//!
//! Periodically evaluates monitor feedback in two stages:
//!
//! 1. [`Controller::decide`] — the raw threshold stage: scale-up when the
//!    cluster-wide resource vacancy exceeds `T_up`, scale-down when the
//!    SLO violation rate exceeds `T_down` (or any OOM occurred), with a
//!    cooldown suppressing decision flapping while a previous operation's
//!    cost is still being amortized.
//! 2. [`Controller::tick`] — runs the matching **pure planner** over a
//!    [`PlanCtx`] and emits a [`PlannedDecision`] carrying a validated,
//!    costed [`crate::plan::ScalePlan`]. Nothing is mutated here; the
//!    caller executes the plan (atomically via
//!    [`crate::ops::PlanExecutor`], or in flight in the simulation
//!    kernel).

use crate::cluster::{Cluster, ShadowLedger};
use crate::ops::ModuleOps;
use crate::placement::Placement;

use super::scale_down::{scale_down, Pressure, ScaleDownConfig, ScaleDownPlan};
use super::scale_up::{scale_up, ScaleUpConfig, ScaleUpPlan};

/// Snapshot of the signals the controller consumes each tick (produced by
/// `monitor::Monitor::controller_view`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerInputs {
    /// Mean vacancy rate across eligible devices (1 − mem_frac).
    pub vacancy_rate: f64,
    /// Fraction of recent requests violating the SLO.
    pub slo_violation_rate: f64,
    /// OOM events since the last tick.
    pub oom_events: u64,
    /// Most loaded device + its pressure kind (scale-down target).
    pub hottest_device: usize,
    /// Compute utilization of the hottest device.
    pub hottest_compute_util: f64,
    /// Memory fraction of the hottest device.
    pub hottest_mem_frac: f64,
}

/// Raw threshold decision for one tick (stage 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Thresholds in the healthy band (or cooling down) — no action.
    None,
    /// Vacancy above T_up with a clean SLO: harvest idle resources.
    ScaleUp,
    /// SLO violations or OOM: relieve the named device under the given
    /// pressure kind.
    ScaleDown { device: usize, pressure: Pressure },
}

/// A threshold decision elaborated into an executable plan (stage 2).
#[derive(Debug)]
pub enum PlannedDecision {
    /// Nothing to do (or the decision planned to a no-op).
    None,
    /// An Algorithm 1 replication plan.
    ScaleUp(ScaleUpPlan),
    /// An Algorithm 2 relief plan plus its batch decision.
    ScaleDown(ScaleDownPlan),
}

/// Everything the planners need to elaborate a decision, borrowed
/// read-only from the deployment being controlled. Ownership rule:
/// planners never see `&mut Cluster` — the controller cannot mutate.
pub struct PlanCtx<'a> {
    /// Module sizing + transfer costing for the controlled instance.
    pub ops: &'a ModuleOps<'a>,
    /// The live device ledgers (read-only).
    pub cluster: &'a Cluster,
    /// The instance's live placement (read-only).
    pub placement: &'a Placement,
    /// Algorithm 1 knobs for the scale-up planner.
    pub up_cfg: ScaleUpConfig,
    /// Algorithm 2 knobs for the scale-down planner.
    pub down_cfg: ScaleDownConfig,
    /// Current serving batch size (phase-3 scale-down input).
    pub batch_size: usize,
    /// Live KV payload per layer, for KV-cache migration costing.
    pub kv_bytes_per_layer: f64,
    /// Scale-down source override (e.g. the instance-local hottest
    /// device); defaults to the monitor's cluster-wide hottest.
    pub down_src: Option<usize>,
}

/// Threshold configuration (T_up / T_down of §5).
#[derive(Debug, Clone, Copy)]
pub struct ControllerConfig {
    /// Scale up when vacancy exceeds this (idle resources to harvest).
    pub t_up: f64,
    /// Scale down when SLO violation rate exceeds this.
    pub t_down: f64,
    /// Ticks to wait after an action before acting again.
    pub cooldown_ticks: u32,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig { t_up: 0.30, t_down: 0.05, cooldown_ticks: 2 }
    }
}

/// Stateful threshold controller.
#[derive(Debug, Clone)]
pub struct Controller {
    /// Threshold configuration this controller was built with.
    pub cfg: ControllerConfig,
    cooldown: u32,
    decisions: u64,
}

impl Controller {
    /// Build a controller for the given thresholds.
    pub fn new(cfg: ControllerConfig) -> Controller {
        Controller { cfg, cooldown: 0, decisions: 0 }
    }

    /// Non-`None` decisions made so far.
    pub fn decisions_made(&self) -> u64 {
        self.decisions
    }

    /// Stage 1: evaluate the thresholds for one control tick.
    ///
    /// Priority: OOM/SLO pressure outranks idle-resource harvesting —
    /// scale-down is checked first (§4.2 runs "when workload intensifies
    /// beyond capacity"), and an OOM bypasses the cooldown entirely.
    pub fn decide(&mut self, inp: &ControllerInputs) -> Decision {
        let emergency = inp.oom_events > 0;
        if self.cooldown > 0 && !emergency {
            self.cooldown -= 1;
            return Decision::None;
        }

        if emergency || inp.slo_violation_rate > self.cfg.t_down {
            // Memory pressure if the hot device is memory-dominated;
            // compute pressure otherwise (§3.3 module selection).
            let pressure = if emergency
                || inp.hottest_mem_frac >= inp.hottest_compute_util
            {
                Pressure::Memory
            } else {
                Pressure::Compute
            };
            self.arm();
            return Decision::ScaleDown { device: inp.hottest_device, pressure };
        }

        if inp.vacancy_rate > self.cfg.t_up && inp.slo_violation_rate == 0.0 {
            self.arm();
            return Decision::ScaleUp;
        }

        Decision::None
    }

    /// Stage 2: evaluate one control tick and elaborate the decision into
    /// an executable plan via the pure planners. Decisions that plan to a
    /// no-op (empty plan, unchanged batch) collapse to
    /// [`PlannedDecision::None`].
    pub fn tick(
        &mut self,
        inp: &ControllerInputs,
        ctx: &PlanCtx<'_>,
        is_violating: impl FnMut(&ShadowLedger<'_>, &Placement, usize) -> bool,
    ) -> PlannedDecision {
        let decision = self.decide(inp);
        self.plan(decision, ctx, is_violating)
    }

    /// Elaborate a stage-1 decision into a plan (stateless — callers that
    /// want to skip building a [`PlanCtx`] on `Decision::None` ticks run
    /// [`Controller::decide`] first and call this only when acting).
    pub fn plan(
        &self,
        decision: Decision,
        ctx: &PlanCtx<'_>,
        is_violating: impl FnMut(&ShadowLedger<'_>, &Placement, usize) -> bool,
    ) -> PlannedDecision {
        match decision {
            Decision::None => PlannedDecision::None,
            Decision::ScaleUp => {
                let plan = scale_up(ctx.ops, ctx.cluster, ctx.placement, &ctx.up_cfg);
                if plan.plan.is_empty() {
                    PlannedDecision::None
                } else {
                    PlannedDecision::ScaleUp(plan)
                }
            }
            Decision::ScaleDown { device, pressure } => {
                let src = ctx.down_src.unwrap_or(device);
                let kv = ctx.kv_bytes_per_layer;
                let plan = scale_down(
                    ctx.ops,
                    ctx.cluster,
                    ctx.placement,
                    src,
                    pressure,
                    ctx.batch_size,
                    &ctx.down_cfg,
                    |_| kv,
                    is_violating,
                );
                if plan.plan.is_empty() && plan.batch_size == ctx.batch_size {
                    PlannedDecision::None
                } else {
                    PlannedDecision::ScaleDown(plan)
                }
            }
        }
    }

    fn arm(&mut self) {
        self.cooldown = self.cfg.cooldown_ticks;
        self.decisions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GIB;
    use crate::model::cost::CostModel;
    use crate::model::ModelConfig;

    fn idle() -> ControllerInputs {
        ControllerInputs {
            vacancy_rate: 0.6,
            slo_violation_rate: 0.0,
            oom_events: 0,
            hottest_device: 0,
            hottest_compute_util: 0.2,
            hottest_mem_frac: 0.4,
        }
    }

    fn overloaded() -> ControllerInputs {
        ControllerInputs {
            vacancy_rate: 0.05,
            slo_violation_rate: 0.4,
            oom_events: 0,
            hottest_device: 2,
            hottest_compute_util: 0.99,
            hottest_mem_frac: 0.7,
        }
    }

    #[test]
    fn idle_cluster_scales_up() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.decide(&idle()), Decision::ScaleUp);
    }

    #[test]
    fn slo_violation_scales_down_with_compute_pressure() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(
            c.decide(&overloaded()),
            Decision::ScaleDown { device: 2, pressure: Pressure::Compute }
        );
    }

    #[test]
    fn memory_dominated_device_gets_memory_pressure() {
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = overloaded();
        inp.hottest_mem_frac = 0.99;
        inp.hottest_compute_util = 0.5;
        assert!(matches!(
            c.decide(&inp),
            Decision::ScaleDown { pressure: Pressure::Memory, .. }
        ));
    }

    #[test]
    fn cooldown_suppresses_consecutive_actions() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.decide(&idle()), Decision::ScaleUp);
        assert_eq!(c.decide(&idle()), Decision::None);
        assert_eq!(c.decide(&idle()), Decision::None);
        assert_eq!(c.decide(&idle()), Decision::ScaleUp); // cooldown over
        assert_eq!(c.decisions_made(), 2);
    }

    #[test]
    fn oom_bypasses_cooldown() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.decide(&idle()), Decision::ScaleUp); // arms cooldown
        let mut inp = overloaded();
        inp.oom_events = 3;
        assert!(matches!(c.decide(&inp), Decision::ScaleDown { .. }));
    }

    #[test]
    fn scale_down_outranks_scale_up() {
        // Vacant cluster *and* SLO violations: stability wins.
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = idle();
        inp.slo_violation_rate = 0.2;
        assert!(matches!(c.decide(&inp), Decision::ScaleDown { .. }));
    }

    #[test]
    fn no_action_in_the_healthy_band() {
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = idle();
        inp.vacancy_rate = 0.2; // below T_up, above trouble
        assert_eq!(c.decide(&inp), Decision::None);
        assert_eq!(c.decisions_made(), 0);
    }

    // ---- stage 2: plan emission -------------------------------------------

    fn plan_fixture() -> (CostModel, crate::cluster::Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let mut cl = crate::cluster::Cluster::paper_testbed();
        cl.device_mut(0).alloc("inst0/model", 24.2 * GIB).unwrap();
        (cm, cl, Placement::single_device(40, 0))
    }

    #[test]
    fn tick_emits_a_scale_up_plan_without_mutating() {
        let (cm, cl, pl) = plan_fixture();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ctx = PlanCtx {
            ops: &ops,
            cluster: &cl,
            placement: &pl,
            up_cfg: ScaleUpConfig { max_ops_per_round: 4, ..Default::default() },
            down_cfg: ScaleDownConfig::default(),
            batch_size: 16,
            kv_bytes_per_layer: 0.0,
            down_src: None,
        };
        let mut c = Controller::new(ControllerConfig::default());
        let d = c.tick(&idle(), &ctx, |_, _, _| false);
        let PlannedDecision::ScaleUp(up) = d else { panic!("expected plan, got {d:?}") };
        assert_eq!(up.planned.len(), 4);
        assert_eq!(pl.inv_p_norm(), 40.0, "tick must not mutate the placement");
        assert_eq!(up.cost.per_op.len(), 4);
    }

    #[test]
    fn tick_emits_a_scale_down_plan_with_batch_decision() {
        let (cm, cl, pl) = plan_fixture();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ctx = PlanCtx {
            ops: &ops,
            cluster: &cl,
            placement: &pl,
            up_cfg: ScaleUpConfig::default(),
            down_cfg: ScaleDownConfig::default(),
            batch_size: 15,
            kv_bytes_per_layer: 1.0 * GIB,
            down_src: Some(0),
        };
        let mut c = Controller::new(ControllerConfig::default());
        let d = c.tick(&overloaded(), &ctx, |_, _, bs| bs > 5);
        let PlannedDecision::ScaleDown(down) = d else { panic!("expected plan, got {d:?}") };
        assert!(down.resolved);
        assert_eq!(down.batch_size, 5, "phase-3 batch decision carried in the plan");
    }

    #[test]
    fn tick_collapses_empty_plans_to_none() {
        let (cm, mut cl, pl) = plan_fixture();
        for d in 1..4 {
            cl.device_mut(d).alloc("hog", 39.0 * GIB).unwrap();
        }
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ctx = PlanCtx {
            ops: &ops,
            cluster: &cl,
            placement: &pl,
            up_cfg: ScaleUpConfig::default(),
            down_cfg: ScaleDownConfig::default(),
            batch_size: 16,
            kv_bytes_per_layer: 0.0,
            down_src: None,
        };
        let mut c = Controller::new(ControllerConfig::default());
        // thresholds say scale up, but no eligible destination exists
        assert!(matches!(c.tick(&idle(), &ctx, |_, _, _| false), PlannedDecision::None));
    }
}
