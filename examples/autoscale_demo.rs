//! Auto-scaling under a traffic ramp — the §4/§5 control loop in action.
//!
//! Traffic ramps 2 → 45 RPS over 60 s. The controller harvests idle devices
//! early (scale-up via layer replication, Algorithm 1) and sheds pressure
//! late (scale-down, Algorithm 2). The demo prints the controller's actions
//! and the resulting placement evolution.
//!
//! ```bash
//! cargo run --release --example autoscale_demo
//! ```

use cocoserve::baselines;
use cocoserve::cluster::Cluster;
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, Simulation};
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn main() {
    println!("== auto-scaling demo: traffic ramp 2 → 45 RPS over 60 s ==\n");
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::paper_testbed();
    let placement = Placement::single_device(cfg.model.n_layers, 0);

    let trace = Trace::generate(
        Arrival::Ramp { from: 2.0, to: 45.0 },
        LengthDist::alpaca(),
        60.0,
        23,
    );
    println!("{} requests generated\n", trace.len());

    for (label, policy) in [
        ("static (no autoscale)", baselines::cocoserve_no_autoscale(16)),
        ("CoCoServe autoscaled ", baselines::cocoserve(16)),
    ] {
        let sim = Simulation::new(
            cfg.clone(),
            Cluster::paper_testbed(),
            vec![(placement.clone(), policy)],
        );
        let r = sim.run(&trace, 60.0);
        let mut lat = r.merged_latency();
        let p = &r.placements[0];
        let degrees: Vec<usize> = (0..p.n_layers).map(|l| p.degree(l)).collect();
        let replicas: usize = degrees.iter().map(|d| d - 1).sum();
        println!(
            "{label}: lat mean {:.2}s p95 {:.2}s · thr {:.0} tok/s · SLO {:.1}%",
            lat.mean(),
            lat.p95(),
            r.total_throughput_tps(),
            r.slo_attainment() * 100.0
        );
        println!(
            "  scaling: {} up / {} down · final replica count {replicas} · max degree {}",
            r.scale_ups,
            r.scale_downs,
            degrees.iter().max().unwrap()
        );
    }
    let _ = cluster;
    println!(
        "\nThe autoscaled run converts idle devices into layer replicas as the\n\
         ramp builds — replication count rises with load, exactly the §3.2\n\
         observation driving Algorithm 1."
    );
}
