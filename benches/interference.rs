//! §8 — interference of scaling operations on neighbouring instances.
//!
//! Paper claims: during dynamic migration, adjacent instances see <3%
//! throughput fluctuation and <5% latency jitter. Setup: two instances on
//! separate devices; instance 0 performs scaling ops mid-run; instance 1's
//! metrics are compared against a run where instance 0 never scales.

use cocoserve::baselines;
use cocoserve::cluster::Cluster;
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn run(scaling: bool) -> (f64, f64) {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::paper_testbed();
    let p0 = Placement::single_device(cfg.model.n_layers, 0);
    let p1 = Placement::single_device(cfg.model.n_layers, 1);
    let inst0 = if scaling {
        baselines::cocoserve(64) // scales during the run
    } else {
        baselines::cocoserve_no_autoscale(64)
    };
    let sim = Simulation::new(
        cfg,
        cluster,
        vec![(p0, inst0), (p1, baselines::cocoserve_no_autoscale(64))],
    );
    let trace = Trace::generate(
        Arrival::Poisson { rps: 25.0 },
        LengthDist::alpaca(),
        25.0,
        31,
    );
    let r = sim.run(&trace, 25.0);
    // neighbour = instance 1
    let neighbour = &r.monitors[1];
    let thr = neighbour.throughput_tokens_per_s(r.duration_s);
    let lat = neighbour.latency_summary().mean();
    (thr, lat)
}

fn main() {
    println!("§8 — scaling interference on a neighbouring instance (25 RPS)\n");
    let (thr_base, lat_base) = run(false);
    let (thr_scaled, lat_scaled) = run(true);
    let thr_fluct = (thr_scaled - thr_base).abs() / thr_base * 100.0;
    let lat_jitter = (lat_scaled - lat_base).abs() / lat_base * 100.0;

    let mut t = Table::new(&["neighbour metric", "no scaling", "with scaling", "delta"]);
    t.row(&[
        "throughput (tok/s)".into(),
        format!("{thr_base:.1}"),
        format!("{thr_scaled:.1}"),
        format!("{thr_fluct:.2}%"),
    ]);
    t.row(&[
        "mean latency (s)".into(),
        format!("{lat_base:.3}"),
        format!("{lat_scaled:.3}"),
        format!("{lat_jitter:.2}%"),
    ]);
    t.print();
    println!(
        "\npaper: throughput fluctuation <3%, latency jitter <5% — measured \
         {thr_fluct:.2}% / {lat_jitter:.2}%"
    );
    let mut rep = Report::new("interference");
    rep.set("throughput_fluct_pct", json::num(thr_fluct));
    rep.set("latency_jitter_pct", json::num(lat_jitter));
    println!("report: {}", rep.write().unwrap().display());
}
