//! # CoCoServe — fine-grained LLM serving via dynamic module scaling
//!
//! Reproduction of "Unlock the Potential of Fine-grained LLM Serving via
//! Dynamic Module Scaling" (CS.DC 2025). The library implements the paper's
//! CoCoServe system as the L3 Rust coordinator of a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * module-level **replication** and **migration** primitives ([`ops`]),
//! * declarative **scaling plans** with dry-run costing and an atomic,
//!   rollback-capable **plan executor** ([`plan`], [`ops::PlanExecutor`]),
//! * the modified-Amdahl **speedup model** and the scale-up / scale-down
//!   **auto-scaling planners** ([`autoscale`]),
//! * a continuous-batching **scheduler** with batch splitting across layer
//!   replicas ([`scheduler`]),
//! * a **PJRT runtime** that loads AOT-compiled HLO artifacts and serves a
//!   real (tiny) model end-to-end with Python off the request path
//!   ([`runtime`], [`engine`]),
//! * an **event-driven multi-instance simulator** over A100-calibrated
//!   cost models — a deterministic event kernel ([`sim::events`]) driving
//!   per-instance serving state machines, regenerating the paper's
//!   13B/70B-scale tables and figures ([`sim`]),
//! * a **predictive control plane** — streaming traffic forecasting
//!   (EWMA / Holt / Holt-Winters / burst detection) and horizon capacity
//!   planning that provisions *before* demand arrives, arbitrated with
//!   the reactive fleet controller ([`forecast`]),
//! * a **memory-pressure governor** — elastic KV-pool resizing plus
//!   quantized layer swapping walked as an escalation ladder so governed
//!   instances shed requests only as a last resort ([`mempress`],
//!   [`kvcache`]),
//! * a **deterministic tracing & telemetry layer** — request/op/step
//!   spans, controller decision records, a streaming timeline, Perfetto
//!   trace export, and a kernel self-profiler, all recorded in
//!   simulation time so traces replay byte-identically ([`telemetry`]),
//! * a **traffic scenario library** (steady / diurnal / burst / ramp /
//!   two-tenant mix) for dynamic-load experiments ([`workload`]),
//! * **HFT-like and vLLM-like baselines** over the same substrate
//!   ([`baselines`]).

// CI enforces `cargo clippy -- -D warnings`; the allows below are
// deliberate idiom choices (index loops mirror the paper's per-layer
// math; the Algorithm 2 signature follows the paper's parameter list),
// not suppressed findings.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
// Every public item carries rustdoc: the burn-down that started in the
// scaling-API surface (`cluster`, `coordinator`, `placement`, `plan` —
// PR 4) and proceeded through the control/telemetry surface
// (`autoscale`, `forecast`, `monitor`, `sim`, `workload` — PR 5), the
// memory surface (`kvcache`, `mempress`, `model` — PR 7), the
// plan-execution surface (`ops` — PR 8) and the batching surface
// (`scheduler` — PR 9) finished with `config`, `engine`, `runtime` and
// `util` in PR 10. No per-module allows remain — CI's
// `RUSTDOCFLAGS="-D warnings"` holds the whole crate to it.
#![warn(missing_docs)]

pub mod autoscale;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod forecast;
pub mod kvcache;
pub mod mempress;
pub mod model;
pub mod monitor;
pub mod ops;
pub mod placement;
pub mod plan;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workload;

/// The README's code blocks compile and run as doctests, so the quickstart
/// snippet in README.md can never drift from the real API.
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub struct ReadmeDoctests;
