//! The modified-Amdahl speedup model (§4.1, Eqs. 1–4).
//!
//! The paper models a replication strategy `P = [p_1 … p_n]` (per-layer
//! parallelism degrees) with:
//!
//! * Eq. 1 — computation term
//!   `W(P) = Σ_i max_j d²·bs_ij·l / C_ij`
//! * Eq. 2 — communication term
//!   `T(P) = δ · Σ_i Σ_{j=1}^{p_i−1} d·bs_ij·l / B_ij`
//! * Eq. 3 — speedup `S(P) = W(P₀) / (W(P) + T(P))`
//! * Eq. 4 — homogeneous closed form
//!   `S_homo(P) = 1 / (γ + (1−γ)/n · Σ_i 1/p_i)`, `γ = δ·C/(d·B)`
//!
//! `W` and `T` are *proportional* to (not equal to) real times — only
//! ratios matter (the paper says so explicitly). Eq. 4's γ is clamped to
//! [0, 1): γ ≥ 1 would mean communication alone costs more than the
//! entire sequential computation, at which point replication can't help.

/// Cluster/strategy description for the heterogeneous model (Eqs. 1–3).
#[derive(Debug, Clone)]
pub struct HeteroStrategy {
    /// Model dimension d.
    pub d_model: f64,
    /// Final sequence length l.
    pub seq_len: f64,
    /// Non-consecutive-transition constant δ (Eq. 2).
    pub delta: f64,
    /// Per layer i, per replica j: batch share bs_ij.
    pub batch_share: Vec<Vec<f64>>,
    /// Per layer i, per replica j: compute capacity C_ij (FLOPs/s).
    pub compute: Vec<Vec<f64>>,
    /// Per layer i, per replica j (j ≥ 1): bandwidth B_ij to replica j.
    pub bandwidth: Vec<Vec<f64>>,
}

impl HeteroStrategy {
    /// Eq. 1: W(P) = Σ_i max_j d²·bs_ij·l / C_ij.
    pub fn w(&self) -> f64 {
        let d2l = self.d_model * self.d_model * self.seq_len;
        self.batch_share
            .iter()
            .zip(&self.compute)
            .map(|(bs, c)| {
                bs.iter()
                    .zip(c)
                    .map(|(b, cap)| d2l * b / cap)
                    .fold(0.0_f64, f64::max)
            })
            .sum()
    }

    /// Eq. 2: T(P) = δ · Σ_i Σ_{j≥1} d·bs_ij·l / B_ij.
    ///
    /// The inner sum runs over the p_i − 1 *replicas* (j ≥ 1): the primary
    /// needs no transfer.
    pub fn t(&self) -> f64 {
        let dl = self.d_model * self.seq_len;
        self.delta
            * self
                .batch_share
                .iter()
                .zip(&self.bandwidth)
                .map(|(bs, bw)| {
                    bs.iter()
                        .skip(1)
                        .zip(bw)
                        .map(|(b, band)| dl * b / band)
                        .sum::<f64>()
                })
                .sum::<f64>()
    }

    /// Eq. 3: S(P) = W(P₀) / (W(P) + T(P)) where P₀ is the same workload
    /// fully sequential on the primary devices.
    pub fn speedup(&self) -> f64 {
        let p0 = HeteroStrategy {
            batch_share: self
                .batch_share
                .iter()
                .map(|bs| vec![bs.iter().sum::<f64>()])
                .collect(),
            compute: self.compute.iter().map(|c| vec![c[0]]).collect(),
            bandwidth: self.bandwidth.iter().map(|_| vec![]).collect(),
            ..self.clone()
        };
        p0.w() / (self.w() + self.t())
    }
}

/// γ = δ·C/(d·B) — the homogeneous cluster constant of Eq. 4. Clamped to
/// [0, 1) (see module docs).
pub fn gamma(delta: f64, compute: f64, d_model: f64, bandwidth: f64) -> f64 {
    (delta * compute / (d_model * bandwidth)).clamp(0.0, 0.999_999)
}

/// Eq. 4: S_homo(P) = 1 / (γ + (1−γ)/n · Σ 1/p_i).
pub fn s_homo(gamma: f64, p: &[usize]) -> f64 {
    assert!(!p.is_empty());
    let n = p.len() as f64;
    let inv_sum: f64 = p.iter().map(|&pi| 1.0 / pi as f64).sum();
    1.0 / (gamma + (1.0 - gamma) / n * inv_sum)
}

/// Eq. 4 via the pre-computed ‖1 ⊘ P‖₁ (Algorithm 1's incremental form).
pub fn s_homo_from_norm(gamma: f64, n: usize, inv_p_norm: f64) -> f64 {
    1.0 / (gamma + (1.0 - gamma) / n as f64 * inv_p_norm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn sequential_strategy_speedup_is_one() {
        assert!((s_homo(0.1, &[1; 40]) - 1.0).abs() < 1e-12);
        let h = HeteroStrategy {
            d_model: 5120.0,
            seq_len: 256.0,
            delta: 1.0,
            batch_share: vec![vec![15.0]; 4],
            compute: vec![vec![1e14]; 4],
            bandwidth: vec![vec![]; 4],
        };
        assert!((h.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn full_replication_approaches_p_over_gamma_limit() {
        // γ → 0: S → p for uniform p (pure Amdahl with a=1).
        let s = s_homo(0.0, &[4; 40]);
        assert!((s - 4.0).abs() < 1e-9, "{s}");
        // γ > 0 bounds the speedup below p.
        let s = s_homo(0.1, &[4; 40]);
        assert!(s < 4.0 && s > 1.0);
    }

    #[test]
    fn partial_replication_interpolates() {
        // replicate half the layers at p=2: Amdahl with a=0.5, p=2 → 4/3.
        let mut p = vec![1usize; 40];
        for pi in p.iter_mut().take(20) {
            *pi = 2;
        }
        let s = s_homo(0.0, &p);
        assert!((s - 4.0 / 3.0).abs() < 1e-9, "{s}");
    }

    /// §4.1: "speedup exhibits a positive correlation with both the number
    /// of [replicated] modules and the degree of parallelism".
    #[test]
    fn monotone_in_replication_count_and_degree() {
        let g = 0.05;
        let mut prev = 0.0;
        for k in 0..=40 {
            let mut p = vec![1usize; 40];
            for pi in p.iter_mut().take(k) {
                *pi = 2;
            }
            let s = s_homo(g, &p);
            assert!(s >= prev, "k={k}: {s} < {prev}");
            prev = s;
        }
        let mut prev = 0.0;
        for dop in 1..=8 {
            let s = s_homo(g, &vec![dop; 40]);
            assert!(s > prev, "dop={dop}");
            prev = s;
        }
    }

    #[test]
    fn diminishing_returns_in_dop() {
        // marginal gain of dop k→k+1 shrinks — the Fig. 6c plateau.
        let g = 0.05;
        let s: Vec<f64> = (1..=5).map(|d| s_homo(g, &vec![d; 40])).collect();
        for w in s.windows(3) {
            assert!(w[2] - w[1] < w[1] - w[0]);
        }
    }

    #[test]
    fn hetero_reduces_to_homo_for_uniform_cluster() {
        let d = 5120.0;
        let l = 256.0;
        let cap = 1.4e14;
        let bw = 1.0e11;
        let delta = 2.0;
        let n = 8;
        let p = 2usize;
        // even batch split over p replicas on identical devices
        let h = HeteroStrategy {
            d_model: d,
            seq_len: l,
            delta,
            batch_share: vec![vec![7.5; p]; n],
            compute: vec![vec![cap; p]; n],
            bandwidth: vec![vec![bw; p - 1]; n],
        };
        // γ per Eq. 4 (bs cancels in W ratio; T carries bs·δ·d·l/B, W₀
        // carries bs·d²·l/C — γ = δ·C/(d·B) after normalization).
        let g = gamma(delta, cap, d, bw);
        let want = s_homo(g, &vec![p; n]);
        let got = h.speedup();
        assert!((got - want).abs() / want < 0.05, "hetero {got} vs homo {want}");
    }

    #[test]
    fn hetero_penalizes_slow_replica() {
        // A replica on a device 10× slower dominates the max() in W.
        let base = HeteroStrategy {
            d_model: 512.0,
            seq_len: 64.0,
            delta: 1.0,
            batch_share: vec![vec![8.0, 8.0]; 4],
            compute: vec![vec![1e13, 1e13]; 4],
            bandwidth: vec![vec![1e11]; 4],
        };
        let mut slow = base.clone();
        for c in &mut slow.compute {
            c[1] = 1e12;
        }
        assert!(slow.speedup() < base.speedup());
    }

    #[test]
    fn gamma_clamped() {
        assert_eq!(gamma(1000.0, 1e15, 512.0, 1e3), 0.999_999);
        assert_eq!(gamma(0.0, 1e15, 512.0, 1e9), 0.0);
    }

    #[test]
    fn prop_s_homo_bounds() {
        // 1 ≤ S ≤ max(p) and S(P₀) = 1 for any γ ∈ [0,1).
        prop::check(
            "s-homo-bounds",
            |r: &mut Rng| {
                let n = 1 + r.below(64) as usize;
                let p: Vec<usize> = (0..n).map(|_| 1 + r.below(8) as usize).collect();
                let g = r.f64() * 0.9;
                (p, g)
            },
            |(p, g)| {
                let s = s_homo(*g, p);
                let pmax = *p.iter().max().unwrap() as f64;
                if !(0.999_999..=pmax + 1e-9).contains(&s) {
                    return Err(format!("S={s} out of [1, {pmax}]"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn norm_form_matches_direct_form() {
        let p = [1usize, 2, 4, 1, 3];
        let norm: f64 = p.iter().map(|&x| 1.0 / x as f64).sum();
        assert!(
            (s_homo(0.2, &p) - s_homo_from_norm(0.2, p.len(), norm)).abs() < 1e-12
        );
    }
}
