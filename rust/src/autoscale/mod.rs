//! The dynamic auto-scaling mechanism (§4) — CoCoServe's core contribution.
//!
//! * [`speedup`] — the modified-Amdahl model, Eqs. 1–4,
//! * [`scale_up`] — Algorithm 1: greedy continuity-sorted layer replication,
//! * [`scale_down`] — Algorithm 2: migrate → evict → reduce, graduated,
//! * [`controller`] — the §5 threshold controller closing the loop with
//!   the monitor.

pub mod controller;
pub mod scale_down;
pub mod scale_up;
pub mod speedup;

pub use controller::{Controller, ControllerConfig, ControllerInputs, Decision};
pub use scale_down::{scale_down, Pressure, ScaleDownConfig, ScaleDownOutcome};
pub use scale_up::{scale_up, ScaleUpConfig, ScaleUpOutcome};
