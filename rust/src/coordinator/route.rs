//! Cross-instance request routing — the fleet's front door.
//!
//! Arrivals land at the coordinator, not at a fixed instance: the event
//! kernel pops an `Arrival`, asks the [`Router`] to pick a serving
//! instance, and dispatches the request as a `Routed` event to that
//! instance. The policy is pluggable ([`RoutePolicy`]) and every decision
//! is deterministic: candidates are examined in ascending instance-id
//! order and every comparison breaks ties toward the lower id, so the same
//! trace always produces the same routing sequence (the fleet golden-replay
//! contract).
//!
//! ### Backpressure
//!
//! Each instance may carry an admission limit (max outstanding requests).
//! When no instance can admit, the request parks in the router's FIFO
//! [`Router::pending`] queue and is retried after every kernel event — the
//! first instance to free capacity drains the queue head. Requests shed by
//! an instance's OOM handling can likewise be handed back for re-routing
//! (see `sim::instance`), which is what lets a fleet survive a single
//! instance's memory cliff without failing the requests outright.
//!
//! ### Barrier-time routing (sharded kernel)
//!
//! Under the sharded event kernel (`SimConfig::shards ≥ 2`), arrivals
//! are *global* events — epoch barriers — so every routing decision is
//! made coordinator-side at a barrier, over candidate state that all
//! shards have fully caught up to. The router itself never observes a
//! half-drained shard. Combined with the deterministic scan order below,
//! this is why the sharded kernel's routing sequence (and hence its
//! metrics JSON) is byte-identical to the sequential kernel's.

use std::collections::VecDeque;

use crate::workload::{Request, SloClass};

/// How the coordinator picks a serving instance for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through admitting instances in id order. Oblivious to load —
    /// the baseline policy real gateways start from.
    RoundRobin,
    /// The instance with the fewest outstanding requests (pending +
    /// running + already-routed-but-undelivered); ties go to the lowest
    /// id. This reproduces the pre-fleet kernel's least-loaded dispatch.
    LeastOutstanding,
    /// The instance whose device set has the most free ledger bytes —
    /// KV-cache headroom — so long decodes land where their cache can
    /// grow; ties go to the lowest id.
    KvHeadroom,
    /// Class-aware strict priority: instance selection is
    /// least-outstanding, but the parked queue always serves
    /// latency-sensitive entries before any best-effort entry, and
    /// best-effort admission is additionally capped by
    /// [`RouterConfig::be_admission_limit`]. At equal arrival times a
    /// premium request can never queue behind a best-effort one
    /// (no-inversion — asserted by the `slo_props` property harness).
    StrictPriority,
    /// Class-aware weighted fair queuing: instance selection is
    /// least-outstanding; the parked queue is served by deficit-style
    /// virtual time — each dispatch of class `c` advances `c`'s virtual
    /// service by `1/weight(c)`, and the next dispatch goes to the
    /// backlogged class with the least virtual service (ties to the
    /// premium class). Long-run service shares of continuously
    /// backlogged classes converge to the configured
    /// [`RouterConfig::wfq_premium_weight`] :
    /// [`RouterConfig::wfq_be_weight`] ratio.
    WeightedFair,
}

impl RoutePolicy {
    /// Does this policy consult [`SloClass`] at all? Classless policies
    /// (`RoundRobin` / `LeastOutstanding` / `KvHeadroom`) never read the
    /// class, never reorder the parked queue, and never apply the
    /// per-class admission cap — the byte-identity guarantee for every
    /// pre-existing golden rests on this predicate.
    pub fn class_aware(self) -> bool {
        matches!(self, RoutePolicy::StrictPriority | RoutePolicy::WeightedFair)
    }
}

/// Routing configuration for a simulation run.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Instance-selection policy.
    pub policy: RoutePolicy,
    /// Max outstanding requests an instance may hold before the router
    /// stops offering it new work (`None` = unlimited, the legacy
    /// behaviour).
    pub admission_limit: Option<usize>,
    /// Hand requests shed by an instance's OOM handling back to the
    /// router for re-routing instead of requeueing them locally.
    pub reroute_on_shed: bool,
    /// Per-tenant admission cap for best-effort requests, applied *in
    /// addition to* [`RouterConfig::admission_limit`] and only under a
    /// class-aware policy: a best-effort request is admitted only while
    /// the target instance holds fewer than this many outstanding
    /// requests, reserving the remaining headroom for the premium class.
    /// `None` (the default) leaves best-effort admission ungated.
    pub be_admission_limit: Option<usize>,
    /// Weighted-fair-queuing weight of the latency-sensitive class
    /// (consulted only under [`RoutePolicy::WeightedFair`]). Default 3.
    pub wfq_premium_weight: u32,
    /// Weighted-fair-queuing weight of the best-effort class (consulted
    /// only under [`RoutePolicy::WeightedFair`]). Default 1.
    pub wfq_be_weight: u32,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: false,
            be_admission_limit: None,
            wfq_premium_weight: 3,
            wfq_be_weight: 1,
        }
    }
}

/// One instance's routing-relevant state, snapshotted by the kernel at
/// decision time.
#[derive(Debug, Clone, Copy)]
pub struct RouteCandidate {
    /// Is the instance accepting new work (active, past its cold start,
    /// not draining)?
    pub accepting: bool,
    /// Outstanding requests: scheduler pending + running + routed-but-
    /// undelivered.
    pub outstanding: usize,
    /// Free ledger bytes summed over the instance's device set (the
    /// KV-headroom signal).
    pub free_bytes: f64,
}

/// A request parked at the router under admission backpressure.
#[derive(Debug, Clone, Copy)]
pub struct Parked {
    /// The request itself (original arrival time preserved).
    pub req: Request,
    /// OOM-reload penalty the request carries from a previous instance.
    pub penalty: f64,
    /// Was this a shed re-route (vs. a first-time arrival)?
    pub reroute: bool,
}

/// The fleet's request router: policy + admission backpressure + the
/// parked-request queue.
#[derive(Debug)]
pub struct Router {
    /// Routing configuration this router was built with.
    pub cfg: RouterConfig,
    /// Requests no instance could admit, in arrival order. Retried after
    /// every kernel event (class-aware policies reorder *service*, never
    /// the stored arrival order).
    pub pending: VecDeque<Parked>,
    /// Round-robin cursor (next instance id to try first).
    cursor: usize,
    /// First-time routing decisions made (each trace arrival counts once).
    pub routes: u64,
    /// Re-routing decisions for shed requests.
    pub reroutes: u64,
    /// Routing decisions (first-time + re-route) per class, indexed by
    /// [`Router::class_idx`]. Maintained unconditionally — cheap — but
    /// surfaced in the metrics JSON only when a class-aware policy is
    /// configured, so classless goldens never see it.
    pub class_routes: [u64; 2],
    /// Weighted-fair-queuing virtual service per class, indexed by
    /// [`Router::class_idx`]: each parked dispatch of class `c` adds
    /// `1/weight(c)`. Only [`RoutePolicy::WeightedFair`] reads or
    /// advances it.
    wfq_served: [f64; 2],
}

impl Router {
    /// Build a router with the given configuration.
    pub fn new(cfg: RouterConfig) -> Router {
        Router {
            cfg,
            pending: VecDeque::new(),
            cursor: 0,
            routes: 0,
            reroutes: 0,
            class_routes: [0; 2],
            wfq_served: [0.0; 2],
        }
    }

    /// Stable per-class array index: 0 = latency-sensitive, 1 =
    /// best-effort.
    pub fn class_idx(class: SloClass) -> usize {
        match class {
            SloClass::LatencySensitive => 0,
            SloClass::BestEffort => 1,
        }
    }

    /// Park a request that no instance could admit; the kernel retries the
    /// queue after every event (head-first classless, policy-ordered under
    /// a class-aware policy — see [`Router::next_parked`]).
    pub fn park(&mut self, req: Request, penalty: f64, reroute: bool) {
        self.pending.push_back(Parked { req, penalty, reroute });
    }

    /// Can this candidate admit one more request of `class` under the
    /// configured backpressure limits? The per-class best-effort cap
    /// applies only under a class-aware policy, so classless
    /// configurations never consult the request's class.
    fn admits(&self, c: &RouteCandidate, class: SloClass) -> bool {
        if !c.accepting {
            return false;
        }
        if let Some(limit) = self.cfg.admission_limit {
            if c.outstanding >= limit {
                return false;
            }
        }
        if self.cfg.policy.class_aware() && class == SloClass::BestEffort {
            if let Some(limit) = self.cfg.be_admission_limit {
                if c.outstanding >= limit {
                    return false;
                }
            }
        }
        true
    }

    /// Pick an instance for one request of `class`, or `None` when every
    /// instance is saturated (the caller parks the request in
    /// [`Router::pending`]). Deterministic: candidates scan in ascending
    /// id order; every policy breaks ties toward the lower id
    /// (round-robin toward the cursor). The class-aware policies select
    /// instances exactly like [`RoutePolicy::LeastOutstanding`] — their
    /// class-awareness lives in [`Router::admits`] and
    /// [`Router::next_parked`], not the instance scan.
    pub fn pick(&mut self, candidates: &[RouteCandidate], class: SloClass) -> Option<usize> {
        let n = candidates.len();
        if n == 0 {
            return None;
        }
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                for k in 0..n {
                    let i = (self.cursor + k) % n;
                    if self.admits(&candidates[i], class) {
                        self.cursor = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            RoutePolicy::LeastOutstanding
            | RoutePolicy::StrictPriority
            | RoutePolicy::WeightedFair => candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| self.admits(c, class))
                .min_by_key(|&(i, c)| (c.outstanding, i))
                .map(|(i, _)| i),
            RoutePolicy::KvHeadroom => candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| self.admits(c, class))
                // max free bytes; total_cmp is a total order so ties fall
                // to the lower id via min_by's first-wins semantics
                .min_by(|(ia, a), (ib, b)| {
                    b.free_bytes.total_cmp(&a.free_bytes).then(ia.cmp(ib))
                })
                .map(|(i, _)| i),
        }
    }

    /// Index into [`Router::pending`] of the entry the policy serves
    /// next, or `None` when the queue is empty.
    ///
    /// * Classless policies: always the head (index 0) — arrival-order
    ///   FIFO, bit-identical to the pre-class drain loop.
    /// * [`RoutePolicy::StrictPriority`]: the first latency-sensitive
    ///   entry if any exists, else the head.
    /// * [`RoutePolicy::WeightedFair`]: the first entry of the backlogged
    ///   class with the least virtual service (`served/weight` deficit;
    ///   ties to the premium class). Within a class, arrival order.
    pub fn next_parked(&self) -> Option<usize> {
        if self.pending.is_empty() {
            return None;
        }
        match self.cfg.policy {
            RoutePolicy::RoundRobin
            | RoutePolicy::LeastOutstanding
            | RoutePolicy::KvHeadroom => Some(0),
            RoutePolicy::StrictPriority => Some(
                self.pending
                    .iter()
                    .position(|p| p.req.class == SloClass::LatencySensitive)
                    .unwrap_or(0),
            ),
            RoutePolicy::WeightedFair => {
                let first_of = |class: SloClass| {
                    self.pending.iter().position(|p| p.req.class == class)
                };
                let premium = first_of(SloClass::LatencySensitive);
                let be = first_of(SloClass::BestEffort);
                match (premium, be) {
                    (Some(p), Some(b)) => {
                        // least virtual service first; the tie (exact
                        // float equality, e.g. both at 0 on an empty
                        // ledger) goes to the premium class
                        let idx_p = Self::class_idx(SloClass::LatencySensitive);
                        let idx_b = Self::class_idx(SloClass::BestEffort);
                        if self.wfq_served[idx_p] <= self.wfq_served[idx_b] {
                            Some(p)
                        } else {
                            Some(b)
                        }
                    }
                    (Some(p), None) => Some(p),
                    (None, Some(b)) => Some(b),
                    (None, None) => None,
                }
            }
        }
    }

    /// Remove and return the parked entry at `idx` (chosen by
    /// [`Router::next_parked`]), advancing the weighted-fair virtual
    /// service of its class when the WFQ policy is active.
    pub fn take_parked(&mut self, idx: usize) -> Parked {
        let parked = self.pending.remove(idx).expect("parked index in range");
        if self.cfg.policy == RoutePolicy::WeightedFair {
            let k = Self::class_idx(parked.req.class);
            let weight = match parked.req.class {
                SloClass::LatencySensitive => self.cfg.wfq_premium_weight,
                SloClass::BestEffort => self.cfg.wfq_be_weight,
            };
            self.wfq_served[k] += 1.0 / f64::from(weight.max(1));
        }
        parked
    }

    /// Parked requests of the given class (the premium backlog is a
    /// per-class capacity-planning input).
    pub fn parked_of(&self, class: SloClass) -> usize {
        self.pending.iter().filter(|p| p.req.class == class).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BE: SloClass = SloClass::BestEffort;
    const LS: SloClass = SloClass::LatencySensitive;

    fn cand(outstanding: usize, free_bytes: f64) -> RouteCandidate {
        RouteCandidate { accepting: true, outstanding, free_bytes }
    }

    fn router(policy: RoutePolicy, limit: Option<usize>) -> Router {
        Router::new(RouterConfig {
            policy,
            admission_limit: limit,
            ..RouterConfig::default()
        })
    }

    fn req(id: u64, class: SloClass) -> Request {
        Request {
            id,
            arrival_s: id as f64,
            prompt_tokens: 8,
            output_tokens: 4,
            class,
        }
    }

    #[test]
    fn round_robin_cycles_in_id_order() {
        let mut r = router(RoutePolicy::RoundRobin, None);
        let c = vec![cand(0, 0.0); 3];
        let picks: Vec<_> = (0..5).map(|_| r.pick(&c, BE).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn round_robin_skips_saturated_instances() {
        let mut r = router(RoutePolicy::RoundRobin, Some(4));
        let c = vec![cand(4, 0.0), cand(1, 0.0), cand(4, 0.0)];
        assert_eq!(r.pick(&c, BE), Some(1));
        assert_eq!(r.pick(&c, BE), Some(1), "only instance 1 admits");
    }

    #[test]
    fn least_outstanding_ties_to_lowest_id() {
        let mut r = router(RoutePolicy::LeastOutstanding, None);
        let c = vec![cand(3, 0.0), cand(1, 0.0), cand(1, 0.0)];
        assert_eq!(r.pick(&c, BE), Some(1));
        let even = vec![cand(2, 0.0); 4];
        assert_eq!(r.pick(&even, BE), Some(0));
    }

    #[test]
    fn kv_headroom_prefers_most_free_bytes() {
        let mut r = router(RoutePolicy::KvHeadroom, None);
        let c = vec![cand(0, 1.0), cand(0, 9.0), cand(0, 9.0)];
        assert_eq!(r.pick(&c, BE), Some(1), "ties break to the lower id");
    }

    #[test]
    fn saturation_returns_none() {
        let mut r = router(RoutePolicy::LeastOutstanding, Some(2));
        let c = vec![cand(2, 0.0), cand(5, 0.0)];
        assert_eq!(r.pick(&c, BE), None);
    }

    #[test]
    fn replayed_candidate_stream_routes_identically() {
        // The golden-replay contract: two routers fed the same candidate
        // snapshots make the same decisions — including hidden cursor
        // state. This is what barrier-time routing leans on for parity.
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastOutstanding,
            RoutePolicy::KvHeadroom,
            RoutePolicy::StrictPriority,
            RoutePolicy::WeightedFair,
        ] {
            let mut a = router(policy, Some(3));
            let mut b = router(policy, Some(3));
            let mut seed = 0x9e3779b97f4a7c15u64;
            for step in 0..200 {
                let class = if step % 3 == 0 { LS } else { BE };
                let c: Vec<_> = (0..4u64)
                    .map(|i| {
                        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(i + 1);
                        cand((seed >> 60) as usize % 4, (seed >> 32) as f64)
                    })
                    .collect();
                assert_eq!(
                    a.pick(&c, class),
                    b.pick(&c, class),
                    "{policy:?} diverged at step {step}"
                );
            }
        }
    }

    #[test]
    fn non_accepting_instances_are_skipped() {
        let mut r = router(RoutePolicy::LeastOutstanding, None);
        let mut c = vec![cand(0, 0.0), cand(9, 0.0)];
        c[0].accepting = false;
        assert_eq!(r.pick(&c, BE), Some(1));
        c[1].accepting = false;
        assert_eq!(r.pick(&c, BE), None);
        assert_eq!(r.pick(&[], BE), None);
    }

    #[test]
    fn classless_policies_ignore_class_and_serve_head_first() {
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::KvHeadroom]
        {
            let mut r = Router::new(RouterConfig {
                policy,
                be_admission_limit: Some(1), // must be ignored classless
                ..RouterConfig::default()
            });
            let c = vec![cand(5, 0.0)];
            assert_eq!(r.pick(&c, LS), r.pick(&c, BE), "{policy:?} read the class");
            r.park(req(0, BE), 0.0, false);
            r.park(req(1, LS), 0.0, false);
            assert_eq!(r.next_parked(), Some(0), "{policy:?} must stay FIFO");
        }
    }

    #[test]
    fn strict_priority_serves_premium_parked_entries_first() {
        let mut r = router(RoutePolicy::StrictPriority, None);
        r.park(req(0, BE), 0.0, false);
        r.park(req(1, BE), 0.0, false);
        r.park(req(2, LS), 0.0, false);
        assert_eq!(r.next_parked(), Some(2), "premium jumps the queue");
        let taken = r.take_parked(2);
        assert_eq!(taken.req.id, 2);
        assert_eq!(r.next_parked(), Some(0), "then best-effort in arrival order");
    }

    #[test]
    fn be_admission_limit_reserves_headroom_for_premium() {
        let mut r = Router::new(RouterConfig {
            policy: RoutePolicy::StrictPriority,
            admission_limit: Some(8),
            be_admission_limit: Some(2),
            ..RouterConfig::default()
        });
        let c = vec![cand(2, 0.0)];
        assert_eq!(r.pick(&c, BE), None, "best-effort capped at 2");
        assert_eq!(r.pick(&c, LS), Some(0), "premium keeps the headroom");
        let full = vec![cand(8, 0.0)];
        assert_eq!(r.pick(&full, LS), None, "the shared limit still binds");
    }

    #[test]
    fn weighted_fair_shares_track_weights() {
        let mut r = Router::new(RouterConfig {
            policy: RoutePolicy::WeightedFair,
            wfq_premium_weight: 3,
            wfq_be_weight: 1,
            ..RouterConfig::default()
        });
        // keep both classes continuously backlogged; count dispatches
        let mut served = [0usize; 2];
        let mut next_id = 0u64;
        for class in [LS, LS, BE, BE] {
            r.park(req(next_id, class), 0.0, false);
            next_id += 1;
        }
        for _ in 0..400 {
            let idx = r.next_parked().unwrap();
            let taken = r.take_parked(idx);
            served[Router::class_idx(taken.req.class)] += 1;
            r.park(req(next_id, taken.req.class), 0.0, false); // stays backlogged
            next_id += 1;
        }
        let share = served[0] as f64 / (served[0] + served[1]) as f64;
        assert!(
            (share - 0.75).abs() < 0.02,
            "premium share {share} should track weight 3:1"
        );
    }

    #[test]
    fn weighted_fair_drains_lone_class_without_starving() {
        let mut r = router(RoutePolicy::WeightedFair, None);
        r.park(req(0, BE), 0.0, false);
        r.park(req(1, BE), 0.0, false);
        assert_eq!(r.next_parked(), Some(0), "only best-effort parked: serve it");
        r.take_parked(0);
        r.park(req(2, LS), 0.0, false);
        // premium virtual service (0) ≤ best-effort's — premium goes next
        let idx = r.next_parked().unwrap();
        assert_eq!(r.pending[idx].req.class, LS);
    }
}
