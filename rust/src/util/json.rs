//! Minimal JSON (std-only serde_json replacement).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`,
//! experiment configs, and writes metric/benchmark reports. Supports the
//! full JSON grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number; integers are stored losslessly up to 2^53.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — rendering is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where it occurred.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset into the input where parsing failed.
    pub pos: usize,
    /// Human-readable description of what was expected.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// The `&str` if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number if this is numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as `u64` if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as u64) } else { None }
        })
    }

    /// [`Json::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    /// The boolean if this is `true`/`false`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value map if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["key"]` with a readable panic message for required fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key `{key}`"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            cp = cp * 16 + (c as char).to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let ch = chunk.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- writing ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                // JSON has no NaN/Infinity literals; emit null so exported
                // files (notably Perfetto traces) always stay parseable.
                if !n.is_finite() {
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Build an object from `(key, value)` pairs (convenience for reports).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Wrap a number.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Wrap a string.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Collect values into an array.
pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 2);
        assert_eq!(j.req("c").as_str(), Some("x"));
        assert_eq!(j.req("a").as_arr().unwrap()[1].req("b"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        let j = Json::parse("\"\\u00e9 caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{e9} caf\u{e9}"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn as_u64_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }

    #[test]
    fn non_finite_numbers_render_as_null() {
        // `{n}` on a non-finite f64 would print NaN/inf — not JSON, and
        // Perfetto rejects the whole trace file. Pin the null fallback.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let j = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)]);
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn trace_exporter_number_edge_cases() {
        // Negative zero must not print a sign (byte-determinism across
        // platforms) and zero-duration spans print as plain integers.
        assert_eq!(Json::Num(-0.0).to_string(), "0");
        assert_eq!(Json::Num(0.0).to_string(), "0");
        // Microsecond timestamps: sim seconds x 1e6 stays integral.
        assert_eq!(Json::Num(1.5 * 1e6).to_string(), "1500000");
        // Sub-integer durations keep their fraction and round-trip.
        assert_eq!(Json::Num(0.25).to_string(), "0.25");
        let back = Json::parse(&Json::Num(0.1 + 0.2).to_string()).unwrap();
        assert_eq!(back.as_f64(), Some(0.1 + 0.2));
        // Negative durations (clamped upstream, but must still be valid).
        assert_eq!(Json::Num(-3.0).to_string(), "-3");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
        // Beyond the i64 fast path falls through to `{n}` and stays valid.
        let big = Json::Num(1e18).to_string();
        assert!(Json::parse(&big).unwrap().as_f64() == Some(1e18));
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let m = Json::parse(&text).expect("manifest parses");
            assert_eq!(m.req("format").as_u64(), Some(1));
            assert!(!m.req("artifacts").as_arr().unwrap().is_empty());
        }
    }
}
