//! Fig. 9 — memory utilization / fragmentation comparison.
//!
//! Paper claims: CoCoServe wastes 5.3 GB less than HFT and 3.2 GB less than
//! vLLM on a 40 GB A100; fragmentation reduced 3.12× vs HFT and 2.28× vs
//! vLLM; 37.5 GB effectively usable for serving.
//!
//! Mechanisms reproduced: HFT's contiguous max-length KV reservation wastes
//! (max_len − actual) per sequence; vLLM's paged allocator wastes only
//! partial blocks but cannot use the fragments *across* devices; CoCoServe
//! pages *and* harvests cross-device fragments via module placement.
//!
//! The three systems serve identical traces through the event kernel
//! across all five scenario shapes of the workload library (steady /
//! diurnal / burst / ramp / two-tenant). Asserted per scenario:
//! (a) the contiguous allocator's waste strictly exceeds the paged
//!     allocators' (the Fig. 9 mechanism, not a tuned constant);
//! (b) HFT's fragmentation strictly exceeds CoCoServe's;
//! (c) every cell golden-replays byte-identically.
//!
//! ```bash
//! cargo bench --bench fig9_memory            # full sweep
//! FIG9_SMOKE=1 cargo bench --bench fig9_memory  # CI smoke (steady only)
//! ```

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::Trace;

const SEED: u64 = 9;
const RPS: f64 = 30.0;
const DURATION_S: f64 = 20.0;

fn run(policy: SimPolicy, devices: usize, trace: &Trace) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(devices, DeviceSpec::a100_40gb());
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    Simulation::new(cfg, cluster, vec![(placement, policy)]).run(trace, DURATION_S)
}

fn main() {
    let smoke = std::env::var("FIG9_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke");
    println!(
        "Fig. 9 — KV memory waste & fragmentation (13B @ {RPS:.0} RPS{})\n",
        if smoke { ", SMOKE" } else { "" }
    );

    let scenarios: Vec<(&str, Trace)> = if smoke {
        vec![("steady", Trace::steady(RPS, DURATION_S, SEED))]
    } else {
        vec![
            ("steady", Trace::steady(RPS, DURATION_S, SEED)),
            ("diurnal", Trace::diurnal(RPS, DURATION_S, SEED)),
            ("burst", Trace::burst(RPS, DURATION_S, SEED)),
            ("ramp", Trace::ramp(RPS, DURATION_S, SEED)),
            ("two_tenant", Trace::two_tenant(RPS, DURATION_S, SEED)),
        ]
    };

    let mut t = Table::new(&[
        "scenario", "system", "kv waste (GiB)", "fragmentation", "peak resident (GiB)",
    ]);
    let mut rep = Report::new("fig9_memory");
    let mut replay_ok = true;

    for (scenario, trace) in &scenarios {
        let mut rows = vec![];
        for (name, policy) in [
            ("HFT (contiguous)", baselines::hft(16)),
            ("vLLM (paged)", baselines::vllm_like(64)),
            ("CoCoServe", baselines::cocoserve(64)),
        ] {
            let r = run(policy, 4, trace);
            // (c) golden replay per cell
            let again = run(policy, 4, trace);
            let identical = r.to_json().to_string() == again.to_json().to_string();
            replay_ok &= identical;
            if !identical {
                eprintln!("WARNING: {scenario}/{name} not replay-deterministic");
            }
            let kv = r.kv_stats[0];
            let (waste, frag, peak) =
                (kv.waste_bytes() / GIB, kv.fragmentation(), r.peak_mem_bytes / GIB);
            t.row(&[
                scenario.to_string(),
                name.to_string(),
                format!("{waste:.2}"),
                format!("{frag:.2}"),
                format!("{peak:.2}"),
            ]);
            rep.set(
                &format!("{scenario}/{name}"),
                json::arr([waste, frag, peak].into_iter().map(json::num)),
            );
            rows.push((waste, frag, peak));
        }

        let (hft_w, hft_f, _) = rows[0];
        let (vllm_w, _, vllm_peak) = rows[1];
        let (coco_w, coco_f, coco_peak) = rows[2];
        // (a) the contiguous reservation mechanism, not a tuned constant
        assert!(
            hft_w > coco_w && hft_w > vllm_w,
            "{scenario}: contiguous waste ({hft_w:.2} GiB) must exceed paged \
             ({vllm_w:.2} / {coco_w:.2} GiB)"
        );
        // (b) paging bounds fragmentation below max-length reservation
        assert!(
            hft_f > coco_f,
            "{scenario}: HFT fragmentation {hft_f:.2} must exceed CoCoServe {coco_f:.2}"
        );

        if *scenario == "steady" {
            // vs vLLM the win is not allocator waste (both page) but
            // *idle-fragment harvesting*: vLLM's instance-level scaling
            // strands the other devices' free memory; CoCoServe's module
            // replication puts it to work.
            let harvested = coco_peak - vllm_peak;
            println!(
                "allocator waste: CoCoServe {:.1} GiB below HFT (paper: 5.3 GB); \
                 fragmentation improves {:.2}× vs HFT (paper: 3.12×).\n\
                 idle-memory harvesting vs vLLM: CoCoServe puts {harvested:.1} GiB \
                 of otherwise-stranded cross-device memory to work as layer \
                 replicas (the paper's 3.2 GB effective-memory edge, amplified \
                 here by 3 idle devices).\n",
                hft_w - coco_w,
                hft_f / coco_f
            );
        }
    }

    t.print();
    println!(
        "\ngolden replay across all cells: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
