//! Fig. 8 — single-instance CoCoServe vs HFT vs vLLM (13B and 70B).
//!
//! Paper setup: one instance on the 4×A100 testbed, low (3–30 RPS) and
//! high (31–50 RPS) workloads, 5 repeats. Claims to reproduce (shape):
//! CoCo < vLLM < HFT latency; CoCo > vLLM > HFT throughput; HFT collapses
//! under high load; CoCo's edge over vLLM grows with load.

use cocoserve::baselines;
use cocoserve::cluster::Cluster;
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const LOW_RPS: [f64; 3] = [3.0, 15.0, 30.0];
const HIGH_RPS: [f64; 3] = [35.0, 42.0, 50.0];
/// 70B weighs 152 GB under the paper's own §3.3 arithmetic — on 4×A100-40GB
/// the KV headroom is ~1 GiB/device, capping feasible request rates far
/// below the 13B sweep (see EXPERIMENTS.md for the scale discussion).
const RPS_70B: [f64; 6] = [0.5, 1.0, 1.5, 2.0, 3.0, 4.0];
const REPEATS: u64 = 3;

fn run(model: &str, policy: SimPolicy, rps: f64) -> (f64, f64) {
    let (mut lat_acc, mut thr_acc) = (0.0, 0.0);
    for seed in 0..REPEATS {
        let cfg = if model == "llama2-70b" {
            SimConfig::paper_70b()
        } else {
            SimConfig::paper_13b()
        };
        let n_layers = cfg.model.n_layers;
        // 70B spans two devices (131 GiB in bf16 > 40 GiB)
        let placement = if model == "llama2-70b" {
            Placement::contiguous_shards(n_layers, &[0, 1, 2, 3])
        } else {
            Placement::single_device(n_layers, 0)
        };
        let sim = Simulation::new(cfg, Cluster::paper_testbed(),
                                  vec![(placement, policy)]);
        let trace = Trace::generate(Arrival::Poisson { rps },
                                    LengthDist::alpaca(), 20.0, 40 + seed);
        let r = sim.run(&trace, 20.0);
        lat_acc += r.merged_latency().mean();
        thr_acc += r.total_throughput_tps();
    }
    (lat_acc / REPEATS as f64, thr_acc / REPEATS as f64)
}

fn sweep(model: &str, rep: &mut Report) {
    println!("--- {model} ---");
    let mut t = Table::new(&["rps", "hft lat", "vllm lat", "coco lat",
                             "hft thr", "vllm thr", "coco thr"]);
    let mut ratios: Vec<(f64, f64, f64, f64)> = vec![];
    let rates: Vec<f64> = if model == "llama2-70b" {
        RPS_70B.to_vec()
    } else {
        LOW_RPS.iter().chain(&HIGH_RPS).copied().collect()
    };
    for &rps in &rates {
        let (hl, ht) = run(model, baselines::hft(16), rps);
        let (vl, vt) = run(model, baselines::vllm_like(128), rps);
        let (cl, ct) = run(model, baselines::cocoserve(128), rps);
        t.row(&[
            format!("{rps:.0}"),
            format!("{hl:.2}"),
            format!("{vl:.2}"),
            format!("{cl:.2}"),
            format!("{ht:.0}"),
            format!("{vt:.0}"),
            format!("{ct:.0}"),
        ]);
        ratios.push((1.0 - cl / hl, 1.0 - cl / vl, ct / ht, ct / vt));
        rep.set(
            &format!("{model}_rps{}", rps as u64),
            json::arr([hl, vl, cl, ht, vt, ct].into_iter().map(json::num)),
        );
    }
    t.print();
    let n = ratios.len() as f64;
    let avg = ratios.iter().fold((0.0, 0.0, 0.0, 0.0), |a, r| {
        (a.0 + r.0 / n, a.1 + r.1 / n, a.2 + r.2 / n, a.3 + r.3 / n)
    });
    println!(
        "\naverages: CoCo latency −{:.0}% vs HFT (paper 57–75%), −{:.0}% vs vLLM \
         (paper 14–32%); throughput {:.2}× HFT (paper 2.1–4×), {:.2}× vLLM \
         (paper 1.16–1.48×)\n",
        avg.0 * 100.0,
        avg.1 * 100.0,
        avg.2,
        avg.3
    );
}

fn main() {
    println!("Fig. 8 — single instance, CoCoServe vs HFT vs vLLM\n");
    let mut rep = Report::new("fig8_single_instance");
    sweep("llama2-13b", &mut rep);
    sweep("llama2-70b", &mut rep);
    println!("report: {}", rep.write().unwrap().display());
}
