//! Minimal, std-only stand-in for the `anyhow` crate.
//!
//! The build environment is offline, so instead of the real crate we vendor
//! the small surface this workspace actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`ensure!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Semantics match `anyhow` where it
//! matters here:
//!
//! * `Error` is an opaque, context-carrying error value. `{}` prints the
//!   outermost message; `{:#}` (and `Debug`) print the whole chain.
//! * `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what lets the blanket `From<E: std::error::Error>` conversion (and
//!   thus `?`) exist without overlapping the reflexive `From` impl.

use std::fmt;

/// Opaque error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Anything `.context(..)` can wrap: std errors and [`crate::Error`]
    /// itself. Sealed — the two impls below are coherent only because
    /// `crate::Error` does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// (with either a std error or an [`Error`]) and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("parsing a number")?;
        ensure!(n < 100, "{n} too large");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "parsing a number");
        assert!(format!("{e:#}").contains("invalid digit"));
    }

    #[test]
    fn ensure_formats_message() {
        let e = parse("500").unwrap_err();
        assert_eq!(format!("{e}"), "500 too large");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
        assert!(format!("{e:#}").starts_with("step 3: "));
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn anyhow_macro_forms() {
        let x = 5;
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {x}").to_string(), "x = 5");
        assert_eq!(anyhow!("x = {}", x).to_string(), "x = 5");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }
}
