"""Flash-attention-style Pallas kernel (L1 hot-spot).

TPU adaptation of the paper's A100 attention hot path (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging tiles through
shared memory, the `BlockSpec`s below express the HBM→VMEM schedule — the
grid walks (batch*heads, q-blocks), each step holding one Q block plus a
streamed K/V block in VMEM while an online-softmax accumulator (m, l, acc)
carries the flash-attention recurrence in f32. The two matmuls per step
(`q @ k^T`, `p @ v`) are the MXU work.

`interpret=True` is mandatory here: CPU PJRT cannot execute the Mosaic
custom-call a real TPU lowering produces. Correctness is asserted against
`ref.attention` in python/tests/test_kernels.py; VMEM/MXU structure is
analyzed (not timed) in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                            seq_k: int, causal: bool, sm_scale: float,
                            q_offset: int):
    """One grid step: a full pass over K/V blocks for one Q block.

    Refs are VMEM blocks: q_ref [block_q, hd], k_ref/v_ref [seq_k, hd]
    (indexed into block_k chunks inside the loop), o_ref [block_q, hd].
    """
    block_q, head_dim = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * sm_scale

    # Online-softmax state.
    m = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q,), dtype=jnp.float32)
    acc = jnp.zeros((block_q, head_dim), dtype=jnp.float32)

    # Absolute row index of each query in this block (for causal masking).
    q_pos = q_offset + pl.program_id(1) * block_q + jax.lax.iota(
        jnp.int32, block_q)

    num_kb = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k_start = kb * block_k
        k = pl.load(k_ref, (pl.dslice(k_start, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(k_start, block_k), slice(None)))
        s = q @ k.T.astype(jnp.float32)  # [block_q, block_k] — MXU matmul

        # Out-of-range keys of a partial final block are always masked
        # (block_k need not divide seq_k); causal adds the triangle mask.
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < seq_k
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m, l, acc))
    # Rows with no valid key (fully masked) would divide by zero; clamp.
    l = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 16,
                    block_k: int = 16, q_offset: int = 0):
    """Tiled attention via Pallas.

    q: [b, h, sq, hd]; k, v: [b, h, sk, hd]. `causal` masks key j > query i
    (+ q_offset shifts query positions — used when sq < sk, e.g. chunked
    prefill where queries are the *last* sq positions of sk).
    Returns [b, h, sq, hd].
    """
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_attention_kernel,
        block_k=block_k,
        seq_k=sk,
        causal=causal,
        sm_scale=sm_scale,
        q_offset=q_offset if sq != sk else 0 if q_offset == 0 else q_offset,
    )

    # Collapse (b, h) into one grid axis; q-blocks on the second.
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, sk, hd)
    vf = v.reshape(b * h, sk, hd)

    # Pad Q/K/V up to block multiples: partial blocks are undefined under
    # interpret-mode BlockSpecs/pl.load. Padded keys carry k_pos >= seq_k
    # and are masked to NEG_INF in-kernel; padded query rows are sliced off
    # the output below.
    sq_pad = ((sq + block_q - 1) // block_q) * block_q
    sk_pad = ((sk + block_k - 1) // block_k) * block_k
    if sq_pad != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0))
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)

    grid = (b * h, sq_pad // block_q)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((None, sk_pad, hd), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((None, sk_pad, hd), lambda bh, qb: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_pad, hd), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out[:, :sq, :].reshape(b, h, sq, hd)


def vmem_bytes(block_q: int, block_k: int, seq_k: int, head_dim: int,
               dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid step (DESIGN.md §Perf).

    One Q block + the full K/V panels for this (b,h) + f32 accumulators.
    With the default BlockSpec the K/V panel is resident per grid step;
    a production TPU kernel would stream K/V block_k-at-a-time, shrinking
    the K/V term to 2*block_k*head_dim.
    """
    q_bytes = block_q * head_dim * dtype_bytes
    kv_bytes = 2 * seq_k * head_dim * dtype_bytes
    acc_bytes = block_q * (head_dim + 2) * 4
    out_bytes = block_q * head_dim * dtype_bytes
    return q_bytes + kv_bytes + acc_bytes + out_bytes
