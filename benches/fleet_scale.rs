//! Fleet-scale kernel benchmark — the perf-trajectory artifact.
//!
//! Runs the event kernel at a scale the paper never touched: 1024
//! thirteen-B instances over a 1280-device fleet, ≥5M requests across
//! all five traffic scenarios, CoCoServe policy (so plans execute in
//! flight and profile recompilation is exercised). Reports, per scenario
//! and in aggregate:
//!
//! * **events/sec** and **steps/sec** — kernel throughput (wall-clock),
//! * **allocations/step** — heap allocations per serving step, measured
//!   by a counting global allocator,
//! * **p50/p99 end-to-end latency** — streamed through the O(1)-memory
//!   P² estimator, so the percentile pass adds no second materialized
//!   copy and no O(n log n) sort over 500k+ latencies (the per-instance
//!   monitors still retain their completion records — that retention is
//!   what the golden-replay metrics contract is computed from),
//!
//! and writes the whole document to `BENCH_fleet.json` at the repo root.
//!
//! Before any simulation runs, two targeted probes assert that the
//! compiled step-cost path (`PlacementProfile::{prefill,decode}_step_time`)
//! and the predictive forecaster's observe/advance/forecast path
//! (`forecast::TrafficForecaster`) perform **zero** heap allocations —
//! the zero-alloc contracts of the compiled-profile refactor and the
//! predictive control plane.
//!
//! After the scenario sweep, a **shards sweep** re-runs the steady
//! scenario under the sharded event kernel at 1/2/4/8 shards and reports
//! a speedup table (wall-clock vs the sequential kernel) — the sharded
//! kernel's metrics are byte-identical by contract, so the sweep measures
//! pure kernel overhead/offload.
//!
//! ```bash
//! cargo bench --bench fleet_scale                 # full fleet (~minutes)
//! FLEET_SCALE_SMOKE=1 cargo bench --bench fleet_scale   # CI smoke
//! SHARDS=4 cargo bench --bench fleet_scale        # shard count for the sweep runs
//! GOLDEN_OUT=golden.json FLEET_SCALE_SMOKE=1 cargo bench --bench fleet_scale
//! ```
//!
//! `SHARDS=<k>` sets the event-kernel shard count used for the scenario
//! sweep (default 1 — the sequential kernel). `GOLDEN_OUT=<path>` writes
//! the concatenated per-scenario golden metrics JSON to `<path>`; CI runs
//! the smoke twice (`SHARDS=1` and `SHARDS=4`) and byte-compares the two
//! files — the cross-kernel parity gate at bench scale.
//!
//! After the shards sweep, a **telemetry pass** re-runs the steady
//! scenario with the tracing layer and the kernel self-profiler on:
//! the per-event-kind wall-time/event/allocation breakdown is printed
//! and written into `BENCH_fleet.json` as the `profile` table, and the
//! telemetry-on vs telemetry-off events/sec ratio is gated at ≤10%
//! overhead in smoke mode. `TRACE_OUT=<path>` additionally selects the
//! full (unbounded) span sink and writes the Chrome/Perfetto trace
//! export to `<path>` — span timestamps are sim-time only, so CI runs
//! this twice and byte-compares the files. A third zero-alloc probe
//! asserts span recording into the ring sink never touches the heap.
//!
//! Smoke mode (8 instances, 5k requests) additionally enforces the
//! checked-in regression floors: events/sec must stay above half of
//! `SMOKE_EVENTS_PER_SEC_FLOOR`, and allocations/step must stay within
//! `SMOKE_ALLOCS_PER_STEP_BUDGET`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::forecast::{BurstDetector, Ewma, Holt, HoltWinters, TrafficForecaster};
use cocoserve::placement::{Placement, PlacementProfile};
use cocoserve::sim::{SimConfig, SimReport, Simulation};
use cocoserve::telemetry::{MarkKind, ReqPhase, SpanSink, TelemetryConfig, Tracer};
use cocoserve::util::bench::Table;
use cocoserve::util::json::{self, Json};
use cocoserve::workload::Trace;

// ---- counting allocator ----------------------------------------------------

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

// ---- regression floors (checked in; enforced in smoke mode) ----------------

/// Smoke-mode kernel throughput floor. CI fails when the measured rate
/// regresses more than 2× below this (i.e. below FLOOR / 2) — deliberately
/// conservative so shared-runner jitter cannot flake the gate.
const SMOKE_EVENTS_PER_SEC_FLOOR: f64 = 20_000.0;

/// Smoke-mode heap budget per serving step (scheduler admission vectors,
/// KV bookkeeping; the step-cost path itself contributes zero).
const SMOKE_ALLOCS_PER_STEP_BUDGET: f64 = 512.0;

// ---- configuration ---------------------------------------------------------

struct FleetConfig {
    instances: usize,
    devices: usize,
    requests_per_scenario: usize,
    duration_s: f64,
    smoke: bool,
    /// Event-kernel shard count for the scenario sweep (`SHARDS` env,
    /// default 1 = sequential kernel). Metrics are byte-identical at any
    /// value — this only changes which kernel produces them.
    shards: usize,
}

impl FleetConfig {
    fn from_env() -> FleetConfig {
        let smoke = std::env::var("FLEET_SCALE_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
            || std::env::args().any(|a| a == "--smoke");
        let shards = std::env::var("SHARDS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&s| s >= 1)
            .unwrap_or(1);
        if smoke {
            // 8 instances / 5k requests total: the CI configuration.
            FleetConfig {
                instances: 8,
                devices: 10,
                requests_per_scenario: 1_000,
                duration_s: 10.0,
                smoke,
                shards,
            }
        } else {
            // 1024 instances, ≥5M requests across the five scenarios.
            FleetConfig {
                instances: 1024,
                devices: 1280,
                requests_per_scenario: 1_000_000,
                duration_s: 60.0,
                smoke,
                shards,
            }
        }
    }

    fn rps(&self) -> f64 {
        self.requests_per_scenario as f64 / self.duration_s
    }
}

// ---- the zero-allocation probe --------------------------------------------

/// Assert the compiled step-cost path performs zero heap allocations.
/// Returns the number of probed calls (for the report).
fn assert_step_cost_zero_alloc(cfg: &SimConfig) -> u64 {
    let cost = cfg.cost_model();
    let cluster = Cluster::homogeneous(4, DeviceSpec::a100_40gb());
    let mut pl = Placement::single_device(cfg.model.n_layers, 0);
    pl.add_replica(0, 1);
    pl.add_replica(1, 1);
    pl.add_replica(2, 2);
    let prof = PlacementProfile::compile(&pl, &cluster, 0);
    // warm up (first call may fault in lazily-initialized runtime state)
    std::hint::black_box(prof.prefill_step_time(&cost, cfg.dtype_bytes, 16, 128));
    std::hint::black_box(prof.decode_step_time(&cost, cfg.dtype_bytes, 16, 128));
    let calls = 2 * 64;
    let before = allocs();
    for b in 1..=64usize {
        std::hint::black_box(prof.prefill_step_time(&cost, cfg.dtype_bytes, b, 128));
        std::hint::black_box(prof.decode_step_time(&cost, cfg.dtype_bytes, b, 256));
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "steady-state step-cost path allocated {delta} times over {calls} calls"
    );
    calls
}

/// Assert the forecaster's observe/advance/forecast path performs zero
/// heap allocations — the predictive control plane rides the same
/// zero-alloc discipline as the compiled step costs. Returns the number
/// of probed updates (for the report).
fn assert_forecaster_zero_alloc() -> u64 {
    let mut f = TrafficForecaster::new(
        1.0,
        Ewma::new(0.3),
        Holt::new(0.4, 0.2),
        HoltWinters::new(0.4, 0.2, 0.3, 60), // seasonal table allocated here
        BurstDetector::new(0.05, 3.0),
    );
    // warm up: prime every estimator and close a few buckets
    for i in 0..64 {
        f.observe(i as f64 * 0.25);
    }
    f.advance(20.0);
    std::hint::black_box(f.forecast(8.0));
    let updates = 4096u64;
    let before = allocs();
    for i in 0..updates {
        let t = 20.0 + i as f64 * 0.05; // ~80 arrivals/bucket + gap closes
        f.observe(t);
        std::hint::black_box(f.forecast(8.0));
        std::hint::black_box(f.forecast(1.0));
    }
    f.advance(20.0 + updates as f64 * 0.05 + 30.0); // idle-gap bucket closes
    std::hint::black_box(f.mae());
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "forecaster update path allocated {delta} times over {updates} observes"
    );
    updates
}

/// Assert that span recording on the step path is alloc-free once the
/// ring sink reaches steady state (records are `Copy`; overwrites happen
/// in place). Returns the number of probed recording rounds.
fn assert_tracer_zero_alloc() -> u64 {
    let cfg = TelemetryConfig {
        sink: SpanSink::Ring(1024),
        timeline_window_s: None, // isolate span recording from window rolls
        ..TelemetryConfig::default()
    };
    let mut tr = Tracer::new(Some(&cfg));
    // Warm past ring capacity so steady state overwrites in place.
    for i in 0..2048u64 {
        tr.req(i as f64 * 1e-3, i, 0, ReqPhase::Routed);
    }
    let rounds = 4096u64;
    let before = allocs();
    for i in 0..rounds {
        let t = 3.0 + i as f64 * 1e-3;
        tr.req(t, i, 0, ReqPhase::Routed);
        tr.step(t, 0.05, 0, 16, true);
        tr.completion(t, i, 0, 0.2);
        tr.mark(t, 0, MarkKind::MempressRelief, 1.0);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "steady-state span recording allocated {delta} times over {rounds} rounds"
    );
    rounds
}

// ---- per-scenario measurement ----------------------------------------------

struct ScenarioResult {
    name: &'static str,
    requests: usize,
    completed: usize,
    events: u64,
    steps: u64,
    wall_s: f64,
    allocs_total: u64,
    p50_s: f64,
    p99_s: f64,
    scale_ups: u64,
    scale_downs: u64,
    /// Golden metrics JSON (captured only when `GOLDEN_OUT` is set).
    golden: Option<String>,
}

impl ScenarioResult {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    fn steps_per_sec(&self) -> f64 {
        self.steps as f64 / self.wall_s.max(1e-9)
    }

    fn allocs_per_step(&self) -> f64 {
        self.allocs_total as f64 / self.steps.max(1) as f64
    }
}

fn run_scenario(
    fleet: &FleetConfig,
    name: &'static str,
    trace: &Trace,
    shards: usize,
    capture_golden: bool,
    telemetry: Option<TelemetryConfig>,
) -> (ScenarioResult, SimReport) {
    let mut cfg = SimConfig::paper_13b();
    cfg.shards = shards;
    cfg.telemetry = telemetry;
    let cluster = Cluster::homogeneous(fleet.devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..fleet.instances)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % fleet.devices),
                baselines::cocoserve(32),
            )
        })
        .collect();
    let sim = Simulation::new(cfg, cluster, placements);

    let allocs_before = allocs();
    let t0 = Instant::now();
    let report: SimReport = sim.run(trace, fleet.duration_s);
    let wall_s = t0.elapsed().as_secs_f64();
    let allocs_total = allocs() - allocs_before;

    // Percentiles via SimReport's streaming P² path: one pass, no merged
    // latency vector, nothing sorted. (The monitors still hold their
    // completion records — the golden-replay metrics are computed from
    // them, so that retention stays.)
    let quantiles = report.latency_p2s(&[0.50, 0.99]);
    let golden = capture_golden.then(|| report.to_json().to_string());
    let result = ScenarioResult {
        name,
        requests: trace.len(),
        completed: report.total_completed(),
        events: report.events_processed,
        steps: report.steps_started,
        wall_s,
        allocs_total,
        p50_s: quantiles[0],
        p99_s: quantiles[1],
        scale_ups: report.scale_ups,
        scale_downs: report.scale_downs,
        golden,
    };
    (result, report)
}

fn main() {
    let fleet = FleetConfig::from_env();
    let golden_out = std::env::var("GOLDEN_OUT").ok().filter(|p| !p.is_empty());
    println!(
        "Fleet-scale kernel bench — {} instances / {} devices / {} requests × 5 scenarios, \
         shards={}{}\n",
        fleet.instances,
        fleet.devices,
        fleet.requests_per_scenario,
        fleet.shards,
        if fleet.smoke { " (SMOKE)" } else { "" }
    );

    let probe_calls = assert_step_cost_zero_alloc(&SimConfig::paper_13b());
    println!("zero-alloc probe: {probe_calls} step-cost calls, 0 heap allocations ✓");
    let forecast_updates = assert_forecaster_zero_alloc();
    println!(
        "zero-alloc probe: {forecast_updates} forecaster observe/forecast rounds, \
         0 heap allocations ✓"
    );
    let tracer_rounds = assert_tracer_zero_alloc();
    println!(
        "zero-alloc probe: {tracer_rounds} span-recording rounds (ring sink), \
         0 heap allocations ✓\n"
    );

    let sweep = Trace::scenario_sweep(fleet.rps(), fleet.duration_s, 4096);
    let mut results = Vec::new();
    let mut table = Table::new(&[
        "scenario", "requests", "completed", "events/s", "steps/s", "allocs/step",
        "p50", "p99", "ups", "downs",
    ]);
    for (name, trace) in sweep {
        let (r, _) =
            run_scenario(&fleet, name, &trace, fleet.shards, golden_out.is_some(), None);
        table.row(&[
            r.name.to_string(),
            format!("{}", r.requests),
            format!("{}", r.completed),
            format!("{:.0}", r.events_per_sec()),
            format!("{:.0}", r.steps_per_sec()),
            format!("{:.1}", r.allocs_per_step()),
            format!("{:.2}s", r.p50_s),
            format!("{:.2}s", r.p99_s),
            format!("{}", r.scale_ups),
            format!("{}", r.scale_downs),
        ]);
        results.push(r);
    }
    table.print();

    let total_requests: usize = results.iter().map(|r| r.requests).sum();
    let total_events: u64 = results.iter().map(|r| r.events).sum();
    let total_steps: u64 = results.iter().map(|r| r.steps).sum();
    let total_wall: f64 = results.iter().map(|r| r.wall_s).sum();
    let total_allocs: u64 = results.iter().map(|r| r.allocs_total).sum();
    let agg_events_per_sec = total_events as f64 / total_wall.max(1e-9);
    let agg_allocs_per_step = total_allocs as f64 / total_steps.max(1) as f64;
    println!(
        "\naggregate: {total_requests} requests, {total_events} events, {total_steps} \
         steps in {total_wall:.1}s — {agg_events_per_sec:.0} events/s, \
         {agg_allocs_per_step:.1} allocs/step"
    );

    // ---- golden metrics dump (cross-kernel parity gate) ---------------------
    if let Some(path) = &golden_out {
        // One concatenated document, scenarios in sweep order. CI runs the
        // smoke at SHARDS=1 and SHARDS=4 and byte-compares the two files.
        let mut dump = String::new();
        for r in &results {
            dump.push_str(r.name);
            dump.push('\n');
            dump.push_str(r.golden.as_deref().expect("golden captured"));
            dump.push('\n');
        }
        std::fs::write(path, dump).expect("write GOLDEN_OUT");
        println!("golden metrics: {path} (shards={})", fleet.shards);
    }

    // ---- shards sweep: sequential vs sharded kernel wall-clock --------------
    // Same steady trace, shards ∈ {1,2,4,8}; metrics are byte-identical by
    // contract (asserted in tests + CI), so this isolates kernel cost. The
    // sharded kernel parallelizes epoch drains (heap maintenance); event
    // application stays sequential for parity, so expect modest deltas —
    // the table records what is, not what marketing wants.
    let sweep_trace = Trace::steady(fleet.rps(), fleet.duration_s, 4096);
    let mut sweep_results = Vec::new();
    let mut sweep_table = Table::new(&["shards", "wall_s", "events/s", "speedup vs 1"]);
    for shards in [1usize, 2, 4, 8] {
        let (r, _) = run_scenario(&fleet, "steady", &sweep_trace, shards, false, None);
        sweep_results.push((shards, r));
    }
    let base_wall = sweep_results[0].1.wall_s.max(1e-9);
    for (shards, r) in &sweep_results {
        sweep_table.row(&[
            format!("{shards}"),
            format!("{:.2}", r.wall_s),
            format!("{:.0}", r.events_per_sec()),
            format!("{:.2}x", base_wall / r.wall_s.max(1e-9)),
        ]);
    }
    println!("\nshards sweep (steady scenario):");
    sweep_table.print();

    // ---- telemetry overhead + kernel self-profiler --------------------------
    // Telemetry-on re-run of the steady trace, sequential kernel. `TRACE_OUT`
    // selects the full span sink and writes the Chrome trace export (CI runs
    // this twice and byte-compares — span timestamps are sim-time only, so
    // the export is deterministic); otherwise a bounded ring keeps memory
    // flat at fleet scale. The self-profiler is always on here: wall-time,
    // event-count and allocation deltas per event kind, attributed via the
    // counting allocator, land in BENCH_fleet.json as the `profile` table.
    let trace_out = std::env::var("TRACE_OUT").ok().filter(|p| !p.is_empty());
    let mut tcfg = if trace_out.is_some() {
        TelemetryConfig::default()
    } else {
        TelemetryConfig::ring(1 << 16)
    };
    tcfg.profile = true;
    tcfg.alloc_probe = Some(allocs);
    let telemetry_off = &sweep_results[0].1; // steady, shards=1, telemetry off
    let (telemetry_on, telem_report) =
        run_scenario(&fleet, "steady", &sweep_trace, 1, false, Some(tcfg));
    let overhead_frac =
        1.0 - telemetry_on.events_per_sec() / telemetry_off.events_per_sec().max(1e-9);
    println!(
        "\ntelemetry overhead (steady): {:.0} events/s on vs {:.0} off ({:+.1}%)",
        telemetry_on.events_per_sec(),
        telemetry_off.events_per_sec(),
        overhead_frac * 100.0
    );
    let profile = telem_report.profile.clone().expect("profiler enabled");
    profile.print();
    if let Some(path) = &trace_out {
        let chrome = telem_report.chrome_trace().expect("trace buffer captured");
        std::fs::write(path, chrome.to_string()).expect("write TRACE_OUT");
        println!("trace export: {path}");
    }

    // ---- BENCH_fleet.json ---------------------------------------------------
    let scenarios = json::arr(results.iter().map(|r| {
        json::obj(vec![
            ("allocs_per_step", json::num(r.allocs_per_step())),
            ("allocs_total", json::num(r.allocs_total as f64)),
            ("completed", json::num(r.completed as f64)),
            ("events", json::num(r.events as f64)),
            ("events_per_sec", json::num(r.events_per_sec())),
            ("latency_p50_s", json::num(r.p50_s)),
            ("latency_p99_s", json::num(r.p99_s)),
            ("requests", json::num(r.requests as f64)),
            ("scale_downs", json::num(r.scale_downs as f64)),
            ("scale_ups", json::num(r.scale_ups as f64)),
            ("scenario", json::s(r.name)),
            ("steps", json::num(r.steps as f64)),
            ("steps_per_sec", json::num(r.steps_per_sec())),
            ("wall_s", json::num(r.wall_s)),
        ])
    }));
    let doc = json::obj(vec![
        (
            "aggregate",
            json::obj(vec![
                ("allocs_per_step", json::num(agg_allocs_per_step)),
                ("events_per_sec", json::num(agg_events_per_sec)),
                ("requests", json::num(total_requests as f64)),
                ("steps", json::num(total_steps as f64)),
                ("wall_s", json::num(total_wall)),
            ]),
        ),
        (
            "config",
            json::obj(vec![
                ("devices", json::num(fleet.devices as f64)),
                ("instances", json::num(fleet.instances as f64)),
                (
                    "requests_per_scenario",
                    json::num(fleet.requests_per_scenario as f64),
                ),
                ("shards", json::num(fleet.shards as f64)),
                ("smoke", json::num(f64::from(u8::from(fleet.smoke)))),
            ]),
        ),
        (
            "floors",
            json::obj(vec![
                ("smoke_allocs_per_step_budget", json::num(SMOKE_ALLOCS_PER_STEP_BUDGET)),
                ("smoke_events_per_sec_floor", json::num(SMOKE_EVENTS_PER_SEC_FLOOR)),
            ]),
        ),
        (
            "shards_sweep",
            json::arr(sweep_results.iter().map(|(shards, r)| {
                json::obj(vec![
                    ("events_per_sec", json::num(r.events_per_sec())),
                    ("shards", json::num(*shards as f64)),
                    ("speedup_vs_1", json::num(base_wall / r.wall_s.max(1e-9))),
                    ("wall_s", json::num(r.wall_s)),
                ])
            })),
        ),
        ("profile", profile.to_json()),
        (
            "telemetry",
            json::obj(vec![
                ("events_per_sec_off", json::num(telemetry_off.events_per_sec())),
                ("events_per_sec_on", json::num(telemetry_on.events_per_sec())),
                ("overhead_frac", json::num(overhead_frac)),
                ("trace_events", json::num(
                    telem_report.trace.as_ref().map_or(0.0, |b| b.events.len() as f64),
                )),
                ("trace_dropped", json::num(
                    telem_report.trace.as_ref().map_or(0.0, |b| b.dropped as f64),
                )),
            ]),
        ),
        (
            "zero_alloc_probe",
            json::obj(vec![
                ("allocations", json::num(0.0)),
                ("forecaster_updates", json::num(forecast_updates as f64)),
                ("step_cost_calls", json::num(probe_calls as f64)),
                ("tracer_rounds", json::num(tracer_rounds as f64)),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_fleet.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_fleet.json");
    println!("report: {}", path.display());
    let _ = Json::parse(&doc.to_string()).expect("self-parse");

    // ---- smoke-mode regression gates ---------------------------------------
    if fleet.smoke {
        assert!(
            agg_events_per_sec >= SMOKE_EVENTS_PER_SEC_FLOOR / 2.0,
            "kernel throughput regressed >2x below the floor: {agg_events_per_sec:.0} \
             events/s < {}/2",
            SMOKE_EVENTS_PER_SEC_FLOOR
        );
        assert!(
            agg_allocs_per_step <= SMOKE_ALLOCS_PER_STEP_BUDGET,
            "allocation budget exceeded: {agg_allocs_per_step:.1} allocs/step > {}",
            SMOKE_ALLOCS_PER_STEP_BUDGET
        );
        assert!(
            overhead_frac <= 0.10,
            "telemetry overhead gate: {:.1}% events/s regression > 10% \
             ({:.0} on vs {:.0} off)",
            overhead_frac * 100.0,
            telemetry_on.events_per_sec(),
            telemetry_off.events_per_sec()
        );
        println!(
            "smoke gates passed: events/s ≥ floor/2, allocs/step ≤ budget, \
             telemetry overhead ≤ 10% ✓"
        );
    }
    for r in &results {
        assert!(r.completed > 0, "scenario `{}` served nothing", r.name);
    }
}
