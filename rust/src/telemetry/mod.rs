//! Deterministic tracing & telemetry — the kernel's observability layer.
//!
//! Three surfaces, all derived from the **same** record stream the event
//! kernel emits from inside its shared `dispatch` body (so the sharded and
//! sequential kernels produce byte-identical traces by construction):
//!
//! * **Spans** ([`TraceEvent`]) — per-request lifecycle edges
//!   (`Arrival → Routed → Admitted → … → Completed`), per-step serving
//!   spans, per-module-op spans with dry-run vs actual cost, instant
//!   marks (failures, rollbacks, memory-pressure relief), and structured
//!   [*decision records*](TraceEvent::Decision) for every fleet /
//!   predictive / memory-pressure choice. Exported as Chrome trace-event
//!   JSON ([`TraceBuffer::chrome_trace`]) loadable in Perfetto or
//!   `chrome://tracing`.
//! * **Timeline** ([`TimelineBlock`]) — a streaming per-window summary
//!   (arrivals, completions, sheds, outstanding, p50/p99 via the
//!   O(1)-memory [`P2Quantile`], device-seconds, compute utilization)
//!   emitted as the strictly-additive `timeline` key of the metrics JSON.
//! * **Profiler** ([`profiler::KernelProfiler`]) — wall-time, event-count
//!   and allocation histogram per event kind, kept entirely *outside* the
//!   golden surface (wall-clock may never leak into replayed metrics).
//!
//! ### Determinism contract
//!
//! Every recorded timestamp is **simulation time** — `std::time::Instant`
//! appears only in the self-profiler, whose output lands in
//! `BENCH_fleet.json`, never in the metrics JSON or the trace export.
//! With telemetry disabled (the default) the tracer records nothing and
//! the metrics JSON is byte-identical to a build without this module;
//! with telemetry enabled, two runs of the same seed — at any shard
//! count — export byte-identical traces (`rust/tests/telemetry.rs`).
//!
//! ### Hot-path contract
//!
//! Recording into the [`SpanSink::Ring`] sink is allocation-free: the
//! ring is pre-allocated at construction, [`TraceEvent`] is `Copy`, and
//! overflow overwrites the oldest record (counted in
//! [`TraceBuffer::dropped`]). `benches/fleet_scale.rs` asserts zero heap
//! allocations across ring-sink span recording with its counting global
//! allocator.

pub mod export;
pub mod profiler;

use crate::plan::ModuleOp;
use crate::util::stats::P2Quantile;

// ---- configuration ---------------------------------------------------------

/// Where recorded spans go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanSink {
    /// Keep every record (growable buffer — full-fidelity export).
    Full,
    /// Pre-allocated ring of this capacity; overflow overwrites the
    /// oldest record. The zero-allocation sink for fleet-scale runs.
    Ring(usize),
}

/// Telemetry configuration, carried on [`crate::sim::SimConfig`].
///
/// `None` there (the default everywhere) disables telemetry entirely:
/// the kernel's tracer records nothing, the metrics JSON grows no keys,
/// and every golden replay stays byte-identical to the pre-telemetry
/// kernel.
#[derive(Debug, Clone, Copy)]
pub struct TelemetryConfig {
    /// Span sink selection (full export vs bounded ring).
    pub sink: SpanSink,
    /// Streaming timeline window in seconds (`None` = no timeline block).
    pub timeline_window_s: Option<f64>,
    /// Record controller/governor decision records.
    pub decisions: bool,
    /// Run the kernel self-profiler (per-event-kind wall time + allocs).
    /// Wall-clock stays outside the golden surface — see module docs.
    pub profile: bool,
    /// Allocation counter the profiler samples around each dispatch
    /// (benches pass their counting-allocator reader; `None` records 0).
    pub alloc_probe: Option<fn() -> u64>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            sink: SpanSink::Full,
            timeline_window_s: Some(1.0),
            decisions: true,
            profile: false,
            alloc_probe: None,
        }
    }
}

impl TelemetryConfig {
    /// Full-fidelity capture (growable span buffer, timeline, decisions).
    pub fn full() -> TelemetryConfig {
        TelemetryConfig::default()
    }

    /// Bounded capture for scale runs: ring sink of `capacity` records.
    pub fn ring(capacity: usize) -> TelemetryConfig {
        TelemetryConfig { sink: SpanSink::Ring(capacity), ..TelemetryConfig::default() }
    }
}

// ---- record types ----------------------------------------------------------

/// Why an instance shed a request back to the router — carried on the
/// shed record so the trace can distinguish OOM sheds, SLO preemptions
/// and failure-domain evacuations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// KV admission hit device OOM (FailBatch / preempt-newest paths).
    Oom,
    /// Mid-step preemption of a best-effort batch for a premium request.
    SloPreempt,
    /// Device failure or forced release evacuated the request.
    Failure,
}

/// A request-lifecycle edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqPhase {
    /// The request entered the system (trace arrival).
    Arrival,
    /// The router picked an instance (delivery scheduled).
    Routed,
    /// Admission backpressure parked it at the router.
    Parked,
    /// Delivered into an instance's scheduler queue.
    Admitted,
    /// An OOM/failure shed moved it to a different instance.
    Rerouted,
    /// Shed out of a serving batch (OOM or failure evacuation).
    Shed,
    /// Preempted mid-step in favour of a premium request.
    Preempted,
    /// Finished decoding — the terminal edge.
    Completed,
}

impl ReqPhase {
    /// Stable lower-case label used in the trace export.
    pub fn name(&self) -> &'static str {
        match self {
            ReqPhase::Arrival => "arrival",
            ReqPhase::Routed => "routed",
            ReqPhase::Parked => "parked",
            ReqPhase::Admitted => "admitted",
            ReqPhase::Rerouted => "rerouted",
            ReqPhase::Shed => "shed",
            ReqPhase::Preempted => "preempted",
            ReqPhase::Completed => "completed",
        }
    }
}

/// Module-op span phase (mirrors the kernel's `OpStarted`/`OpCompleted`
/// events plus the abort/rollback outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpSpanPhase {
    /// The op began executing (span start; duration = dry-run estimate).
    Started,
    /// The op landed; the record carries dry-run *and* actual cost.
    Applied,
    /// The op (and its plan) rolled back.
    Aborted,
}

impl OpSpanPhase {
    /// Stable lower-case label used in the trace export.
    pub fn name(&self) -> &'static str {
        match self {
            OpSpanPhase::Started => "started",
            OpSpanPhase::Applied => "applied",
            OpSpanPhase::Aborted => "aborted",
        }
    }
}

/// Instant-event kinds (rendered as Perfetto instants on the owning
/// track).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MarkKind {
    /// A device died (value = device id).
    DeviceFailed,
    /// An in-flight plan rolled back.
    Rollback,
    /// The memory-pressure governor granted relief (value = rung code).
    MempressRelief,
    /// A KV-admission OOM episode began (value = deficit bytes).
    OomEpisode,
    /// Fleet controller deployed a fresh instance (value = device).
    SpinUp,
    /// Fleet controller started draining an instance.
    Drain,
    /// A drained instance released its devices.
    Release,
}

impl MarkKind {
    /// Stable label used in the trace export.
    pub fn name(&self) -> &'static str {
        match self {
            MarkKind::DeviceFailed => "device_failed",
            MarkKind::Rollback => "rollback",
            MarkKind::MempressRelief => "mempress_relief",
            MarkKind::OomEpisode => "oom_episode",
            MarkKind::SpinUp => "spin_up",
            MarkKind::Drain => "drain",
            MarkKind::Release => "release",
        }
    }
}

/// Which control plane produced a decision record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionActor {
    /// Reactive fleet controller (pressure classifier + arbitration).
    Fleet,
    /// Predictive controller (forecast deficits).
    Predictive,
    /// Per-instance memory-pressure governor.
    Mempress,
}

impl DecisionActor {
    /// Stable label used in the trace export.
    pub fn name(&self) -> &'static str {
        match self {
            DecisionActor::Fleet => "fleet",
            DecisionActor::Predictive => "predictive",
            DecisionActor::Mempress => "mempress",
        }
    }
}

/// What a decision record enacted (or declined to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionAction {
    /// Pressure in the hold band — no reactive action.
    Hold,
    /// Scale-out arbitration chose module replication.
    ScaleOutReplicate,
    /// Scale-out arbitration chose whole-instance spin-up.
    ScaleOutSpinUp,
    /// Scale-out wanted, but neither option was available.
    ScaleOutNone,
    /// Reactive scale-in: drain the least-loaded instance.
    DrainInstance,
    /// The predictor vetoed a reactive drain (capacity needed soon).
    DrainVetoed,
    /// Predictive replication (deficit at the plan's own lead time).
    PredictedReplicate,
    /// Predictive spin-up (deficit at the cold-start horizon).
    PredictedSpinUp,
    /// The reactive signal vetoed a predictive proposal.
    PredictiveVetoed,
    /// Governor grew the instance's KV pool.
    GrowPool,
    /// Governor shrank the KV pool toward its floor.
    ShrinkPool,
    /// Governor requested int8 precision swaps.
    RequestSwaps,
    /// Governor told the instance to wait out the episode.
    Wait,
    /// Governor escalated to the policy's raw OOM handling.
    Escalate,
}

impl DecisionAction {
    /// Stable label used in the trace export.
    pub fn name(&self) -> &'static str {
        match self {
            DecisionAction::Hold => "hold",
            DecisionAction::ScaleOutReplicate => "scale_out_replicate",
            DecisionAction::ScaleOutSpinUp => "scale_out_spin_up",
            DecisionAction::ScaleOutNone => "scale_out_none",
            DecisionAction::DrainInstance => "drain_instance",
            DecisionAction::DrainVetoed => "drain_vetoed",
            DecisionAction::PredictedReplicate => "predicted_replicate",
            DecisionAction::PredictedSpinUp => "predicted_spin_up",
            DecisionAction::PredictiveVetoed => "predictive_vetoed",
            DecisionAction::GrowPool => "grow_pool",
            DecisionAction::ShrinkPool => "shrink_pool",
            DecisionAction::RequestSwaps => "request_swaps",
            DecisionAction::Wait => "wait",
            DecisionAction::Escalate => "escalate",
        }
    }
}

/// One recorded telemetry event. `Copy` with numeric payloads only — no
/// string is built until export, which is what keeps ring-sink recording
/// allocation-free on the step path.
///
/// `instance` is `i64` where the fleet/router lane (`-1`) is a valid
/// owner; spans that always belong to an instance use `u32`.
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    /// A request-lifecycle edge (async span begin/instant/end).
    Req {
        /// Simulation time (seconds).
        t: f64,
        /// Request id (trace-unique).
        id: u64,
        /// Owning instance, or `-1` for the router/fleet lane.
        instance: i64,
        /// Which lifecycle edge.
        phase: ReqPhase,
    },
    /// One serving step (prefill or decode) on an instance.
    Step {
        /// Step start time (seconds).
        t: f64,
        /// Step duration (seconds, contention included).
        dur_s: f64,
        /// Instance that ran the step.
        instance: u32,
        /// Sequences in the batch.
        batch: u32,
        /// `true` = decode step, `false` = prefill.
        decode: bool,
    },
    /// A module-op span edge (start / applied / aborted).
    Op {
        /// Event time (seconds): span start for [`OpSpanPhase::Started`],
        /// completion time otherwise.
        t: f64,
        /// Instance executing the plan.
        instance: u32,
        /// Op index within its plan.
        op_idx: u32,
        /// The operation itself (kind, layer, destination device).
        op: ModuleOp,
        /// Dry-run cost estimate (seconds) the kernel scheduled with.
        dry_s: f64,
        /// Actual applied cost (seconds); `0` until applied.
        actual_s: f64,
        /// Span edge.
        phase: OpSpanPhase,
    },
    /// An instant mark (failure, rollback, relief, lifecycle edge).
    Mark {
        /// Simulation time (seconds).
        t: f64,
        /// Owning instance, or `-1` for the fleet lane.
        instance: i64,
        /// What happened.
        kind: MarkKind,
        /// Kind-specific numeric payload (device id, bytes, rung…).
        value: f64,
    },
    /// A controller/governor decision with its inputs and the dry-run
    /// price of the losing alternative — "why replicate, why not spin
    /// up" is answerable from this record alone.
    Decision {
        /// Simulation time (seconds).
        t: f64,
        /// Which control plane decided.
        actor: DecisionActor,
        /// What it chose.
        action: DecisionAction,
        /// Target instance, or `-1` for fleet-wide decisions.
        instance: i64,
        /// Reactive pressure input (mean outstanding per live instance
        /// for fleet decisions; pool deficit bytes for the governor).
        pressure: f64,
        /// Forecast deficit in instance-equivalents (`0` for purely
        /// reactive decisions).
        deficit: f64,
        /// Dry-run cost of the chosen option (seconds; `-1` = n/a).
        chosen_cost: f64,
        /// Dry-run cost of the rejected alternative (seconds; `-1` =
        /// no alternative was on the table).
        rejected_cost: f64,
    },
}

impl TraceEvent {
    /// The event's simulation timestamp (seconds).
    pub fn t(&self) -> f64 {
        match *self {
            TraceEvent::Req { t, .. }
            | TraceEvent::Step { t, .. }
            | TraceEvent::Op { t, .. }
            | TraceEvent::Mark { t, .. }
            | TraceEvent::Decision { t, .. } => t,
        }
    }
}

// ---- timeline --------------------------------------------------------------

/// One closed timeline window (all cumulative fields sampled at the
/// event that crossed the window boundary — see `DESIGN.md`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineWindow {
    /// Window end (seconds, a multiple of the window size except for a
    /// final partial window).
    pub t_s: f64,
    /// Arrivals observed in the window.
    pub arrivals: u64,
    /// Requests completed in the window.
    pub completions: u64,
    /// Requests shed or preempted in the window.
    pub sheds: u64,
    /// Outstanding requests (queued + running + parked) at window close.
    pub outstanding: u64,
    /// p50 end-to-end latency of the window's completions (0 if none).
    pub p50_s: f64,
    /// p99 end-to-end latency of the window's completions (0 if none).
    pub p99_s: f64,
    /// Cumulative billed device-seconds at window close.
    pub device_seconds: f64,
    /// Mean compute utilization across devices over the window, from the
    /// busy-seconds delta (clamped to `[0, 1]`).
    pub busy_frac: f64,
}

/// The streaming timeline: the strictly-additive `timeline` block of the
/// metrics JSON (present iff telemetry configured a window).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TimelineBlock {
    /// Window size in seconds.
    pub window_s: f64,
    /// Closed windows in time order.
    pub windows: Vec<TimelineWindow>,
}

impl TimelineBlock {
    /// Serialize as the metrics-JSON `timeline` value. Deterministic:
    /// sim-time inputs only, keys sorted by the JSON builder.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json;
        json::obj(vec![
            ("window_s", json::num(self.window_s)),
            (
                "windows",
                json::arr(self.windows.iter().map(|w| {
                    json::obj(vec![
                        ("arrivals", json::num(w.arrivals as f64)),
                        ("busy_frac", json::num(w.busy_frac)),
                        ("completions", json::num(w.completions as f64)),
                        ("device_seconds", json::num(w.device_seconds)),
                        ("outstanding", json::num(w.outstanding as f64)),
                        ("p50_s", json::num(w.p50_s)),
                        ("p99_s", json::num(w.p99_s)),
                        ("sheds", json::num(w.sheds as f64)),
                        ("t_s", json::num(w.t_s)),
                    ])
                })),
            ),
        ])
    }
}

/// Builds [`TimelineBlock`] incrementally. Counters accumulate on the
/// record path (allocation-free); windows close lazily when the kernel
/// sees the first event at or past a boundary.
#[derive(Debug)]
struct TimelineBuilder {
    window_s: f64,
    next_boundary: f64,
    arrivals: u64,
    completions: u64,
    sheds: u64,
    p50: P2Quantile,
    p99: P2Quantile,
    samples: u64,
    last_busy: f64,
    windows: Vec<TimelineWindow>,
}

impl TimelineBuilder {
    fn new(window_s: f64) -> TimelineBuilder {
        assert!(window_s > 0.0, "timeline window must be positive");
        TimelineBuilder {
            window_s,
            next_boundary: window_s,
            arrivals: 0,
            completions: 0,
            sheds: 0,
            p50: P2Quantile::new(0.5),
            p99: P2Quantile::new(0.99),
            samples: 0,
            last_busy: 0.0,
            windows: Vec::new(),
        }
    }

    #[inline]
    fn due(&self, t: f64) -> bool {
        t >= self.next_boundary
    }

    fn close(
        &mut self,
        t_end: f64,
        span: f64,
        outstanding: u64,
        device_seconds: f64,
        busy_s: f64,
        n_devices: usize,
    ) {
        let delta = (busy_s - self.last_busy).max(0.0);
        self.last_busy = busy_s;
        let denom = n_devices as f64 * span;
        let busy_frac = if denom > 0.0 { (delta / denom).min(1.0) } else { 0.0 };
        let (p50_s, p99_s) = if self.samples > 0 {
            (self.p50.value(), self.p99.value())
        } else {
            (0.0, 0.0)
        };
        self.windows.push(TimelineWindow {
            t_s: t_end,
            arrivals: self.arrivals,
            completions: self.completions,
            sheds: self.sheds,
            outstanding,
            p50_s,
            p99_s,
            device_seconds,
            busy_frac,
        });
        self.arrivals = 0;
        self.completions = 0;
        self.sheds = 0;
        self.samples = 0;
        self.p50 = P2Quantile::new(0.5);
        self.p99 = P2Quantile::new(0.99);
    }

    /// Close every window whose boundary is at or before `t`. All
    /// cumulative samples are taken at `t` (the crossing event); skipped
    /// empty windows record zero deltas.
    fn roll(
        &mut self,
        t: f64,
        outstanding: u64,
        device_seconds: f64,
        busy_s: f64,
        n_devices: usize,
    ) {
        while self.next_boundary <= t {
            let t_end = self.next_boundary;
            self.next_boundary += self.window_s;
            self.close(t_end, self.window_s, outstanding, device_seconds, busy_s, n_devices);
        }
    }

    /// Close remaining full windows and a final partial window (if it
    /// saw any activity), then emit the block.
    fn finish(
        mut self,
        t_end: f64,
        outstanding: u64,
        device_seconds: f64,
        busy_s: f64,
        n_devices: usize,
    ) -> TimelineBlock {
        self.roll(t_end, outstanding, device_seconds, busy_s, n_devices);
        let partial_span = t_end - (self.next_boundary - self.window_s);
        let active = self.arrivals + self.completions + self.sheds + self.samples > 0;
        if partial_span > 0.0 && active {
            self.close(t_end, partial_span, outstanding, device_seconds, busy_s, n_devices);
        }
        TimelineBlock { window_s: self.window_s, windows: self.windows }
    }
}

// ---- the tracer ------------------------------------------------------------

/// The exported span buffer (chronological; ring overflow already
/// unrolled).
#[derive(Debug, Clone, Default)]
pub struct TraceBuffer {
    /// Recorded events in simulation-time order.
    pub events: Vec<TraceEvent>,
    /// Records overwritten by ring-sink overflow (0 for the full sink).
    pub dropped: u64,
    /// Instance lanes the trace export lays out (fleet size at end of
    /// run, spun-up instances included).
    pub n_instances: usize,
}

impl TraceBuffer {
    /// Export as Chrome trace-event JSON — see [`export::chrome_trace`].
    pub fn chrome_trace(&self) -> crate::util::json::Json {
        export::chrome_trace(self)
    }
}

/// The kernel's recorder. Always present on the simulation (one `bool`
/// branch when disabled); every record method is an `#[inline]`
/// early-return no-op unless telemetry was configured.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    decisions_on: bool,
    ring_cap: Option<usize>,
    events: Vec<TraceEvent>,
    next_overwrite: usize,
    dropped: u64,
    timeline: Option<TimelineBuilder>,
    profile: bool,
    alloc_probe: Option<fn() -> u64>,
}

impl Tracer {
    /// The no-op tracer (telemetry off — records nothing, owns nothing).
    pub fn disabled() -> Tracer {
        Tracer {
            enabled: false,
            decisions_on: false,
            ring_cap: None,
            events: Vec::new(),
            next_overwrite: 0,
            dropped: 0,
            timeline: None,
            profile: false,
            alloc_probe: None,
        }
    }

    /// Build from the optional config (`None` → [`Tracer::disabled`]).
    /// Ring sinks pre-allocate their full capacity here, so recording
    /// never allocates.
    pub fn new(cfg: Option<&TelemetryConfig>) -> Tracer {
        let Some(cfg) = cfg else { return Tracer::disabled() };
        let (ring_cap, events) = match cfg.sink {
            SpanSink::Full => (None, Vec::new()),
            SpanSink::Ring(cap) => {
                let cap = cap.max(1);
                (Some(cap), Vec::with_capacity(cap))
            }
        };
        Tracer {
            enabled: true,
            decisions_on: cfg.decisions,
            ring_cap,
            events,
            next_overwrite: 0,
            dropped: 0,
            timeline: cfg.timeline_window_s.map(TimelineBuilder::new),
            profile: cfg.profile,
            alloc_probe: cfg.alloc_probe,
        }
    }

    /// Is telemetry recording at all?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Should the run loop wrap dispatch in the self-profiler?
    pub fn profile_enabled(&self) -> bool {
        self.enabled && self.profile
    }

    /// The allocation counter handed to the profiler (if any).
    pub fn alloc_probe(&self) -> Option<fn() -> u64> {
        self.alloc_probe
    }

    #[inline]
    fn push(&mut self, ev: TraceEvent) {
        match self.ring_cap {
            None => self.events.push(ev),
            Some(cap) => {
                if self.events.len() < cap {
                    self.events.push(ev);
                } else {
                    self.events[self.next_overwrite] = ev;
                    self.next_overwrite += 1;
                    if self.next_overwrite == cap {
                        self.next_overwrite = 0;
                    }
                    self.dropped += 1;
                }
            }
        }
    }

    /// Record a request-lifecycle edge. Arrival/shed/preempt edges also
    /// feed the timeline counters; completions use
    /// [`Tracer::completion`] instead (it carries the latency sample).
    #[inline]
    pub fn req(&mut self, t: f64, id: u64, instance: i64, phase: ReqPhase) {
        if !self.enabled {
            return;
        }
        if let Some(tl) = &mut self.timeline {
            match phase {
                ReqPhase::Arrival => tl.arrivals += 1,
                ReqPhase::Shed | ReqPhase::Preempted => tl.sheds += 1,
                _ => {}
            }
        }
        self.push(TraceEvent::Req { t, id, instance, phase });
    }

    /// Record a completion: the request's terminal span edge plus the
    /// timeline latency sample.
    #[inline]
    pub fn completion(&mut self, t: f64, id: u64, instance: i64, latency_s: f64) {
        if !self.enabled {
            return;
        }
        if let Some(tl) = &mut self.timeline {
            tl.completions += 1;
            tl.samples += 1;
            tl.p50.add(latency_s);
            tl.p99.add(latency_s);
        }
        self.push(TraceEvent::Req { t, id, instance, phase: ReqPhase::Completed });
    }

    /// Record one serving step span.
    #[inline]
    pub fn step(&mut self, t: f64, dur_s: f64, instance: usize, batch: usize, decode: bool) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Step {
            t,
            dur_s,
            instance: instance as u32,
            batch: batch as u32,
            decode,
        });
    }

    /// Record a module-op span edge.
    #[inline]
    pub fn op(
        &mut self,
        t: f64,
        instance: usize,
        op_idx: usize,
        op: ModuleOp,
        dry_s: f64,
        actual_s: f64,
        phase: OpSpanPhase,
    ) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Op {
            t,
            instance: instance as u32,
            op_idx: op_idx as u32,
            op,
            dry_s,
            actual_s,
            phase,
        });
    }

    /// Record an instant mark.
    #[inline]
    pub fn mark(&mut self, t: f64, instance: i64, kind: MarkKind, value: f64) {
        if !self.enabled {
            return;
        }
        self.push(TraceEvent::Mark { t, instance, kind, value });
    }

    /// Record a decision (no-op unless decision records are configured).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn decision(
        &mut self,
        t: f64,
        actor: DecisionActor,
        action: DecisionAction,
        instance: i64,
        pressure: f64,
        deficit: f64,
        chosen_cost: f64,
        rejected_cost: f64,
    ) {
        if !self.enabled || !self.decisions_on {
            return;
        }
        self.push(TraceEvent::Decision {
            t,
            actor,
            action,
            instance,
            pressure,
            deficit,
            chosen_cost,
            rejected_cost,
        });
    }

    /// Cheap boundary check the kernel runs per event: `true` iff the
    /// timeline has a window to close at or before `t` (the kernel then
    /// assembles the samples and calls [`Tracer::roll`]).
    #[inline]
    pub fn timeline_due(&self, t: f64) -> bool {
        self.enabled && self.timeline.as_ref().is_some_and(|tl| tl.due(t))
    }

    /// Close due timeline windows with the kernel's cumulative samples.
    pub fn roll(
        &mut self,
        t: f64,
        outstanding: u64,
        device_seconds: f64,
        busy_s: f64,
        n_devices: usize,
    ) {
        if let Some(tl) = &mut self.timeline {
            tl.roll(t, outstanding, device_seconds, busy_s, n_devices);
        }
    }

    /// Forward an event recorded remotely (an instance's trace outbox).
    /// Applies the same gating as the direct recording methods —
    /// decision records additionally require `decisions` in the config —
    /// but folds no timeline counters: outbox events are marks and
    /// decisions, which the timeline never counts.
    pub fn forward(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if matches!(ev, TraceEvent::Decision { .. }) && !self.decisions_on {
            return;
        }
        self.push(ev);
    }

    /// Consume the tracer at end of run: chronological span buffer (ring
    /// unrolled) and the finished timeline block.
    #[allow(clippy::too_many_arguments)]
    pub fn into_output(
        &mut self,
        t_end: f64,
        outstanding: u64,
        device_seconds: f64,
        busy_s: f64,
        n_devices: usize,
        n_instances: usize,
    ) -> (Option<TraceBuffer>, Option<TimelineBlock>) {
        if !self.enabled {
            return (None, None);
        }
        self.enabled = false;
        let mut events = std::mem::take(&mut self.events);
        if self.dropped > 0 {
            // oldest surviving record sits at the overwrite cursor
            events.rotate_left(self.next_overwrite);
        }
        let buffer = TraceBuffer { events, dropped: self.dropped, n_instances };
        let timeline = self
            .timeline
            .take()
            .map(|tl| tl.finish(t_end, outstanding, device_seconds, busy_s, n_devices));
        (Some(buffer), timeline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut tr = Tracer::disabled();
        tr.req(0.5, 1, 0, ReqPhase::Arrival);
        tr.step(0.5, 0.1, 0, 4, true);
        tr.mark(0.5, -1, MarkKind::DeviceFailed, 2.0);
        assert!(!tr.timeline_due(1e9));
        let (buf, tl) = tr.into_output(10.0, 0, 0.0, 0.0, 4, 1);
        assert!(buf.is_none() && tl.is_none());
    }

    #[test]
    fn full_sink_keeps_everything_in_order() {
        let cfg = TelemetryConfig { timeline_window_s: None, ..TelemetryConfig::full() };
        let mut tr = Tracer::new(Some(&cfg));
        for i in 0..100u64 {
            tr.req(i as f64, i, 0, ReqPhase::Arrival);
        }
        let (buf, tl) = tr.into_output(100.0, 0, 0.0, 0.0, 1, 1);
        let buf = buf.unwrap();
        assert!(tl.is_none());
        assert_eq!(buf.events.len(), 100);
        assert_eq!(buf.dropped, 0);
        let ts: Vec<f64> = buf.events.iter().map(|e| e.t()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn ring_sink_overwrites_oldest_and_unrolls() {
        let mut cfg = TelemetryConfig::ring(8);
        cfg.timeline_window_s = None;
        let mut tr = Tracer::new(Some(&cfg));
        for i in 0..20u64 {
            tr.req(i as f64, i, 0, ReqPhase::Arrival);
        }
        let (buf, _) = tr.into_output(20.0, 0, 0.0, 0.0, 1, 1);
        let buf = buf.unwrap();
        assert_eq!(buf.events.len(), 8);
        assert_eq!(buf.dropped, 12);
        // chronological after unroll: the 8 newest records, in order
        let ids: Vec<u64> = buf
            .events
            .iter()
            .map(|e| match e {
                TraceEvent::Req { id, .. } => *id,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(ids, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn ring_recording_does_not_grow_capacity() {
        let mut cfg = TelemetryConfig::ring(16);
        cfg.timeline_window_s = None;
        let mut tr = Tracer::new(Some(&cfg));
        let cap_before = tr.events.capacity();
        for i in 0..1000u64 {
            tr.step(i as f64, 0.01, 3, 8, i % 2 == 0);
        }
        assert_eq!(tr.events.capacity(), cap_before, "ring must never reallocate");
    }

    #[test]
    fn timeline_windows_close_on_boundaries() {
        let cfg = TelemetryConfig { timeline_window_s: Some(1.0), ..TelemetryConfig::full() };
        let mut tr = Tracer::new(Some(&cfg));
        // window [0,1): two arrivals, one completion at 0.8 with 0.3s e2e
        tr.req(0.2, 1, 0, ReqPhase::Arrival);
        tr.req(0.5, 2, 0, ReqPhase::Arrival);
        tr.completion(0.8, 1, 0, 0.3);
        assert!(!tr.timeline_due(0.9));
        assert!(tr.timeline_due(1.2));
        tr.roll(1.2, 5, 2.0, 1.0, 2);
        // window [1,2): one shed
        tr.req(1.5, 2, 0, ReqPhase::Shed);
        let (_, tl) = tr.into_output(2.5, 3, 4.0, 3.0, 2, 1);
        let tl = tl.unwrap();
        assert_eq!(tl.window_s, 1.0);
        // two full windows; the empty partial [2, 2.5) is skipped
        assert_eq!(tl.windows.len(), 2, "{tl:?}");
        let w0 = tl.windows[0];
        assert!((w0.t_s - 1.0).abs() < 1e-12);
        assert_eq!((w0.arrivals, w0.completions, w0.sheds), (2, 1, 0));
        assert_eq!(w0.outstanding, 5);
        assert!((w0.p50_s - 0.3).abs() < 1e-12);
        // busy delta 1.0 over 2 devices × 1s window
        assert!((w0.busy_frac - 0.5).abs() < 1e-12);
        let w1 = tl.windows[1];
        assert!((w1.t_s - 2.0).abs() < 1e-12);
        assert_eq!((w1.arrivals, w1.sheds), (0, 1));
        assert_eq!(w1.p50_s, 0.0, "no completions → zero percentile");
    }

    #[test]
    fn skipped_windows_emit_zero_deltas() {
        let cfg = TelemetryConfig { timeline_window_s: Some(1.0), ..TelemetryConfig::full() };
        let mut tr = Tracer::new(Some(&cfg));
        tr.req(0.1, 1, 0, ReqPhase::Arrival);
        // next event far in the future: windows 1..=5 all close at once
        tr.roll(5.5, 7, 9.0, 4.0, 4);
        let (_, tl) = tr.into_output(5.5, 7, 9.0, 4.0, 4, 1);
        let tl = tl.unwrap();
        assert_eq!(tl.windows.len(), 5);
        assert_eq!(tl.windows[0].arrivals, 1);
        assert!((tl.windows[0].busy_frac - 1.0).abs() < 1e-12, "first gets the delta");
        for w in &tl.windows[1..] {
            assert_eq!(w.arrivals, 0);
            assert_eq!(w.busy_frac, 0.0, "no new busy time in skipped windows");
            assert_eq!(w.outstanding, 7, "samples repeat the crossing event's state");
        }
    }

    #[test]
    fn timeline_json_shape() {
        let block = TimelineBlock {
            window_s: 1.0,
            windows: vec![TimelineWindow {
                t_s: 1.0,
                arrivals: 3,
                completions: 2,
                sheds: 0,
                outstanding: 4,
                p50_s: 0.25,
                p99_s: 0.5,
                device_seconds: 2.0,
                busy_frac: 0.75,
            }],
        };
        let j = block.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("window_s").unwrap().as_f64().unwrap(), 1.0);
        let ws = parsed.get("windows").unwrap().as_arr().unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].get("arrivals").unwrap().as_u64().unwrap(), 3);
        assert_eq!(ws[0].get("busy_frac").unwrap().as_f64().unwrap(), 0.75);
    }
}
