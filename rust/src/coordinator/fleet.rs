//! Fleet-level autoscaling: instance lifecycle + the module-vs-instance
//! arbitration, plus the device-seconds cost ledger.
//!
//! The per-instance controllers (§5) scale *modules*; this controller
//! scales the *fleet*. Each control tick it reads one aggregate signal —
//! mean outstanding requests per active instance, including requests
//! parked at the router — and walks a three-state decision:
//!
//! * **scale out** when the fleet is oversubscribed. The kernel then
//!   arbitrates between the two concrete options at hand by dry-run cost
//!   per unit of added capacity ([`FleetController::arbitrate`]): a layer
//!   replication round on the most-loaded instance (cheap, small capacity,
//!   flows through the existing in-flight [`crate::plan::ScalePlan`]
//!   machinery) versus spinning up a whole new instance (expensive
//!   cold start, a full instance of capacity).
//! * **scale in** when the fleet has been underloaded for several
//!   consecutive ticks: the least-loaded instance is marked *draining* —
//!   the router stops offering it work, it finishes what it holds, and a
//!   later tick *releases* it (frees every ledger allocation), which is
//!   the moment its devices stop billing.
//! * **hold** otherwise, with a cooldown after every action.
//!
//! ### The cost model behind the 46 % claim
//!
//! [`CostLedger`] meters **device-seconds**: a device is billed for every
//! simulated second during which it holds at least one module (weights,
//! replica, or migrated module) of any live instance. Static
//! over-provisioning bills every device for the whole run; the fleet
//! controller bills the small steady-state footprint plus burst capacity
//! only while it exists. `benches/fig1_cost_availability.rs` sweeps the
//! scenario library comparing the two at equal SLO attainment.

use crate::monitor::FleetInputs;
use crate::sim::SimPolicy;

/// Fleet-autoscaling configuration.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Never drain below this many live (active + draining) instances.
    pub min_instances: usize,
    /// Never spin up beyond this many live instances.
    pub max_instances: usize,
    /// Latency between the spin-up decision and the instance accepting
    /// traffic (process launch + weight load; §2.3 reports 8–25 s for a
    /// 13B reload). Billing starts at the decision — the weights are
    /// resident from then on.
    pub cold_start_s: f64,
    /// Scale out when mean outstanding requests per active instance
    /// (router-parked requests included) exceeds this.
    pub scale_out_queue: f64,
    /// Scale in when mean outstanding per active instance is below this…
    pub scale_in_queue: f64,
    /// …for this many consecutive ticks.
    pub idle_ticks_before_drain: u32,
    /// Ticks to wait after any fleet action before acting again.
    pub cooldown_ticks: u32,
    /// Serving policy deployed on spun-up instances.
    pub policy: SimPolicy,
}

impl FleetConfig {
    /// The fig1 bench shape: elastic between `min` and `max` instances,
    /// with the paper's ~8 s cold start.
    pub fn elastic(min: usize, max: usize, policy: SimPolicy) -> FleetConfig {
        FleetConfig {
            min_instances: min,
            max_instances: max,
            cold_start_s: 8.0,
            scale_out_queue: 24.0,
            scale_in_queue: 2.0,
            idle_ticks_before_drain: 3,
            cooldown_ticks: 3,
            policy,
        }
    }
}

/// Fraction of [`FleetConfig::scale_out_queue`] at which the
/// latency-sensitive class's queue alone forces a reactive scale-out
/// under a class-aware policy: premium work waiting half as deep as the
/// mixed-traffic line is already an SLO risk, because it cannot absorb
/// queueing delay the way best-effort work can. Only
/// [`FleetController::pressure_classed`] reads this — classless kernels
/// never take that path.
pub const PREMIUM_PRESSURE_FRACTION: f64 = 0.5;

/// What the fleet controller wants to do this tick (before arbitration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPressure {
    /// Load is inside the healthy band (or the controller is cooling
    /// down) — no lifecycle action.
    Hold,
    /// The fleet is oversubscribed: add capacity (replicate or spin up).
    ScaleOut,
    /// The fleet has been underloaded long enough: drain one instance.
    ScaleIn,
}

/// The capacity-addition option the scale-out arbitration chose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleOutChoice {
    /// Run the replication plan already priced against the live state.
    Replicate,
    /// Spin up a whole new instance.
    SpinUp,
    /// Neither option is available (no plan, no room, at max instances).
    Neither,
}

/// Stateful fleet controller: cooldown + the consecutive-idle counter.
/// Pure decision logic — the simulation kernel executes the outcomes.
#[derive(Debug, Clone)]
pub struct FleetController {
    /// Configuration this controller was built with.
    pub cfg: FleetConfig,
    cooldown: u32,
    idle_ticks: u32,
    /// Lifecycle actions taken (spin-ups + drains), for diagnostics.
    actions: u64,
}

impl FleetController {
    /// Build a controller for the given configuration.
    pub fn new(cfg: FleetConfig) -> FleetController {
        FleetController { cfg, cooldown: 0, idle_ticks: 0, actions: 0 }
    }

    /// Lifecycle actions taken so far.
    pub fn actions_taken(&self) -> u64 {
        self.actions
    }

    /// Stage 1: classify this tick's pressure from the fleet telemetry
    /// window ([`FleetInputs`] — mean outstanding per traffic-accepting
    /// instance, router-parked requests included; `live` counts active +
    /// draining instances, the spin-up/drain bounds).
    pub fn pressure(&mut self, inputs: &FleetInputs) -> FleetPressure {
        let mean_outstanding = inputs.mean_outstanding();
        let live = inputs.live;
        if self.cooldown > 0 {
            self.cooldown -= 1;
            // keep observing idleness through the cooldown so a quiet
            // fleet drains promptly once the cooldown expires
            if mean_outstanding < self.cfg.scale_in_queue {
                self.idle_ticks += 1;
            } else {
                self.idle_ticks = 0;
            }
            return FleetPressure::Hold;
        }
        if mean_outstanding > self.cfg.scale_out_queue && live < self.cfg.max_instances {
            self.idle_ticks = 0;
            self.arm();
            return FleetPressure::ScaleOut;
        }
        if mean_outstanding < self.cfg.scale_in_queue {
            self.idle_ticks += 1;
            if self.idle_ticks >= self.cfg.idle_ticks_before_drain
                && live > self.cfg.min_instances
            {
                self.idle_ticks = 0;
                self.arm();
                return FleetPressure::ScaleIn;
            }
        } else {
            self.idle_ticks = 0;
        }
        FleetPressure::Hold
    }

    /// Class-aware stage 1: judge the latency-sensitive queue *first* —
    /// premium pressure past `scale_out_queue ×`
    /// [`PREMIUM_PRESSURE_FRACTION`] scales out immediately — then fall
    /// through to the ordinary [`FleetController::pressure`] walk for the
    /// mixed signal. The cooldown is decremented exactly once per tick
    /// either way (a premium fire happens only at cooldown zero; every
    /// other path delegates). Classless kernels never call this.
    pub fn pressure_classed(&mut self, inputs: &FleetInputs) -> FleetPressure {
        if self.cooldown == 0
            && inputs.premium_mean_outstanding()
                > self.cfg.scale_out_queue * PREMIUM_PRESSURE_FRACTION
            && inputs.live < self.cfg.max_instances
        {
            self.idle_ticks = 0;
            self.arm();
            return FleetPressure::ScaleOut;
        }
        self.pressure(inputs)
    }

    /// Is the post-action cooldown still running? Predictive proposals
    /// respect it — reactive and predictive actions share one cooldown so
    /// the two controllers cannot double-fire within a window.
    pub fn cooling_down(&self) -> bool {
        self.cooldown > 0
    }

    /// Arm the shared cooldown for an externally-enacted capacity action
    /// (a predictive proposal the kernel executed). Counts toward
    /// [`FleetController::actions_taken`] like any lifecycle action.
    pub fn arm_cooldown(&mut self) {
        self.arm();
    }

    /// Undo the arm for an action an external arbiter vetoed before it
    /// happened (a forecast-gated drain): the cooldown is released and
    /// the action un-counted, so a vetoed no-op can neither suppress the
    /// next controller decision nor inflate the diagnostics.
    pub fn cancel_action(&mut self) {
        self.cooldown = 0;
        self.actions = self.actions.saturating_sub(1);
    }

    /// Stage 2 of scale-out: pick the cheaper capacity per dry-run cost.
    ///
    /// `replication`: `(plan time, capacity gain)` of the candidate layer-
    /// replication round, where capacity gain is the fraction of an
    /// instance-equivalent the round adds (planned replicas / layer
    /// count — full replication of every layer ≈ one extra instance of
    /// decode lanes, Fig. 4). `spin_up`: `(cold start + weight transfer
    /// time, 1.0)` when a device can host a new instance. The option with
    /// the lower cost **per instance-equivalent of capacity** wins; a
    /// replication round that plans nothing, or a full cluster, removes
    /// that option.
    pub fn arbitrate(
        &self,
        replication: Option<(f64, f64)>,
        spin_up: Option<f64>,
    ) -> ScaleOutChoice {
        let rep = replication
            .filter(|&(_, gain)| gain > 0.0)
            .map(|(time_s, gain)| time_s / gain);
        match (rep, spin_up) {
            (Some(r), Some(s)) if r <= s => ScaleOutChoice::Replicate,
            (Some(_), Some(_)) => ScaleOutChoice::SpinUp,
            (Some(_), None) => ScaleOutChoice::Replicate,
            (None, Some(_)) => ScaleOutChoice::SpinUp,
            (None, None) => ScaleOutChoice::Neither,
        }
    }

    fn arm(&mut self) {
        self.cooldown = self.cfg.cooldown_ticks;
        self.actions += 1;
    }
}

// ---- the device-seconds cost ledger ----------------------------------------

/// Meters device-seconds: a device bills for every simulated second during
/// which at least one live instance holds a module on it. The kernel
/// advances the ledger at each event pop (piecewise-constant integration)
/// and adjusts per-device holder refcounts at the discrete points where
/// placements change (deploy, plan op landing, rollback, emergency
/// scale-down, release).
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    /// Per-device count of instances holding ≥1 module there.
    holders: Vec<u32>,
    /// Devices with `holders > 0` (cached — the integration rate).
    billed: usize,
    last_t: f64,
    device_seconds: f64,
}

impl CostLedger {
    /// A ledger for `n_devices`, starting unbilled at t = 0.
    pub fn new(n_devices: usize) -> CostLedger {
        CostLedger { holders: vec![0; n_devices], billed: 0, last_t: 0.0, device_seconds: 0.0 }
    }

    /// Integrate billing up to `now` at the current billed-device count.
    ///
    /// The integral is only correct if events reach the coordinator in
    /// nondecreasing time order — exactly what the event kernels
    /// guarantee (a single queue trivially; the sharded kernel via its
    /// barrier merge). A backwards `now` would mean a shard leaked an
    /// event past its epoch window, so it is a hard error rather than a
    /// silently dropped interval.
    pub fn advance(&mut self, now: f64) {
        assert!(
            now >= self.last_t,
            "billing time went backwards (or NaN): {now} < {}",
            self.last_t
        );
        if now > self.last_t {
            self.device_seconds += (now - self.last_t) * self.billed as f64;
            self.last_t = now;
        }
    }

    /// One instance started holding a module on `device`.
    pub fn acquire(&mut self, device: usize) {
        self.holders[device] += 1;
        if self.holders[device] == 1 {
            self.billed += 1;
        }
    }

    /// One instance stopped holding any module on `device`.
    pub fn release(&mut self, device: usize) {
        debug_assert!(self.holders[device] > 0, "release without acquire");
        self.holders[device] -= 1;
        if self.holders[device] == 0 {
            self.billed -= 1;
        }
    }

    /// Device `device` died: zero its holder refcount so it stops billing
    /// from this instant on — callers must have [`CostLedger::advance`]d
    /// to the failure time first (the kernel does so at every event pop),
    /// so no device-seconds past the failure are ever charged. Returns
    /// the holders that were zeroed (for the audit trail); the caller is
    /// responsible for dropping the device from any cached per-instance
    /// billing lists so later releases do not double-release.
    pub fn fail_device(&mut self, device: usize) -> u32 {
        let zeroed = self.holders[device];
        if zeroed > 0 {
            self.holders[device] = 0;
            self.billed -= 1;
        }
        zeroed
    }

    /// Devices currently billing.
    pub fn billed_devices(&self) -> usize {
        self.billed
    }

    /// Total device-seconds billed so far.
    pub fn device_seconds(&self) -> f64 {
        self.device_seconds
    }
}

// ---- the fleet event log ----------------------------------------------------

/// Lifecycle phase of one logged fleet event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPhase {
    /// A new instance was deployed (billing starts; serving starts after
    /// the cold start).
    SpinUp,
    /// An instance stopped accepting traffic and began draining.
    Drain,
    /// A drained instance released every ledger allocation (billing for
    /// its devices stops unless shared).
    Release,
}

impl FleetPhase {
    /// Stable name used in the golden metrics JSON.
    pub fn name(self) -> &'static str {
        match self {
            FleetPhase::SpinUp => "spin_up",
            FleetPhase::Drain => "drain",
            FleetPhase::Release => "release",
        }
    }
}

/// One timestamped fleet lifecycle record (part of the golden JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetEvent {
    /// Simulated time of the action.
    pub t: f64,
    /// Instance the action applied to.
    pub instance: usize,
    /// Lifecycle phase.
    pub phase: FleetPhase,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;

    fn ctl() -> FleetController {
        let mut cfg = FleetConfig::elastic(2, 6, baselines::cocoserve(32));
        cfg.cooldown_ticks = 1;
        cfg.idle_ticks_before_drain = 2;
        FleetController::new(cfg)
    }

    /// A telemetry window whose mean outstanding per accepting instance
    /// comes out to exactly `mean` over `live` instances.
    fn window(mean: f64, live: usize) -> FleetInputs {
        FleetInputs {
            live,
            accepting: live,
            outstanding: (mean * live as f64).round() as usize,
            ..Default::default()
        }
    }

    #[test]
    fn oversubscription_scales_out_with_cooldown() {
        let mut c = ctl();
        assert_eq!(c.pressure(&window(30.0, 3)), FleetPressure::ScaleOut);
        assert!(c.cooling_down());
        assert_eq!(c.pressure(&window(30.0, 3)), FleetPressure::Hold, "cooling down");
        assert!(!c.cooling_down());
        assert_eq!(c.pressure(&window(30.0, 3)), FleetPressure::ScaleOut);
        assert_eq!(c.actions_taken(), 2);
    }

    #[test]
    fn max_instances_bounds_scale_out() {
        let mut c = ctl();
        assert_eq!(c.pressure(&window(99.0, 6)), FleetPressure::Hold);
    }

    #[test]
    fn sustained_idleness_drains_but_respects_min() {
        let mut c = ctl();
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::Hold); // idle tick 1
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::ScaleIn); // tick 2
        assert_eq!(c.pressure(&window(0.5, 2)), FleetPressure::Hold, "cooldown");
        assert_eq!(c.pressure(&window(0.5, 2)), FleetPressure::Hold, "at min_instances");
    }

    #[test]
    fn load_blip_resets_the_idle_counter() {
        let mut c = ctl();
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::Hold);
        assert_eq!(c.pressure(&window(10.0, 4)), FleetPressure::Hold); // healthy band
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::Hold); // counter restarted
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::ScaleIn);
    }

    #[test]
    fn parked_requests_count_toward_pressure() {
        let mut c = ctl();
        // 10 outstanding over 2 accepting = 5 (healthy band)…
        let mut w = window(5.0, 2);
        assert_eq!(c.pressure(&w), FleetPressure::Hold);
        // …but 40 more parked at the router pushes the mean to 25
        w.parked = 40;
        assert_eq!(c.pressure(&w), FleetPressure::ScaleOut);
    }

    #[test]
    fn premium_pressure_scales_out_at_half_the_mixed_line() {
        let mut c = ctl(); // scale_out_queue = 24
        // mixed mean 5 is the healthy band; premium mean 13 > 24 × 0.5
        let mut w = window(5.0, 2);
        w.premium_outstanding = 26;
        assert_eq!(c.pressure_classed(&w), FleetPressure::ScaleOut);
        assert!(c.cooling_down());
        // cooling: exactly one decrement per tick, premium fire suppressed
        assert_eq!(c.pressure_classed(&w), FleetPressure::Hold);
        assert!(!c.cooling_down());
        // premium parked entries count toward the premium signal
        let mut w2 = window(5.0, 2);
        w2.parked = 26;
        w2.premium_parked = 26;
        assert_eq!(c.pressure_classed(&w2), FleetPressure::ScaleOut);
        // without premium fields the classed walk matches the classless one
        let mut a = ctl();
        let mut b = ctl();
        for &(m, live) in &[(5.0, 2), (30.0, 3), (0.5, 4), (0.5, 4), (0.5, 4)] {
            assert_eq!(a.pressure_classed(&window(m, live)), b.pressure(&window(m, live)));
        }
        // max_instances bounds the premium fire like any scale-out
        let mut c2 = ctl();
        let mut w3 = window(5.0, 6);
        w3.premium_outstanding = 99;
        assert_eq!(c2.pressure_classed(&w3), FleetPressure::Hold);
    }

    #[test]
    fn external_actions_arm_the_shared_cooldown() {
        let mut c = ctl();
        assert!(!c.cooling_down());
        c.arm_cooldown();
        assert!(c.cooling_down());
        assert_eq!(c.actions_taken(), 1);
        // the armed cooldown suppresses the next reactive decision
        assert_eq!(c.pressure(&window(30.0, 3)), FleetPressure::Hold);
    }

    #[test]
    fn cancelled_actions_release_the_cooldown_and_uncount() {
        let mut c = ctl();
        // an idle fleet decides to drain (arms cooldown, counts action)…
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::Hold);
        assert_eq!(c.pressure(&window(0.5, 4)), FleetPressure::ScaleIn);
        assert!(c.cooling_down());
        assert_eq!(c.actions_taken(), 1);
        // …but the drain is vetoed before it happens
        c.cancel_action();
        assert!(!c.cooling_down());
        assert_eq!(c.actions_taken(), 0);
        // the controller is immediately free to decide again
        assert_eq!(c.pressure(&window(30.0, 3)), FleetPressure::ScaleOut);
    }

    #[test]
    fn arbitration_picks_cheaper_capacity() {
        let c = ctl();
        // 0.5 s for 0.1 instance-equivalents = 5 s/inst vs 8 s spin-up
        assert_eq!(c.arbitrate(Some((0.5, 0.1)), Some(8.0)), ScaleOutChoice::Replicate);
        // 2 s for 0.1 = 20 s/inst loses to an 8 s spin-up
        assert_eq!(c.arbitrate(Some((2.0, 0.1)), Some(8.0)), ScaleOutChoice::SpinUp);
        assert_eq!(c.arbitrate(None, Some(8.0)), ScaleOutChoice::SpinUp);
        assert_eq!(c.arbitrate(Some((0.5, 0.1)), None), ScaleOutChoice::Replicate);
        assert_eq!(c.arbitrate(None, None), ScaleOutChoice::Neither);
        // a zero-gain plan is not an option
        assert_eq!(c.arbitrate(Some((0.5, 0.0)), None), ScaleOutChoice::Neither);
    }

    #[test]
    fn cost_ledger_bills_only_held_devices() {
        let mut l = CostLedger::new(3);
        l.advance(5.0);
        assert_eq!(l.device_seconds(), 0.0, "nothing held, nothing billed");
        l.acquire(0);
        l.acquire(0); // second holder on the same device
        l.acquire(2);
        assert_eq!(l.billed_devices(), 2);
        l.advance(7.0); // 2 devices × 2 s
        assert_eq!(l.device_seconds(), 4.0);
        l.release(0);
        assert_eq!(l.billed_devices(), 2, "device 0 still has one holder");
        l.release(0);
        assert_eq!(l.billed_devices(), 1);
        l.advance(10.0); // 1 device × 3 s
        assert_eq!(l.device_seconds(), 7.0);
        l.advance(10.0); // same-time re-advance is a no-op
        assert_eq!(l.device_seconds(), 7.0);
    }

    #[test]
    fn fail_device_stops_billing_at_the_failure_instant() {
        let mut l = CostLedger::new(2);
        l.acquire(0);
        l.acquire(0);
        l.acquire(1);
        l.advance(4.0); // 2 devices × 4 s
        assert_eq!(l.device_seconds(), 8.0);
        assert_eq!(l.fail_device(0), 2, "both holders zeroed at once");
        assert_eq!(l.billed_devices(), 1);
        l.advance(10.0); // only device 1 bills the remaining 6 s
        assert_eq!(l.device_seconds(), 14.0);
        // idempotent: a dead device has no holders left to zero
        assert_eq!(l.fail_device(0), 0);
        assert_eq!(l.billed_devices(), 1);
    }

    #[test]
    #[should_panic(expected = "billing time went backwards")]
    fn cost_ledger_rejects_backwards_time() {
        // A backwards advance means an event escaped its epoch window in
        // the sharded kernel (or a caller reordered events) — the billing
        // integral would silently drop the interval, so it is a hard error.
        let mut l = CostLedger::new(1);
        l.advance(10.0);
        l.advance(9.0);
    }
}
