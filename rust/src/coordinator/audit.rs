//! Append-only audit trail for the failure-domain fleet.
//!
//! Every module operation, device failure, recovery decision, and
//! rollback the kernel performs while a failure schedule is configured
//! lands here as one structured, timestamped record. The trail is:
//!
//! * **append-only** — records are pushed in dispatch order and never
//!   mutated or reordered, so the log *is* the recovery narrative;
//! * **deterministic** — the kernel's event order is deterministic, so
//!   two runs of the same seed produce byte-identical trails
//!   ([`AuditLog::to_json`] uses the same fixed-key-order JSON as the
//!   rest of the golden metrics document);
//! * **replayable** — each record carries enough state (instance,
//!   device, structured detail) that the chaos tests can walk the trail
//!   and re-derive the end state (which instances recovered, which
//!   released, which devices stopped billing when) and diff it against
//!   the report's counters.
//!
//! The trail rides in the golden metrics JSON under the strictly
//! additive `audit` key: runs without a failure schedule carry no trail
//! and therefore stay byte-identical to the pre-failure-domain kernel —
//! the same discipline as the `forecast` and `mempress` blocks.

use crate::util::json::{self, Json};

/// What one audit record describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuditKind {
    /// A scaling-op lifecycle transition (started / completed / aborted)
    /// — the module-op mirror of the `op_events` log, kept in the trail
    /// so recovery interleaves with the ops it raced against.
    ModuleOp,
    /// A device died (spot preemption or hardware loss): its memory
    /// vanished and its billing stopped at this instant.
    DeviceFailed,
    /// An in-flight plan touching the dead device was rolled back via
    /// the undo log (rollback never re-acquires memory).
    PlanRollback,
    /// A module resident only on the dead device was re-placed onto a
    /// surviving device (copy-then-verify-then-free — the free side is
    /// vacuous, the source is gone).
    EmergencyMigration,
    /// A replica on the dead device was dropped from the placement; the
    /// module survives elsewhere, so no bytes moved.
    ReplicaDropped,
    /// In-flight requests were shed back to the router for re-routing
    /// (the no-request-lost path).
    RequestsShed,
    /// An instance released every ledger tag outside the normal
    /// drain-then-release path (it failed or was preempted mid-drain).
    ForcedRelease,
    /// An instance could not be recovered (no surviving device had room
    /// for its modules) and was retired.
    InstanceLost,
}

impl AuditKind {
    /// Stable name used in the golden metrics JSON.
    pub fn name(self) -> &'static str {
        match self {
            AuditKind::ModuleOp => "module_op",
            AuditKind::DeviceFailed => "device_failed",
            AuditKind::PlanRollback => "plan_rollback",
            AuditKind::EmergencyMigration => "emergency_migration",
            AuditKind::ReplicaDropped => "replica_dropped",
            AuditKind::RequestsShed => "requests_shed",
            AuditKind::ForcedRelease => "forced_release",
            AuditKind::InstanceLost => "instance_lost",
        }
    }
}

/// One append-only audit record.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditRecord {
    /// Simulated time of the action.
    pub t: f64,
    /// What happened.
    pub kind: AuditKind,
    /// Instance the action applied to (`None` for fleet-level records
    /// like the failure itself).
    pub instance: Option<usize>,
    /// Device the action applied to (`None` for instance-level records
    /// spanning several devices).
    pub device: Option<usize>,
    /// Compact structured detail (op description, byte counts, request
    /// counts) — deterministic, so it diffs byte-for-byte.
    pub detail: String,
}

/// The append-only audit trail — a push-only vector of records plus the
/// deterministic JSON rendering.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AuditLog {
    records: Vec<AuditRecord>,
}

impl AuditLog {
    /// An empty trail.
    pub fn new() -> AuditLog {
        AuditLog::default()
    }

    /// Append one record (the only mutation the trail supports).
    pub fn push(
        &mut self,
        t: f64,
        kind: AuditKind,
        instance: Option<usize>,
        device: Option<usize>,
        detail: impl Into<String>,
    ) {
        self.records.push(AuditRecord { t, kind, instance, device, detail: detail.into() });
    }

    /// The records, in append order.
    pub fn records(&self) -> &[AuditRecord] {
        &self.records
    }

    /// Number of records appended so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Is the trail empty?
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records of one kind, in append order (replay/diff helper).
    pub fn of_kind(&self, kind: AuditKind) -> impl Iterator<Item = &AuditRecord> {
        self.records.iter().filter(move |r| r.kind == kind)
    }

    /// Deterministic JSON rendering: an array of fixed-key-order objects
    /// (`detail`, `device`, `instance`, `kind`, `t`; absent
    /// instance/device render as -1 so every record has the same shape).
    pub fn to_json(&self) -> Json {
        json::arr(self.records.iter().map(|r| {
            json::obj(vec![
                ("detail", json::s(&r.detail)),
                ("device", json::num(r.device.map_or(-1.0, |d| d as f64))),
                ("instance", json::num(r.instance.map_or(-1.0, |i| i as f64))),
                ("kind", json::s(r.kind.name())),
                ("t", json::num(r.t)),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AuditLog {
        let mut log = AuditLog::new();
        log.push(1.0, AuditKind::DeviceFailed, None, Some(2), "lost=3GiB holders=1");
        log.push(1.0, AuditKind::PlanRollback, Some(0), Some(2), "ops_undone=2");
        log.push(1.0, AuditKind::EmergencyMigration, Some(0), Some(1), "migrate L3->d1");
        log.push(1.0, AuditKind::RequestsShed, Some(0), None, "shed=4");
        log
    }

    #[test]
    fn trail_is_append_only_and_ordered() {
        let log = sample();
        assert_eq!(log.len(), 4);
        assert_eq!(log.records()[0].kind, AuditKind::DeviceFailed);
        assert_eq!(log.records()[3].kind, AuditKind::RequestsShed);
        assert_eq!(log.of_kind(AuditKind::PlanRollback).count(), 1);
        assert!(!log.is_empty());
        assert!(AuditLog::new().is_empty());
    }

    #[test]
    fn json_is_deterministic_and_parses() {
        let a = sample().to_json().to_string();
        let b = sample().to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].req("kind").as_str(), Some("device_failed"));
        assert_eq!(arr[0].req("instance").as_f64(), Some(-1.0));
        assert_eq!(arr[0].req("device").as_f64(), Some(2.0));
        assert_eq!(arr[2].req("detail").as_str(), Some("migrate L3->d1"));
    }

    #[test]
    fn every_kind_has_a_stable_name() {
        let kinds = [
            AuditKind::ModuleOp,
            AuditKind::DeviceFailed,
            AuditKind::PlanRollback,
            AuditKind::EmergencyMigration,
            AuditKind::ReplicaDropped,
            AuditKind::RequestsShed,
            AuditKind::ForcedRelease,
            AuditKind::InstanceLost,
        ];
        let names: std::collections::BTreeSet<&str> =
            kinds.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), kinds.len(), "names must be unique");
    }
}
