//! Timing + report harness for `benches/` (std-only criterion replacement).
//!
//! Each paper table/figure has a `[[bench]]` target (harness = false) that
//! builds workloads, runs the system/simulator, and prints the same
//! rows/series the paper reports. This module provides:
//!
//! * [`time_it`] — warmup + timed iterations with mean/p50/p95,
//! * [`Table`] — aligned text tables matching the paper's row format,
//! * [`Report`] — JSON sidecar written to `target/bench-reports/` so
//!   EXPERIMENTS.md numbers are regenerable byte-for-byte.

use std::io::Write as _;
use std::time::Instant;

use super::json::{self, Json};
use super::stats::Summary;
use crate::cluster::Cluster;
use crate::model::cost::CostModel;
use crate::model::ModelConfig;
use crate::ops::{ModuleOps, PlanExecutor};
use crate::placement::Placement;
use crate::plan::{ModuleOp, ScalePlan};

/// Shared fixture for the fig6/eq4 benches: a 13B placement with the
/// first `n_rep` layers replicated to degree `dop`, replicas spread
/// round-robin over devices 1..4 — built by planning one replication
/// batch and executing it against a scratch paper-testbed cluster.
pub fn replicated_placement_13b(n_rep: usize, dop: usize) -> Placement {
    let model = ModelConfig::llama2_13b();
    let mut p = Placement::single_device(model.n_layers, 0);
    let cm = CostModel::new(model);
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let mut scratch = Cluster::paper_testbed();
    ops.deploy_instance(&mut scratch, &p).unwrap();
    let mut plan = ScalePlan::new();
    for extra in 0..dop.saturating_sub(1) {
        for l in 0..n_rep {
            let op = ModuleOp::Replicate { layer: l, dst: 1 + (extra + l) % 3 };
            if !plan.ops.contains(&op) {
                plan.push(op);
            }
        }
    }
    PlanExecutor::new(&ops).execute(&mut scratch, &mut p, &plan).unwrap();
    p
}

/// Timing result for one benchmarked operation.
#[derive(Debug, Clone)]
pub struct Timing {
    /// Recorded iterations (excludes warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Fastest iteration in seconds.
    pub min_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    Timing {
        iters,
        mean_s: s.mean(),
        p50_s: s.p50(),
        p95_s: s.p95(),
        min_s: s.min(),
    }
}

/// Aligned plain-text table writer (the bench stdout format).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Print the table with right-aligned, width-fitted columns.
    pub fn print(&self) {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        println!("{}", w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// JSON sidecar report: one per bench, named by experiment id.
pub struct Report {
    name: String,
    fields: Vec<(String, Json)>,
}

impl Report {
    /// A report that will be written as `<name>.json`.
    pub fn new(name: &str) -> Self {
        Report { name: name.to_string(), fields: vec![] }
    }

    /// Set a top-level field.
    pub fn set(&mut self, key: &str, v: Json) {
        self.fields.push((key.to_string(), v));
    }

    /// Set a numeric-array field.
    pub fn series(&mut self, key: &str, xs: &[f64]) {
        self.set(key, json::arr(xs.iter().map(|&x| json::num(x))));
    }

    /// Write to `target/bench-reports/<name>.json`.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("target/bench-reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.json", self.name));
        let obj = Json::Obj(
            self.fields
                .iter()
                .cloned()
                .collect::<std::collections::BTreeMap<_, _>>(),
        );
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{obj}")?;
        Ok(path)
    }
}

/// Format seconds human-readably (µs/ms/s) for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures_something() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0 && t.min_s <= t.p95_s);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["1".into()]);
        }));
        assert!(res.is_err());
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn report_writes_json() {
        let mut r = Report::new("unit-test-report");
        r.set("k", json::num(1.0));
        r.series("xs", &[1.0, 2.0]);
        let p = r.write().unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        let j = Json::parse(txt.trim()).unwrap();
        assert_eq!(j.req("k").as_f64(), Some(1.0));
        std::fs::remove_file(p).ok();
    }
}
