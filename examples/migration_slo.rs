//! Migration vs. the latency cliff — the paper's Fig. 3 scenario as a
//! narrative demo.
//!
//! A single 13B instance on one A100 shares the device with another tenant.
//! Under a 50-RPS surge, the default (static) deployment hits repeated KV
//! OOMs and the latency cliff; CoCoServe's scale-down migrates module(s)
//! (KV cache first, then a decoder layer) to the free device and keeps
//! latency flat.
//!
//! ```bash
//! cargo run --release --example migration_slo
//! ```

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, Simulation};
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn run(policy: cocoserve::sim::SimPolicy, label: &str) {
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::paper_testbed();
    // another tenant occupies most of device 0's headroom
    cluster
        .device_mut(0)
        .alloc("other-tenant", 13.0 * GIB)
        .unwrap();
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
    let trace = Trace::generate(
        Arrival::Poisson { rps: 50.0 },
        LengthDist::alpaca(),
        20.0,
        3,
    );
    let r = sim.run(&trace, 20.0);
    let mut lat = r.merged_latency();
    println!(
        "{label:<22} mean {:>6.2}s  p95 {:>6.2}s  OOM {:>3}  migrations/evictions {:>2}  SLO {:>5.1}%",
        lat.mean(),
        lat.p95(),
        r.total_oom_events,
        r.scale_downs,
        r.slo_attainment() * 100.0
    );
}

fn main() {
    println!("== Fig. 3 scenario: 50 RPS surge on a memory-constrained device ==\n");
    run(baselines::hft(16), "default (HFT-like)");
    run(baselines::vllm_like(48), "vLLM-like (preempt)");
    run(baselines::cocoserve(48), "CoCoServe (migrate)");
    println!(
        "\nCoCoServe's Algorithm 2 migrates memory-intensive modules off the\n\
         hot device instead of failing the batch — the paper's ~70% latency\n\
         reduction mechanism at 50–55 RPS."
    );
}
