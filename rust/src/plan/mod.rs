//! Declarative scaling plans — the *what* of a scaling decision, decoupled
//! from the *when* and *how* of its execution.
//!
//! The paper's §3.1 claim is that module operations are cheap enough to run
//! **while serving continues**. Modeling that precisely requires scaling to
//! be a first-class, timed, abortable activity instead of an instantaneous
//! side effect — so the scaling stack is split three ways:
//!
//! 1. **Planners** ([`crate::autoscale::scale_up`] /
//!    [`crate::autoscale::scale_down`]) are *pure*: they read the cluster
//!    and placement and return a [`ScalePlan`], never a mutation.
//! 2. **Plans** (this module) are validated, costed batches of
//!    [`ModuleOp`]s. [`ScalePlan::dry_run`] prices a plan against the
//!    current ledgers without touching them; the dry-run cost equals the
//!    executed cost *exactly* (Table 2 parity) because both walk the same
//!    state evolution.
//! 3. **The executor** ([`crate::ops::PlanExecutor`]) applies a plan with
//!    two-phase prepare/commit semantics: a mid-plan failure (e.g.
//!    [`crate::ops::OpError::DestinationOom`]) rolls every prior op back,
//!    leaving cluster allocations and placement byte-identical to the
//!    pre-plan state.
//!
//! The simulation kernel executes plans *in flight*: each op becomes an
//! `OpStarted`/`OpCompleted` event pair whose duration comes from the
//! plan's costed ops, so replication genuinely overlaps serving and
//! migration blocks only the moved module (see `sim`).

use crate::cluster::{Cluster, ShadowLedger};
use crate::model::{ModuleId, ModuleKind};
use crate::ops::{ModuleOps, OpCost, OpError, PlanExecution};
use crate::placement::Placement;

/// One primitive module operation (§3.1): the unit of a [`ScalePlan`].
///
/// Sources are implicit — resolved from the placement at validation /
/// execution time — so a plan stays valid under re-planning as long as the
/// ops themselves remain feasible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ModuleOp {
    /// Copy decoder layer `layer` onto `dst`, registering a replica
    /// (Fig. 4). The source copy keeps serving during the transfer.
    Replicate { layer: usize, dst: usize },
    /// Move decoder layer `layer`'s primary residence to `dst` (Fig. 5).
    /// The layer is unavailable for the transfer's duration.
    MigrateLayer { layer: usize, dst: usize },
    /// Move a sub-layer module (attention, FFN, projection, KV cache) to
    /// `dst`. `payload_bytes` covers dynamic contents (live KV cache).
    MigrateModule { module: ModuleId, dst: usize, payload_bytes: f64 },
    /// Drop the replica of `layer` on `device` (scale-down phase 2).
    Evict { layer: usize, device: usize },
    /// Rewrite decoder layer `layer`'s weights on `device` from `from`- to
    /// `to`-byte elements in place (memory-pressure relief: int8 swap frees
    /// roughly half the layer's bytes and shrinks its roofline weight-read
    /// term, at a per-step quality penalty —
    /// [`crate::model::cost::SWAP_QUALITY_PENALTY_PER_STEP`]).
    SwapPrecision { layer: usize, device: usize, from: usize, to: usize },
}

impl ModuleOp {
    /// Does executing this op take a serving-path module offline for the
    /// op's duration? Replication never does (the source keeps serving);
    /// migration blocks exactly the moved module; eviction is metadata.
    /// Precision swaps never block: the full-precision copy serves until
    /// the quantized rewrite lands and is switched in atomically.
    pub fn blocks_serving(&self) -> bool {
        matches!(self, ModuleOp::MigrateLayer { .. } | ModuleOp::MigrateModule { .. })
    }

    /// Is this a replication (drives the post-plan inter-replica
    /// communication setup barrier, §6.5)?
    pub fn is_replication(&self) -> bool {
        matches!(self, ModuleOp::Replicate { .. })
    }

    /// Does this op write `device` (as destination or in-place target)?
    /// The failure-recovery path uses this to decide whether an in-flight
    /// plan must roll back when a device dies mid-plan; source devices
    /// are covered separately by the instance's resident device set.
    pub fn touches_device(&self, device: usize) -> bool {
        match *self {
            ModuleOp::Replicate { dst, .. }
            | ModuleOp::MigrateLayer { dst, .. }
            | ModuleOp::MigrateModule { dst, .. } => dst == device,
            ModuleOp::Evict { device: d, .. }
            | ModuleOp::SwapPrecision { device: d, .. } => d == device,
        }
    }

    /// Compact human-readable form for logs and event records.
    pub fn describe(&self) -> String {
        match self {
            ModuleOp::Replicate { layer, dst } => format!("replicate L{layer}->d{dst}"),
            ModuleOp::MigrateLayer { layer, dst } => format!("migrate L{layer}->d{dst}"),
            ModuleOp::MigrateModule { module, dst, .. } => {
                format!("migrate {module}->d{dst}")
            }
            ModuleOp::Evict { layer, device } => format!("evict L{layer}@d{device}"),
            ModuleOp::SwapPrecision { layer, device, from, to } => {
                format!("swap L{layer}@d{device} {from}B->{to}B")
            }
        }
    }
}

/// Why a plan was refused before execution, or where it failed during it.
#[derive(Debug)]
pub enum PlanError {
    /// Validation rejected op `op_idx` — nothing was touched.
    Rejected { op_idx: usize, reason: String },
    /// Execution (or dry-run) failed at op `op_idx`. After an execution
    /// failure the executor has already rolled back; state is pre-plan.
    Failed { op_idx: usize, error: OpError },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Rejected { op_idx, reason } => {
                write!(f, "plan rejected at op {op_idx}: {reason}")
            }
            PlanError::Failed { op_idx, error } => {
                write!(f, "plan failed at op {op_idx}: {error}")
            }
        }
    }
}

impl std::error::Error for PlanError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PlanError::Failed { error, .. } => Some(error),
            PlanError::Rejected { .. } => None,
        }
    }
}

/// Full price of a plan: per-op costs (event durations in the simulator)
/// plus their merged total. Produced identically by [`ScalePlan::dry_run`]
/// and [`crate::ops::PlanExecutor::execute`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlanCost {
    /// Cost of each op, in plan order (the simulator's event durations).
    pub per_op: Vec<OpCost>,
    /// Merged total across every op.
    pub total: OpCost,
}

impl PlanCost {
    /// Append one op's cost, folding it into the total.
    pub fn push(&mut self, c: OpCost) {
        self.total = self.total.merge(c);
        self.per_op.push(c);
    }

    /// Total plan time in seconds.
    pub fn time_s(&self) -> f64 {
        self.total.time_s
    }
}

/// An ordered batch of module operations, executed atomically by the
/// [`crate::ops::PlanExecutor`] or op-by-op (in flight) by the simulator.
///
/// Launch cost amortizes across consecutive ops of the same kind to the
/// same destination — the Table 2 batch shape (`n` layers in one
/// operation pay one launch).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScalePlan {
    /// The operations, in execution order.
    pub ops: Vec<ModuleOp>,
}

impl ScalePlan {
    /// An empty plan.
    pub fn new() -> ScalePlan {
        ScalePlan::default()
    }

    /// Append one operation.
    pub fn push(&mut self, op: ModuleOp) {
        self.ops.push(op);
    }

    /// Number of operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Does the plan contain no operations?
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The Table 2 batch-replication shape: `layers` onto one destination.
    pub fn replicate_batch(layers: &[usize], dst: usize) -> ScalePlan {
        ScalePlan {
            ops: layers.iter().map(|&layer| ModuleOp::Replicate { layer, dst }).collect(),
        }
    }

    /// The Table 2 batch-migration shape.
    pub fn migrate_batch(layers: &[usize], dst: usize) -> ScalePlan {
        ScalePlan {
            ops: layers.iter().map(|&layer| ModuleOp::MigrateLayer { layer, dst }).collect(),
        }
    }

    /// Check feasibility against the *current* cluster + placement without
    /// touching either: index ranges, residency rules, and destination
    /// capacity, walking the plan's own state evolution (an op may depend
    /// on memory freed or residency created by an earlier op).
    pub fn validate(
        &self,
        ops: &ModuleOps<'_>,
        cluster: &Cluster,
        placement: &Placement,
    ) -> Result<(), PlanError> {
        let mut pl = placement.clone();
        let mut free: Vec<f64> =
            (0..cluster.n()).map(|d| cluster.device(d).free_bytes()).collect();
        let reject = |op_idx: usize, reason: String| -> Result<(), PlanError> {
            Err(PlanError::Rejected { op_idx, reason })
        };
        for (i, op) in self.ops.iter().enumerate() {
            match *op {
                ModuleOp::Replicate { layer, dst } => {
                    if dst >= cluster.n() {
                        return reject(i, format!("unknown device {dst}"));
                    }
                    if layer >= pl.n_layers {
                        return reject(i, format!("layer {layer} out of range"));
                    }
                    if pl.holds(layer, dst) {
                        return reject(i, format!("layer {layer} already on device {dst}"));
                    }
                    let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
                    if bytes > free[dst] {
                        return reject(i, format!("device {dst} lacks {bytes:.0} B"));
                    }
                    free[dst] -= bytes;
                    pl.add_replica(layer, dst);
                }
                ModuleOp::MigrateLayer { layer, dst } => {
                    if dst >= cluster.n() {
                        return reject(i, format!("unknown device {dst}"));
                    }
                    if layer >= pl.n_layers {
                        return reject(i, format!("layer {layer} out of range"));
                    }
                    let src = pl.primary_device(layer);
                    if src == dst || pl.holds(layer, dst) {
                        return reject(i, format!("layer {layer} already on device {dst}"));
                    }
                    let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
                    if bytes > free[dst] {
                        return reject(i, format!("device {dst} lacks {bytes:.0} B"));
                    }
                    // Source bytes are released only at plan commit
                    // (copy-then-free), so they are never credited here.
                    free[dst] -= bytes;
                    pl.migrate_layer(layer, dst);
                }
                ModuleOp::MigrateModule { module, dst, payload_bytes } => {
                    if dst >= cluster.n() {
                        return reject(i, format!("unknown device {dst}"));
                    }
                    if module.kind == ModuleKind::DecoderLayer {
                        return reject(i, "whole layers use MigrateLayer".into());
                    }
                    if let Some(l) = module.layer {
                        if l >= pl.n_layers {
                            return reject(i, format!("layer {l} out of range"));
                        }
                    }
                    if payload_bytes < 0.0 || !payload_bytes.is_finite() {
                        return reject(i, format!("bad payload {payload_bytes}"));
                    }
                    let src = pl.module_device(module);
                    if src == dst {
                        return reject(i, format!("module {module} already on device {dst}"));
                    }
                    let bytes = ops.module_bytes(module.kind) + payload_bytes;
                    if bytes > free[dst] {
                        return reject(i, format!("device {dst} lacks {bytes:.0} B"));
                    }
                    free[dst] -= bytes;
                    // The source may not carry a dedicated ledger tag (the
                    // module ships inside its layer's deployment alloc), so
                    // freed source bytes are not credited predictively.
                    pl.migrate_module(module, dst);
                }
                ModuleOp::Evict { layer, device } => {
                    if device >= cluster.n() {
                        return reject(i, format!("unknown device {device}"));
                    }
                    if layer >= pl.n_layers {
                        return reject(i, format!("layer {layer} out of range"));
                    }
                    if !pl.remove_replica(layer, device) {
                        return reject(i, format!("no replica of layer {layer} on {device}"));
                    }
                    // eviction's free is deferred to commit — no credit
                }
                ModuleOp::SwapPrecision { layer, device, from, to } => {
                    if device >= cluster.n() {
                        return reject(i, format!("unknown device {device}"));
                    }
                    if layer >= pl.n_layers {
                        return reject(i, format!("layer {layer} out of range"));
                    }
                    if !pl.holds(layer, device) {
                        return reject(i, format!("layer {layer} not resident on {device}"));
                    }
                    if from == to {
                        return reject(i, format!("no-op swap ({from}B->{to}B)"));
                    }
                    if !(1..=4).contains(&from) || !(1..=4).contains(&to) {
                        return reject(i, format!("unsupported precision {from}B->{to}B"));
                    }
                    // Unlike migration/eviction, the swap resizes its ledger
                    // allocation in place at apply time, so a shrink's bytes
                    // are genuinely available to later ops — credit them.
                    let delta = ops.swap_delta_bytes(from, to);
                    if delta > free[device] {
                        return reject(i, format!("device {device} lacks {delta:.0} B"));
                    }
                    free[device] -= delta;
                }
            }
        }
        Ok(())
    }

    /// Price the plan against the current state **without mutating it**:
    /// replays the plan over a copy-on-write [`ShadowLedger`] (free-bytes
    /// + residency deltas only — the full cluster is never cloned) through
    /// the exact code path the executor uses, so the returned [`PlanCost`]
    /// equals the executed cost bit-for-bit (Table 2 parity contract).
    pub fn dry_run(
        &self,
        ops: &ModuleOps<'_>,
        cluster: &Cluster,
        placement: &Placement,
    ) -> Result<PlanCost, PlanError> {
        let mut ledger = ShadowLedger::new(cluster);
        let mut pl = placement.clone();
        let mut exec = PlanExecution::new();
        for (i, op) in self.ops.iter().enumerate() {
            exec.apply_next(ops, &mut ledger, &mut pl, op)
                .map_err(|error| PlanError::Failed { op_idx: i, error })?;
        }
        Ok(exec.commit(&mut ledger))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cost::CostModel;
    use crate::model::ModelConfig;
    use crate::ops::{PlanExecutor, MIGRATION_LAUNCH_S, REPLICATION_LAUNCH_S};

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(40, 0);
        (cm, cluster, placement)
    }

    #[test]
    fn validate_accepts_feasible_plans() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let plan = ScalePlan::replicate_batch(&[0, 1, 2], 1);
        plan.validate(&ops, &cl, &pl).unwrap();
    }

    #[test]
    fn validate_rejects_double_residency() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // second op replicates a layer the first op already placed on d1
        let mut plan = ScalePlan::new();
        plan.push(ModuleOp::Replicate { layer: 3, dst: 1 });
        plan.push(ModuleOp::Replicate { layer: 3, dst: 1 });
        let err = plan.validate(&ops, &cl, &pl).unwrap_err();
        assert!(matches!(err, PlanError::Rejected { op_idx: 1, .. }), "{err}");
    }

    #[test]
    fn validate_rejects_predicted_oom() {
        let (cm, mut cl, pl) = setup();
        cl.device_mut(1).alloc("hog", cl.device(1).free_bytes() - 1.0).unwrap();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let plan = ScalePlan::replicate_batch(&[0], 1);
        assert!(matches!(
            plan.validate(&ops, &cl, &pl),
            Err(PlanError::Rejected { op_idx: 0, .. })
        ));
    }

    #[test]
    fn validate_never_credits_deferred_frees() {
        // Frees (migration sources, evictions) happen at plan *commit*,
        // after every alloc — so validation must not count them as
        // available capacity, in either op order.
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ex = PlanExecutor::new(&ops);
        ex.execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[7], 1)).unwrap();
        let slack = ops.module_bytes(ModuleKind::DecoderLayer) * 0.5;
        let hog = cl.device(1).free_bytes() - slack;
        cl.device_mut(1).alloc("hog", hog).unwrap();
        // evicting first does NOT make room for the new replica pre-commit
        let mut plan = ScalePlan::new();
        plan.push(ModuleOp::Evict { layer: 7, device: 1 });
        plan.push(ModuleOp::Replicate { layer: 8, dst: 1 });
        assert!(matches!(
            plan.validate(&ops, &cl, &pl),
            Err(PlanError::Rejected { op_idx: 1, .. })
        ));
        // with a full slot free, the same plan validates and executes
        cl.device_mut(1).free("hog").unwrap();
        let hog = cl.device(1).free_bytes() - 1.5 * ops.module_bytes(ModuleKind::DecoderLayer);
        cl.device_mut(1).alloc("hog", hog).unwrap();
        plan.validate(&ops, &cl, &pl).unwrap();
        ex.execute(&mut cl, &mut pl, &plan).unwrap();
        assert_eq!(pl.degree(7), 1);
        assert!(pl.layer_devices(8).contains(&1));
    }

    #[test]
    fn dry_run_leaves_state_untouched() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let used_before: Vec<f64> =
            (0..cl.n()).map(|d| cl.device(d).used_bytes()).collect();
        let plan = ScalePlan::replicate_batch(&[0, 1, 2, 3], 1);
        let cost = plan.dry_run(&ops, &cl, &pl).unwrap();
        assert!(cost.total.time_s > REPLICATION_LAUNCH_S);
        assert_eq!(cost.per_op.len(), 4);
        for d in 0..cl.n() {
            assert_eq!(cl.device(d).used_bytes(), used_before[d]);
        }
        assert_eq!(pl.degree(0), 1, "dry run must not register replicas");
    }

    #[test]
    fn launch_amortizes_within_same_destination_runs() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let batch = ScalePlan::replicate_batch(&[0, 1, 2, 3], 1)
            .dry_run(&ops, &cl, &pl)
            .unwrap();
        // four separate single-op plans each pay the launch
        let mut singles = 0.0;
        for l in 0..4usize {
            singles += ScalePlan::replicate_batch(&[l], 1)
                .dry_run(&ops, &cl, &pl)
                .unwrap()
                .total
                .time_s;
        }
        assert!(batch.total.time_s < singles);
        // only the first op of the run carries the launch term
        assert!(batch.per_op[0].time_s > REPLICATION_LAUNCH_S);
        assert!(batch.per_op[1].time_s < MIGRATION_LAUNCH_S);
    }

    #[test]
    fn dry_run_detects_execution_failures() {
        let (cm, mut cl, pl) = setup();
        cl.device_mut(1).alloc("hog", cl.device(1).free_bytes() - 1.0).unwrap();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let plan = ScalePlan::replicate_batch(&[0, 1], 1);
        assert!(matches!(
            plan.dry_run(&ops, &cl, &pl),
            Err(PlanError::Failed { op_idx: 0, error: OpError::DestinationOom(_) })
        ));
    }

    #[test]
    fn describe_is_compact() {
        assert_eq!(
            ModuleOp::Replicate { layer: 3, dst: 1 }.describe(),
            "replicate L3->d1"
        );
        assert_eq!(ModuleOp::Evict { layer: 2, device: 0 }.describe(), "evict L2@d0");
        assert_eq!(
            ModuleOp::SwapPrecision { layer: 3, device: 0, from: 2, to: 1 }.describe(),
            "swap L3@d0 2B->1B"
        );
        assert!(ModuleOp::MigrateLayer { layer: 0, dst: 2 }.blocks_serving());
        assert!(!ModuleOp::Replicate { layer: 0, dst: 2 }.blocks_serving());
        assert!(!ModuleOp::Evict { layer: 0, device: 2 }.blocks_serving());
        assert!(
            !ModuleOp::SwapPrecision { layer: 0, device: 2, from: 2, to: 1 }.blocks_serving()
        );
    }

    #[test]
    fn validate_swap_requires_residency_and_distinct_precisions() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // everything lives on device 0 — a swap on d1 targets nothing
        let mut plan = ScalePlan::new();
        plan.push(ModuleOp::SwapPrecision { layer: 3, device: 1, from: 2, to: 1 });
        assert!(matches!(
            plan.validate(&ops, &cl, &pl),
            Err(PlanError::Rejected { op_idx: 0, .. })
        ));
        let mut noop = ScalePlan::new();
        noop.push(ModuleOp::SwapPrecision { layer: 3, device: 0, from: 2, to: 2 });
        assert!(noop.validate(&ops, &cl, &pl).is_err());
        let mut ok = ScalePlan::new();
        ok.push(ModuleOp::SwapPrecision { layer: 3, device: 0, from: 2, to: 1 });
        ok.validate(&ops, &cl, &pl).unwrap();
    }

    #[test]
    fn validate_credits_swap_shrink_to_later_ops() {
        // A quantization swap frees bytes at apply time (in-place resize),
        // so a later replicate may rely on them — unlike eviction's
        // deferred free.
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ex = PlanExecutor::new(&ops);
        ex.execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[0], 1)).unwrap();
        let layer_bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        let delta = ops.swap_delta_bytes(2, 1);
        assert!(delta < 0.0, "quantization must shrink: {delta}");
        // leave d1 too tight for a replica alone, but wide enough once the
        // swap's shrink is credited
        let hog = cl.device(1).free_bytes() - 0.6 * layer_bytes;
        cl.device_mut(1).alloc("hog", hog).unwrap();
        let alone = ScalePlan::replicate_batch(&[1], 1);
        assert!(alone.validate(&ops, &cl, &pl).is_err());
        let mut plan = ScalePlan::new();
        plan.push(ModuleOp::SwapPrecision { layer: 0, device: 1, from: 2, to: 1 });
        plan.push(ModuleOp::Replicate { layer: 1, dst: 1 });
        plan.validate(&ops, &cl, &pl).unwrap();
    }

    /// Table 2-style parity for the new op: dry-run cost == executed cost,
    /// and the ledger shrinks by exactly the quantization delta.
    #[test]
    fn swap_dry_run_equals_executed() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        ops.deploy_instance(&mut cl, &pl).unwrap();
        let used_before = cl.device(0).used_bytes();
        let mut plan = ScalePlan::new();
        plan.push(ModuleOp::SwapPrecision { layer: 5, device: 0, from: 2, to: 1 });
        plan.push(ModuleOp::SwapPrecision { layer: 6, device: 0, from: 2, to: 1 });
        let dry = plan.dry_run(&ops, &cl, &pl).unwrap();
        let executed = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &plan).unwrap();
        assert_eq!(dry, executed, "swap parity must be bit-for-bit");
        let delta = ops.swap_delta_bytes(2, 1);
        assert_eq!(cl.device(0).used_bytes(), used_before + 2.0 * delta);
        assert!(executed.total.dst_bytes < 0.0, "quantizing frees bytes");
        // swapping back restores the original footprint bit-for-bit
        let mut back = ScalePlan::new();
        back.push(ModuleOp::SwapPrecision { layer: 5, device: 0, from: 1, to: 2 });
        back.push(ModuleOp::SwapPrecision { layer: 6, device: 0, from: 1, to: 2 });
        PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &back).unwrap();
        assert_eq!(cl.device(0).used_bytes(), used_before);
    }
}
