//! Discrete-event cluster simulator — the paper-scale experiment harness.
//!
//! Runs LLaMA-13B/70B-class instances over the A100-calibrated [`cluster`]
//! using the [`model::cost`] arithmetic for step latencies (roofline:
//! compute-bound prefill, memory-bound decode — §2.1), the real
//! [`scheduler`], [`placement`], [`ops`] and [`autoscale`] code paths, and
//! the [`kvcache`] allocators for memory accounting. This is the substrate
//! substitution documented in DESIGN.md: the tensors are not computed (that
//! is the tiny-model real path in [`engine`]), but every *decision* the
//! serving system makes — batching, placement, scaling, OOM handling — is
//! executed by the same code a real deployment would run.
//!
//! [`cluster`]: crate::cluster
//! [`model::cost`]: crate::model::cost
//! [`scheduler`]: crate::scheduler
//! [`placement`]: crate::placement
//! [`ops`]: crate::ops
//! [`autoscale`]: crate::autoscale
//! [`kvcache`]: crate::kvcache
//! [`engine`]: crate::engine

use crate::autoscale::{
    scale_down, scale_up, Controller, ControllerConfig, Decision, Pressure,
    ScaleDownConfig, ScaleUpConfig,
};
use crate::cluster::Cluster;
use crate::kvcache::{ContiguousKvCache, KvCache, PagedKvCache};
use crate::model::cost::{CostModel, Shape};
use crate::model::{ModelConfig, ModuleId, ModuleKind};
use crate::monitor::{Completion, Monitor};
use crate::ops::{ModuleOps, REPLICA_COMM_SETUP_S};
use crate::placement::Placement;
use crate::scheduler::{split_batch, Scheduler, SchedulerConfig, Step};
use crate::workload::Trace;

/// Serving-path pause for one background scaling round (synchronization
/// barrier while dataflow hooks swap in; the weight copy itself overlaps
/// serving — §8 measures <3 % neighbour jitter).
pub const SYNC_PAUSE_S: f64 = 0.05;

/// Fraction of a decode step the SMs are actually busy (bandwidth-bound
/// GEMV) — the compute-utilization signal NVML reports in Fig. 2.
pub const DECODE_BUSY_FRACTION: f64 = 0.65;

/// What an instance does when a KV allocation hits device OOM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OomBehavior {
    /// HFT-like: the step fails; affected requests pay a heavy reload
    /// penalty and retry (the paper's 37 s latency cliff, Fig. 3).
    FailBatch,
    /// vLLM-like: preempt the newest sequences (drop + requeue) until the
    /// allocation fits.
    Preempt,
    /// CoCoServe: trigger Algorithm 2 (migrate KV / evict / reduce batch).
    ScaleDown,
}

/// Per-instance serving policy — baselines and CoCoServe differ only here.
#[derive(Debug, Clone, Copy)]
pub struct SimPolicy {
    pub scheduler: SchedulerConfig,
    /// Paged (vLLM/CoCo) vs contiguous max-length (HFT) KV allocation.
    pub paged_kv: bool,
    /// Run the §5 controller loop (CoCoServe only).
    pub autoscale: bool,
    pub oom: OomBehavior,
}

/// Simulation-wide configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: ModelConfig,
    /// bf16 at paper scale.
    pub dtype_bytes: usize,
    /// End-to-end latency SLO (seconds).
    pub slo_latency_s: f64,
    /// Controller tick period (seconds).
    pub controller_tick_s: f64,
    /// γ for Algorithm 1 (Eq. 4). Derived from cluster constants if None.
    pub gamma: Option<f64>,
    /// Penalty charged to requests caught in an HFT OOM (model reload —
    /// §2.3 reports 8–25 s for a 13B instance).
    pub oom_penalty_s: f64,
    /// Max sequences a device's KV pool aims to hold (HFT contiguous cap).
    pub max_seq_len: usize,
    /// Cap on layer replicas the auto-scaler may hold per instance — the
    /// cost/benefit knob behind Fig. 10's "+9% memory over HFT×2" point
    /// (unbounded harvesting would converge to full model copies).
    pub replica_budget: usize,
}

impl SimConfig {
    pub fn paper_13b() -> SimConfig {
        SimConfig {
            model: ModelConfig::llama2_13b(),
            dtype_bytes: 2,
            slo_latency_s: 15.0,
            controller_tick_s: 1.0,
            gamma: None,
            oom_penalty_s: 12.0,
            max_seq_len: 512,
            replica_budget: 12,
        }
    }

    pub fn paper_70b() -> SimConfig {
        SimConfig { model: ModelConfig::llama2_70b(), ..SimConfig::paper_13b() }
    }
}

/// One simulated model instance.
struct Instance {
    id: usize,
    placement: Placement,
    scheduler: Scheduler,
    kv: Box<dyn KvCache>,
    policy: SimPolicy,
    /// Current max batch (phase-3 scale-down shrinks it).
    batch_size: usize,
    /// Wall time when the in-flight step completes (None = idle).
    busy_until: Option<f64>,
    /// Post-scaling replica-communication setup to charge to the next step.
    pending_setup_s: f64,
    /// Steps since the last OOM (drives batch-size recovery after backoff).
    clean_steps: u64,
    monitor: Monitor,
    /// Peak KV accounting observed (Fig. 9 reads peaks, not end-state).
    kv_peak: crate::kvcache::KvStats,
    /// Request metadata by id (arrival, prompt) for completion records.
    requests: std::collections::BTreeMap<u64, (f64, usize, usize)>,
    /// Per-request accumulated penalty (OOM reloads).
    penalties: std::collections::BTreeMap<u64, f64>,
    /// Unique requests ever caught in an OOM (Fig. 11a numerator).
    oom_victims: std::collections::BTreeSet<u64>,
}

/// Aggregated outcome of a simulation run.
#[derive(Debug)]
pub struct SimReport {
    pub duration_s: f64,
    pub monitors: Vec<Monitor>,
    /// (device, compute utilization, mem frac at end).
    pub device_util: Vec<(usize, f64, f64)>,
    pub total_oom_events: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Unique requests ever caught in an OOM failure.
    pub oom_victims: usize,
    /// Total transfer time consumed by scaling operations (background).
    pub scale_op_time_s: f64,
    /// Total bytes resident at peak (cost/memory comparisons, Fig. 10).
    pub peak_mem_bytes: f64,
    /// Peak KV accounting per instance over the run (Fig. 9).
    pub kv_stats: Vec<crate::kvcache::KvStats>,
    /// Per-instance final placements (inspection/tests).
    pub placements: Vec<Placement>,
    /// Per-instance final batch sizes.
    pub batch_sizes: Vec<usize>,
}

impl SimReport {
    pub fn merged_latency(&self) -> crate::util::stats::Summary {
        let mut s = crate::util::stats::Summary::new();
        for m in &self.monitors {
            for c in m.completions() {
                s.add(c.e2e_latency());
            }
        }
        s
    }

    pub fn total_throughput_tps(&self) -> f64 {
        self.monitors
            .iter()
            .map(|m| m.throughput_tokens_per_s(self.duration_s))
            .sum()
    }

    pub fn total_completed(&self) -> usize {
        self.monitors.iter().map(|m| m.completions().len()).sum()
    }

    pub fn slo_attainment(&self) -> f64 {
        let (ok, total) = self.monitors.iter().fold((0usize, 0usize), |(o, t), m| {
            let good = m
                .completions()
                .iter()
                .filter(|c| c.e2e_latency() <= m.slo_latency_s)
                .count();
            (o + good, t + m.completions().len())
        });
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    }

    /// Fraction of requests caught in an OOM failure (Fig. 11a).
    pub fn oom_rate(&self) -> f64 {
        let total = self.total_completed() as f64;
        if total == 0.0 {
            0.0
        } else {
            self.oom_victims as f64 / total
        }
    }
}

/// The simulator.
pub struct Simulation {
    pub cfg: SimConfig,
    pub cluster: Cluster,
    cost: CostModel,
    instances: Vec<Instance>,
    controller: Controller,
    now: f64,
    scale_ups: u64,
    scale_downs: u64,
    scale_op_time_s: f64,
    peak_mem: f64,
}

impl Simulation {
    /// Build a simulation: each entry of `placements` is one instance with
    /// its policy; instance weights are deployed onto the ledgers.
    pub fn new(
        cfg: SimConfig,
        cluster: Cluster,
        placements: Vec<(Placement, SimPolicy)>,
    ) -> Simulation {
        let cost = CostModel::new(cfg.model.clone());
        let mut cluster = cluster;
        let mut instances = Vec::new();
        for (i, (placement, policy)) in placements.into_iter().enumerate() {
            let ops = ModuleOps::new(&cost, cfg.dtype_bytes, &format!("inst{i}"));
            ops.deploy_instance(&mut cluster, &placement)
                .expect("instance deployment OOM");
            let bytes_per_token = cost.kv_cache_bytes(1, 1, cfg.dtype_bytes)
                * cfg.model.n_layers as f64;
            let kv: Box<dyn KvCache> = if policy.paged_kv {
                Box::new(PagedKvCache::new(f64::INFINITY, bytes_per_token, 16))
            } else {
                Box::new(ContiguousKvCache::new(
                    f64::INFINITY,
                    bytes_per_token,
                    cfg.max_seq_len,
                ))
            };
            instances.push(Instance {
                id: i,
                placement,
                scheduler: Scheduler::new(policy.scheduler),
                kv,
                policy,
                batch_size: policy.scheduler.max_batch,
                busy_until: None,
                pending_setup_s: 0.0,
                clean_steps: 0,
                monitor: Monitor::new(cfg.slo_latency_s),
                kv_peak: Default::default(),
                requests: Default::default(),
                penalties: Default::default(),
                oom_victims: Default::default(),
            });
        }
        Simulation {
            cfg,
            cluster,
            cost,
            instances,
            controller: Controller::new(ControllerConfig::default()),
            now: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            scale_op_time_s: 0.0,
            peak_mem: 0.0,
        }
    }

    fn gamma(&self) -> f64 {
        self.cfg.gamma.unwrap_or_else(|| {
            let spec = &self.cluster.device(0).spec;
            crate::autoscale::speedup::gamma(
                0.3,
                spec.effective_flops(),
                self.cfg.model.d_model as f64,
                spec.link_bw,
            )
        })
    }

    /// Route a request to the least-loaded instance (§5 Scheduler).
    fn route(&mut self, req: crate::workload::Request) {
        let inst = self
            .instances
            .iter_mut()
            .min_by_key(|i| i.scheduler.load())
            .expect("no instances");
        inst.requests
            .insert(req.id, (req.arrival_s, req.prompt_tokens, req.output_tokens));
        inst.scheduler.submit(req);
    }

    // ---- step latency (the roofline substitute for real execution) -------

    /// Per-layer prefill time across replicas: batch split (Fig. 4), max
    /// over replicas, plus scatter/gather per dataflow transition.
    fn prefill_step_time(&self, inst: &Instance, batch: usize, seq: usize) -> f64 {
        let d = self.cfg.model.d_model as f64;
        let dt = self.cfg.dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..inst.placement.n_layers {
            let devs = inst.placement.layer_devices(l);
            let shares = split_batch(batch, devs.len());
            let mut worst: f64 = 0.0;
            for (dev, share) in devs.iter().zip(&shares) {
                if *share == 0 {
                    continue;
                }
                let sh = Shape { batch: *share, seq, dtype_bytes: self.cfg.dtype_bytes };
                let flops = self.cost.flops(ModuleKind::DecoderLayer, sh);
                let spec = &self.cluster.device(*dev).spec;
                worst = worst.max(flops / spec.effective_flops());
            }
            t += worst;
        }
        // communication at non-consecutive boundaries (§3.2)
        let transitions = inst.placement.transition_count() as f64;
        let bytes = batch as f64 * seq as f64 * d * dt;
        let bw = self.cluster.device(0).spec.link_bw;
        t += transitions * (bytes / bw + 20e-6);
        // embed + lm head (primary device)
        let sh = Shape { batch, seq, dtype_bytes: self.cfg.dtype_bytes };
        let spec = &self.cluster.device(inst.placement.primary_device(0)).spec;
        t += self.cost.flops(ModuleKind::LmHead, sh) / spec.effective_flops();
        t
    }

    /// Decode-iteration time: roofline max(compute, HBM bytes) per layer.
    fn decode_step_time(&self, inst: &Instance, batch: usize, mean_ctx: usize) -> f64 {
        let d = self.cfg.model.d_model as f64;
        let dt = self.cfg.dtype_bytes as f64;
        let mut t = 0.0;
        for l in 0..inst.placement.n_layers {
            let devs = inst.placement.layer_devices(l);
            let shares = split_batch(batch, devs.len());
            let mut worst: f64 = 0.0;
            for (dev, share) in devs.iter().zip(&shares) {
                if *share == 0 {
                    continue;
                }
                let spec = &self.cluster.device(*dev).spec;
                let flops =
                    self.cost.decode_flops(ModuleKind::DecoderLayer, *share, mean_ctx);
                let bytes = self
                    .cost
                    .decode_bytes_read(*share, mean_ctx, self.cfg.dtype_bytes);
                worst = worst
                    .max(flops / spec.effective_flops())
                    .max(bytes / spec.hbm_bw);
            }
            t += worst;
        }
        let transitions = inst.placement.transition_count() as f64;
        let bw = self.cluster.device(0).spec.link_bw;
        t += transitions * ((batch as f64 * d * dt) / bw + 20e-6);
        let spec = &self.cluster.device(inst.placement.primary_device(0)).spec;
        t += self.cost.decode_flops(ModuleKind::LmHead, batch, mean_ctx)
            / spec.effective_flops();
        t
    }

    /// Device contention factor: overlap-weighted slowdown from other
    /// instances' in-flight steps. An instance whose device set overlaps
    /// ours by a fraction f contributes +f (full co-location doubles step
    /// time; a single shared device out of four adds 25%). This yields the
    /// §8 behaviour: spread replicas barely perturb neighbours.
    fn contention(&self, inst_id: usize, devices: &[usize]) -> f64 {
        let mine: std::collections::BTreeSet<usize> = devices.iter().copied().collect();
        let mut factor = 1.0;
        for other in &self.instances {
            if other.id == inst_id || other.busy_until.is_none() {
                continue;
            }
            let theirs: std::collections::BTreeSet<usize> = (0..other.placement.n_layers)
                .flat_map(|l| other.placement.layer_devices(l))
                .collect();
            let shared = mine.intersection(&theirs).count();
            if shared > 0 {
                factor += shared as f64 / mine.len().max(1) as f64;
            }
        }
        factor
    }

    fn charge_busy(&mut self, inst_idx: usize, seconds: f64) {
        let devices: std::collections::BTreeSet<usize> = {
            let p = &self.instances[inst_idx].placement;
            (0..p.n_layers).flat_map(|l| p.layer_devices(l)).collect()
        };
        let n = devices.len().max(1) as f64;
        for d in devices {
            self.cluster.device_mut(d).add_busy(seconds / n);
        }
    }

    // ---- KV accounting -----------------------------------------------------

    /// Mirror the instance's KV reservation into device ledgers. On OOM,
    /// apply the policy's behaviour; returns ids of preempted requests.
    fn sync_kv(&mut self, inst_idx: usize) -> Result<(), ()> {
        // distribute reserved bytes across the devices hosting KV modules
        let (reserved, kv_devices) = {
            let inst = &mut self.instances[inst_idx];
            let stats = inst.kv.stats();
            if stats.reserved_bytes > inst.kv_peak.reserved_bytes {
                inst.kv_peak = stats;
            }
            let reserved = stats.reserved_bytes;
            let devs: Vec<usize> = (0..inst.placement.n_layers)
                .map(|l| {
                    inst.placement
                        .module_device(ModuleId::layer(ModuleKind::KvCache, l))
                })
                .collect();
            (reserved, devs)
        };
        let per_layer = reserved / kv_devices.len() as f64;
        let mut per_device: std::collections::BTreeMap<usize, f64> = Default::default();
        for d in kv_devices {
            *per_device.entry(d).or_insert(0.0) += per_layer;
        }
        let tag = format!("inst{}/kv", self.instances[inst_idx].id);
        for (d, bytes) in per_device {
            if self.cluster.device_mut(d).resize(&tag, bytes).is_err() {
                self.instances[inst_idx].monitor.record_oom();
                return Err(());
            }
        }
        self.peak_mem = self.peak_mem.max(self.cluster.total_used_bytes());
        Ok(())
    }

    fn handle_oom(&mut self, inst_idx: usize) {
        match self.instances[inst_idx].policy.oom {
            OomBehavior::FailBatch => {
                // Drop the running batch's KV; requests retry after the
                // model-reload penalty (§2.3: 8–25 s).
                let ids: Vec<u64> = self.instances[inst_idx]
                    .scheduler
                    .running_view()
                    .iter()
                    .map(|(id, _, _)| *id)
                    .collect();
                let penalty = self.cfg.oom_penalty_s;
                let inst = &mut self.instances[inst_idx];
                for id in &ids {
                    inst.kv.remove_sequence(*id);
                    *inst.penalties.entry(*id).or_insert(0.0) += penalty;
                    // requeue as fresh arrival (retry)
                    if let Some(&(arr, p, o)) = inst.requests.get(id) {
                        let _ = arr;
                        inst.scheduler.submit(crate::workload::Request {
                            id: *id,
                            arrival_s: self.now,
                            prompt_tokens: p,
                            output_tokens: o,
                        });
                    }
                }
                // clear the running set by reporting them "finished"… the
                // scheduler has no cancel API; emulate by decoding them to
                // completion is wrong — instead rebuild the scheduler.
                let cfg = inst.scheduler.cfg;
                let mut fresh = Scheduler::new(cfg);
                // keep pending order: resubmitted + previously pending are
                // already in inst.scheduler.pending — copy via running_view
                // is lossy; simplest correct path: move *all* tracked ids
                // into the fresh scheduler.
                for id in inst.pending_ids() {
                    if let Some(&(_, p, o)) = inst.requests.get(&id) {
                        fresh.submit(crate::workload::Request {
                            id,
                            arrival_s: self.now,
                            prompt_tokens: p,
                            output_tokens: o,
                        });
                    }
                }
                inst.scheduler = fresh;
                inst.busy_until = None;
                // After a reload, the static engine restarts with a halved
                // batch (§2.3: "adjusting batch sizes can temporarily
                // mitigate these issues" — at a throughput cost). Every
                // request in the failed batch counts toward the Fig. 11a
                // OOM occurrence rate.
                for id in &ids {
                    inst.oom_victims.insert(*id);
                }
                inst.batch_size = (inst.batch_size / 2).max(1);
                inst.clean_steps = 0;
                let _ = self.sync_kv(inst_idx);
            }
            OomBehavior::Preempt => {
                // Drop the newest running sequence's cache and requeue it.
                // If it is the only running sequence, re-queuing would spin
                // (nothing can ever fit) — fail it instead, with the reload
                // penalty, so the system keeps making progress.
                let view = self.instances[inst_idx].scheduler.running_view();
                let victim = view.last().map(|(id, _, _)| *id);
                let only_one = view.len() <= 1;
                if let Some(id) = victim {
                    let inst = &mut self.instances[inst_idx];
                    inst.oom_victims.insert(id);
                    inst.kv.remove_sequence(id);
                    inst.scheduler.preempt(id);
                    if let Some(&(_, p, o)) = inst.requests.get(&id) {
                        if only_one {
                            *inst.penalties.entry(id).or_insert(0.0) +=
                                self.cfg.oom_penalty_s;
                        }
                        inst.scheduler.submit(crate::workload::Request {
                            id,
                            arrival_s: self.now,
                            prompt_tokens: p,
                            output_tokens: if only_one { 1 } else { o },
                        });
                    }
                }
                let _ = self.sync_kv(inst_idx);
            }
            OomBehavior::ScaleDown => {
                self.run_scale_down(inst_idx, Pressure::Memory);
                let _ = self.sync_kv(inst_idx);
            }
        }
    }

    // ---- auto-scaling ------------------------------------------------------

    fn run_scale_up(&mut self, inst_idx: usize) {
        let gamma = self.gamma();
        let inst = &mut self.instances[inst_idx];
        let held: usize = (0..inst.placement.n_layers)
            .map(|l| inst.placement.degree(l) - 1)
            .sum();
        let remaining = self.cfg.replica_budget.saturating_sub(held);
        if remaining == 0 {
            return;
        }
        let ops = ModuleOps::new(&self.cost, self.cfg.dtype_bytes, &format!("inst{}", inst.id));
        let cfg = ScaleUpConfig { gamma, min_vacancy: 0.45, max_ops_per_round: remaining };
        let out = scale_up(&ops, &mut self.cluster, &mut inst.placement, &cfg);
        if !out.replicated.is_empty() {
            self.scale_ups += 1;
            // Replication copies weights *concurrently* with serving (§8:
            // <3% throughput fluctuation on neighbours); the serving path
            // pays only a short synchronization pause plus the §6.5
            // 39.1 ms replica communication setup. The full op transfer
            // time is tracked separately for cost reporting (Table 2).
            inst.pending_setup_s += SYNC_PAUSE_S + REPLICA_COMM_SETUP_S;
            self.scale_op_time_s += out.cost.time_s;
        }
    }

    fn run_scale_down(&mut self, inst_idx: usize, pressure: Pressure) {
        let hot = {
            let inst = &self.instances[inst_idx];
            // the most loaded device hosting this instance
            (0..inst.placement.n_layers)
                .map(|l| inst.placement.primary_device(l))
                .max_by(|&a, &b| {
                    self.cluster
                        .device(a)
                        .mem_frac()
                        .partial_cmp(&self.cluster.device(b).mem_frac())
                        .unwrap()
                })
                .unwrap_or(0)
        };
        let kv_per_layer = {
            let inst = &self.instances[inst_idx];
            inst.kv.stats().reserved_bytes / inst.placement.n_layers as f64
        };
        let batch = self.instances[inst_idx].batch_size;
        let inst = &mut self.instances[inst_idx];
        let ops = ModuleOps::new(&self.cost, self.cfg.dtype_bytes, &format!("inst{}", inst.id));
        let slo = self.cfg.slo_latency_s;
        let out = scale_down(
            &ops,
            &mut self.cluster,
            &mut inst.placement,
            hot,
            pressure,
            batch,
            &ScaleDownConfig::default(),
            |_l| kv_per_layer,
            |cl, _pl, _bs| cl.device(hot).mem_frac() > 0.92 && slo > 0.0,
        );
        if !out.actions.is_empty() {
            self.scale_downs += 1;
            // Migration is a corrective op on the critical path: the hot
            // device pauses for the transfer (Table 2: 0.25–0.8 s).
            inst.pending_setup_s += out.cost.time_s.min(1.0);
            inst.batch_size = out.batch_size;
            self.scale_op_time_s += out.cost.time_s;
        }
    }

    fn controller_tick(&mut self) {
        for i in 0..self.instances.len() {
            if !self.instances[i].policy.autoscale {
                continue;
            }
            let view = {
                let cluster = &self.cluster;
                self.instances[i].monitor.controller_view(cluster, self.now.max(1e-9))
            };
            match self.controller.tick(&view) {
                Decision::ScaleUp => self.run_scale_up(i),
                Decision::ScaleDown { pressure, .. } => self.run_scale_down(i, pressure),
                Decision::None => {}
            }
        }
    }

    // ---- the event loop -----------------------------------------------------

    /// Run the trace to completion (plus drain); returns the report.
    pub fn run(mut self, trace: &Trace, duration_s: f64) -> SimReport {
        let mut next_req = 0usize;
        let mut next_tick = self.cfg.controller_tick_s;
        let drain_deadline = duration_s + 300.0;

        loop {
            // next event time: arrival, step completion, controller tick
            let t_arr = trace
                .requests
                .get(next_req)
                .map(|r| r.arrival_s)
                .unwrap_or(f64::INFINITY);
            let t_step = self
                .instances
                .iter()
                .filter_map(|i| i.busy_until)
                .fold(f64::INFINITY, f64::min);
            let t_tick = next_tick;
            let t_next = t_arr.min(t_step).min(t_tick);

            let all_idle =
                self.instances.iter().all(|i| i.scheduler.is_idle() && i.busy_until.is_none());
            if (next_req >= trace.requests.len() && all_idle)
                || t_next > drain_deadline
                || t_next == f64::INFINITY && all_idle
            {
                break;
            }

            self.now = t_next;

            if t_next == t_arr {
                let req = trace.requests[next_req].clone();
                next_req += 1;
                self.route(req);
            } else if t_next == t_tick {
                next_tick += self.cfg.controller_tick_s;
                self.controller_tick();
            } else {
                // some instance finished its step
                for i in 0..self.instances.len() {
                    if self.instances[i].busy_until == Some(t_next) {
                        self.instances[i].busy_until = None;
                        self.finish_completions(i);
                    }
                }
            }

            // start steps on idle instances
            for i in 0..self.instances.len() {
                if self.instances[i].busy_until.is_none() {
                    self.start_step(i);
                }
            }
        }

        let wall = self.now.max(1e-9);
        SimReport {
            duration_s: wall,
            device_util: (0..self.cluster.n())
                .map(|d| {
                    (
                        d,
                        self.cluster.device(d).utilization(wall),
                        self.cluster.device(d).mem_frac(),
                    )
                })
                .collect(),
            total_oom_events: self.cluster.total_oom_events()
                + self.instances.iter().map(|i| i.monitor.total_oom()).sum::<u64>(),
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            oom_victims: self
                .instances
                .iter()
                .map(|i| i.oom_victims.len())
                .sum(),
            scale_op_time_s: self.scale_op_time_s,
            peak_mem_bytes: self.peak_mem,
            kv_stats: self.instances.iter().map(|i| i.kv_peak).collect(),
            placements: self.instances.iter().map(|i| i.placement.clone()).collect(),
            batch_sizes: self.instances.iter().map(|i| i.batch_size).collect(),
            monitors: self.instances.into_iter().map(|i| i.monitor).collect(),
        }
    }

    fn start_step(&mut self, i: usize) {
        // Batch capacity = (possibly scaled-down) base batch × the mean
        // layer degree: replica sets add data-parallel lanes (Fig. 4 —
        // the localized data parallelism replication buys). Partial
        // replication yields partial capacity: unreplicated layers are
        // weights-bandwidth-bound in decode, so they absorb the larger
        // batch at near-constant step time, while replicated segments
        // split it (§3.2's "partial data-parallel effects").
        let step = {
            let inst = &mut self.instances[i];
            // Recovery: a reloaded static engine creeps back toward its
            // configured batch (operators restart with the original
            // config; the OOM cycle then recurs under sustained load —
            // the Fig. 11a occurrence-rate mechanism).
            inst.clean_steps += 1;
            if inst.clean_steps % 40 == 0
                && inst.batch_size < inst.policy.scheduler.max_batch
            {
                inst.batch_size = (inst.batch_size * 2)
                    .min(inst.policy.scheduler.max_batch);
            }
            let mean_degree = (0..inst.placement.n_layers)
                .map(|l| inst.placement.degree(l) as f64)
                .sum::<f64>()
                / inst.placement.n_layers.max(1) as f64;
            let cap = ((inst.batch_size as f64) * mean_degree) as usize;
            let mut cfg = inst.scheduler.cfg;
            cfg.max_batch = cap;
            inst.scheduler.cfg = cfg;
            inst.scheduler.next_step(self.now)
        };
        match step {
            Step::Idle => {}
            Step::Prefill { request_ids } => {
                // admit KV for the new sequences
                let mut ok = true;
                {
                    let inst = &mut self.instances[i];
                    for id in &request_ids {
                        // idempotent: a previous partially-OOMed prefill may
                        // have admitted this sequence's cache already
                        if inst.kv.tokens_of(*id).is_some() {
                            continue;
                        }
                        let prompt = inst.requests.get(id).map(|r| r.1).unwrap_or(8);
                        if inst.kv.add_sequence(*id, prompt).is_err() {
                            ok = false;
                        }
                    }
                }
                if ok {
                    ok = self.sync_kv(i).is_ok();
                }
                if !ok {
                    self.handle_oom(i);
                    return;
                }
                let (batch, max_seq) = {
                    let inst = &self.instances[i];
                    let seq = request_ids
                        .iter()
                        .filter_map(|id| inst.requests.get(id).map(|r| r.1))
                        .max()
                        .unwrap_or(8);
                    (request_ids.len(), seq)
                };
                let devices: Vec<usize> = {
                    let p = &self.instances[i].placement;
                    (0..p.n_layers).map(|l| p.primary_device(l)).collect()
                };
                let mut dt = self.prefill_step_time(&self.instances[i], batch, max_seq);
                dt *= self.contention(i, &devices);
                dt += std::mem::take(&mut self.instances[i].pending_setup_s);
                self.charge_busy(i, dt); // prefill is compute-bound: full busy
                self.instances[i].busy_until = Some(self.now + dt);
                self.instances[i].scheduler.on_prefilled(&request_ids);
            }
            Step::Decode { request_ids } => {
                // grow KV by one token per sequence
                let mut ok = true;
                {
                    let inst = &mut self.instances[i];
                    for id in &request_ids {
                        if inst.kv.tokens_of(*id).is_some()
                            && inst.kv.append_token(*id).is_err()
                        {
                            ok = false;
                        }
                    }
                }
                if ok {
                    ok = self.sync_kv(i).is_ok();
                }
                if !ok {
                    self.handle_oom(i);
                    return;
                }
                let (batch, mean_ctx) = {
                    let inst = &self.instances[i];
                    let ctxs: Vec<usize> = request_ids
                        .iter()
                        .filter_map(|id| inst.kv.tokens_of(*id))
                        .collect();
                    let mean =
                        ctxs.iter().sum::<usize>() / ctxs.len().max(1).max(1);
                    (request_ids.len(), mean.max(1))
                };
                let devices: Vec<usize> = {
                    let p = &self.instances[i].placement;
                    (0..p.n_layers).map(|l| p.primary_device(l)).collect()
                };
                let mut dt = self.decode_step_time(&self.instances[i], batch, mean_ctx);
                dt *= self.contention(i, &devices);
                dt += std::mem::take(&mut self.instances[i].pending_setup_s);
                // Decode is HBM-bandwidth-bound: the SMs are only partially
                // occupied during the step (what NVML-style compute
                // utilization reports — the Fig. 2 signal).
                self.charge_busy(i, dt * DECODE_BUSY_FRACTION);
                self.instances[i].busy_until = Some(self.now + dt);
                self.instances[i].scheduler.on_decoded(&request_ids);
            }
        }
    }

    /// Record completions for sequences the scheduler reaped.
    fn finish_completions(&mut self, i: usize) {
        let inst = &mut self.instances[i];
        let tracked: std::collections::BTreeSet<u64> = inst
            .scheduler
            .running_view()
            .iter()
            .map(|(id, _, _)| *id)
            .chain(inst.pending_ids())
            .collect();
        let now = self.now;
        let finished: Vec<u64> = inst
            .requests
            .keys()
            .copied()
            .filter(|id| !tracked.contains(id) && inst.kv.tokens_of(*id).is_some())
            .collect();
        for id in finished {
            inst.kv.remove_sequence(id);
            let (arrival, prompt, output) = inst.requests[&id];
            let penalty = inst.penalties.get(&id).copied().unwrap_or(0.0);
            inst.monitor.record(Completion {
                request_id: id,
                arrival_s: arrival,
                finish_s: now + penalty,
                prompt_tokens: prompt,
                output_tokens: output,
            });
        }
        let _ = self.sync_kv(i);
    }
}

impl Instance {
    fn pending_ids(&self) -> Vec<u64> {
        // ids known to the instance that are neither running nor completed
        // (used by OOM rebuild + completion detection)
        self.scheduler.pending_ids()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::workload::{Arrival, LengthDist, Trace};

    fn run_single(policy: SimPolicy, rps: f64, dur: f64) -> SimReport {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
        let trace = Trace::generate(
            Arrival::Poisson { rps },
            LengthDist::alpaca(),
            dur,
            42,
        );
        sim.run(&trace, dur)
    }

    #[test]
    fn low_load_completes_everything() {
        let r = run_single(baselines::vllm_like(16), 3.0, 20.0);
        assert!(r.total_completed() >= 40, "completed {}", r.total_completed());
        assert!(r.merged_latency().mean() < 20.0);
    }

    #[test]
    fn hft_static_batching_slower_than_continuous() {
        let h = run_single(baselines::hft(16), 8.0, 30.0);
        let v = run_single(baselines::vllm_like(16), 8.0, 30.0);
        let hl = h.merged_latency().mean();
        let vl = v.merged_latency().mean();
        assert!(vl < hl, "vllm {vl} !< hft {hl}");
    }

    #[test]
    fn cocoserve_autoscaler_replicates_under_load() {
        let r = run_single(baselines::cocoserve(16), 20.0, 30.0);
        assert!(r.scale_ups > 0, "no scale-ups happened");
        // some layer gained a replica
        let maxdeg = (0..r.placements[0].n_layers)
            .map(|l| r.placements[0].degree(l))
            .max()
            .unwrap();
        assert!(maxdeg > 1);
    }

    #[test]
    fn cocoserve_outperforms_vllm_under_load() {
        let c = run_single(baselines::cocoserve(16), 20.0, 30.0);
        let v = run_single(baselines::vllm_like(16), 20.0, 30.0);
        let cl = c.merged_latency().mean();
        let vl = v.merged_latency().mean();
        assert!(cl < vl, "coco {cl} !< vllm {vl}");
        assert!(c.total_throughput_tps() >= v.total_throughput_tps() * 0.95);
    }

    #[test]
    fn throughput_increases_with_rps_until_saturation() {
        let lo = run_single(baselines::vllm_like(16), 3.0, 20.0);
        let hi = run_single(baselines::vllm_like(16), 12.0, 20.0);
        assert!(hi.total_throughput_tps() > lo.total_throughput_tps());
    }

    #[test]
    fn device_utilization_reported() {
        let r = run_single(baselines::vllm_like(16), 10.0, 20.0);
        let (_, util0, mem0) = r.device_util[0];
        assert!(util0 > 0.0 && util0 <= 1.0);
        assert!(mem0 > 0.0, "model weights resident");
    }

    #[test]
    fn multi_instance_routes_by_load() {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::paper_testbed();
        let p0 = Placement::single_device(cfg.model.n_layers, 0);
        let p1 = Placement::single_device(cfg.model.n_layers, 1);
        let sim = Simulation::new(
            cfg,
            cluster,
            vec![
                (p0, baselines::vllm_like(16)),
                (p1, baselines::vllm_like(16)),
            ],
        );
        let trace = Trace::generate(
            Arrival::Poisson { rps: 10.0 },
            LengthDist::alpaca(),
            20.0,
            7,
        );
        let r = sim.run(&trace, 20.0);
        let c0 = r.monitors[0].completions().len();
        let c1 = r.monitors[1].completions().len();
        assert!(c0 > 0 && c1 > 0, "both instances serve: {c0}/{c1}");
        let ratio = c0 as f64 / c1 as f64;
        assert!((0.5..2.0).contains(&ratio), "balanced routing: {ratio}");
    }

    #[test]
    fn migration_relieves_memory_cliff() {
        // Fig. 3 mechanism: a layer migrated off the hot device frees
        // memory for KV, avoiding HFT-style OOM churn.
        let cfg = SimConfig::paper_13b();
        let mut cluster = Cluster::paper_testbed();
        // squeeze device 0 so KV pressure appears quickly
        cluster
            .device_mut(0)
            .alloc("other-tenant", 12.0 * crate::cluster::GIB)
            .unwrap();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let sim = Simulation::new(
            cfg,
            cluster,
            vec![(placement, baselines::cocoserve(24))],
        );
        let trace = Trace::generate(
            Arrival::Poisson { rps: 30.0 },
            LengthDist::alpaca(),
            20.0,
            11,
        );
        let r = sim.run(&trace, 20.0);
        // the autoscaler acted and the run stayed mostly OOM-free
        assert!(r.scale_ups + r.scale_downs > 0);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::baselines;
    use crate::workload::{Arrival, LengthDist, Trace};

    #[test]
    #[ignore]
    fn debug_report() {
        for (name, pol) in [
            ("vllm", baselines::vllm_like(16)),
            ("coco", baselines::cocoserve(16)),
        ] {
            let cfg = SimConfig::paper_13b();
            let cluster = Cluster::paper_testbed();
            let placement = Placement::single_device(cfg.model.n_layers, 0);
            let sim = Simulation::new(cfg, cluster, vec![(placement, pol)]);
            let trace = Trace::generate(Arrival::Poisson { rps: 20.0 }, LengthDist::alpaca(), 30.0, 42);
            let n_req = trace.len();
            let r = sim.run(&trace, 30.0);
            let mut lat = r.merged_latency();
            eprintln!("{name}: req={n_req} done={} mean={:.2} p95={:.2} dur={:.1} tps={:.0} ups={} downs={} oom={} batch={:?} trans={} degmax={}",
                r.total_completed(), lat.mean(), lat.p95(), r.duration_s,
                r.total_throughput_tps(), r.scale_ups, r.scale_downs, r.total_oom_events,
                r.batch_sizes, r.placements[0].transition_count(),
                (0..r.placements[0].n_layers).map(|l| r.placements[0].degree(l)).max().unwrap());
        }
    }
}

#[cfg(test)]
mod debug_steps {
    use super::*;
    use crate::baselines;

    #[test]
    #[ignore]
    fn step_times() {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let mut sim = Simulation::new(cfg, cluster, vec![(placement, baselines::cocoserve(16))]);
        let pre1 = sim.prefill_step_time(&sim.instances[0], 16, 256);
        let dec1 = sim.decode_step_time(&sim.instances[0], 16, 256);
        // replicate everything
        for _ in 0..20 { sim.run_scale_up(0); }
        let inst = &sim.instances[0];
        let degs: Vec<usize> = (0..40).map(|l| inst.placement.degree(l)).collect();
        let pre4 = sim.prefill_step_time(inst, 16, 256);
        let dec4 = sim.decode_step_time(inst, 16, 256);
        eprintln!("deg={:?}", &degs[..10]);
        eprintln!("prefill 16x256: before={pre1:.4}s after={pre4:.4}s");
        eprintln!("decode  16@256: before={dec1:.4}s after={dec4:.4}s");
        eprintln!("setup pending: {:.3}s", sim.instances[0].pending_setup_s);
        eprintln!("transitions: {}", sim.instances[0].placement.transition_count());
    }
}
