//! PJRT runtime — loads AOT artifacts and executes modules from Rust.
//!
//! The request-path half of the AOT bridge (DESIGN.md): `python/compile/
//! aot.py` lowered every module × shape bucket to HLO *text*;
//! [`Manifest`] indexes them, [`PjrtEngine`] compiles each on the CPU PJRT
//! client (once, cached) and executes them with weight literals owned by
//! the [`WeightStore`]. Python never runs here.
//!
//! Interchange is HLO text, not serialized proto: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;
pub mod weights;

pub use manifest::{ArtifactEntry, Manifest};
pub use weights::WeightStore;

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

/// Compiles + executes manifest artifacts on a PJRT CPU client.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    root: std::path::PathBuf,
    /// name -> compiled executable (compiled on first use).
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions performed (perf accounting).
    exec_count: RefCell<u64>,
}

impl PjrtEngine {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(artifacts_dir: &std::path::Path) -> Result<PjrtEngine> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            root: artifacts_dir.to_path_buf(),
            compiled: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Total artifact executions so far (perf accounting).
    pub fn executions(&self) -> u64 {
        *self.exec_count.borrow()
    }

    /// Number of artifacts compiled so far (they compile on first use).
    pub fn compiled_count(&self) -> usize {
        self.compiled.borrow().len()
    }

    /// Ensure the named artifact is compiled; returns whether it was cached.
    pub fn ensure_compiled(&self, name: &str) -> Result<bool> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(true);
        }
        let entry = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = self.root.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(false)
    }

    /// Execute an artifact with the given literal arguments; returns the
    /// tuple elements (all artifacts are lowered `return_tuple=True`).
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let compiled = self.compiled.borrow();
        let exe = compiled.get(name).unwrap();
        *self.exec_count.borrow_mut() += 1;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let out = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if out.is_empty() {
            return Err(anyhow!("artifact {name} returned an empty tuple"));
        }
        Ok(out)
    }

    /// f32 literal from a slice with a shape.
    pub fn lit_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "shape {dims:?} vs len {}", data.len());
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims_i64)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// i32 literal from a slice with a shape.
    pub fn lit_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "shape {dims:?} vs len {}", data.len());
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims_i64)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

/// Locate the repo's artifacts directory (tests/examples convenience).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if `make artifacts` has been run.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
