//! In-tree utilities.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the usual ecosystem crates (rand, serde/serde_json,
//! criterion, proptest) are replaced by small, tested, std-only modules:
//!
//! * [`rng`] — SplitMix64/xoshiro256** PRNG + Poisson/normal/lognormal draws
//! * [`json`] — minimal JSON parser/writer (manifest + config + reports)
//! * [`stats`] — streaming summaries, percentiles, fixed-bucket histograms
//! * [`prop`] — property-test harness (randomized cases w/ seed reporting)
//! * [`bench`] — timing harness used by `benches/` (criterion replacement)

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
