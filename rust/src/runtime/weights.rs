//! Weight store: the module weights the coordinator owns and moves.
//!
//! Weights are runtime *arguments* to the HLO artifacts (see
//! `python/compile/model.py`) — this is what makes module replication/
//! migration cheap: moving a module between (simulated) devices moves
//! entries in this store, never recompiles an executable.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;

/// Layer-weight argument order shared with `model.py::LAYER_WEIGHT_NAMES`.
pub const LAYER_WEIGHT_NAMES: [&str; 9] = [
    "rms1", "wq", "wk", "wv", "wo", "rms2", "w_gate", "w_up", "w_down",
];

/// One tensor (host-resident f32, row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// Row-major element data.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Resident bytes (f32).
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// All weights of one model config, keyed like the manifest index
/// (`layer{i}.{name}`, `emb`, `w_out`, `rms_f`).
#[derive(Debug)]
pub struct WeightStore {
    /// Model config these weights belong to.
    pub config: String,
    tensors: BTreeMap<String, Tensor>,
    n_layers: usize,
}

impl WeightStore {
    /// Load every tensor of `config` from the artifacts directory.
    pub fn load(root: &Path, manifest: &Manifest, config: &str) -> Result<WeightStore> {
        let index = manifest
            .weights
            .get(config)
            .ok_or_else(|| anyhow!("no weights for config `{config}`"))?;
        let mut tensors = BTreeMap::new();
        for (name, entry) in index {
            let raw = std::fs::read(root.join(&entry.path))
                .with_context(|| format!("weight {name}"))?;
            anyhow::ensure!(raw.len() % 4 == 0, "weight {name} not f32");
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let numel: usize = entry.shape.iter().product();
            anyhow::ensure!(
                numel == data.len(),
                "weight {name}: shape {:?} vs {} elements",
                entry.shape,
                data.len()
            );
            tensors.insert(
                name.clone(),
                Tensor { shape: entry.shape.clone(), data },
            );
        }
        let n_layers = manifest
            .configs
            .get(config)
            .map(|c| c.n_layers)
            .unwrap_or(0);
        Ok(WeightStore { config: config.to_string(), tensors, n_layers })
    }

    /// Layer count of the loaded config.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Look up a tensor by its manifest key.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing weight `{name}`"))
    }

    /// The 9 layer-weight tensors of `layer`, in artifact argument order.
    pub fn layer_weights(&self, layer: usize) -> Result<Vec<&Tensor>> {
        LAYER_WEIGHT_NAMES
            .iter()
            .map(|n| self.get(&format!("layer{layer}.{n}")))
            .collect()
    }

    /// Subset of layer weights by name (attention-only, FFN-only artifacts).
    pub fn layer_weights_named(&self, layer: usize, names: &[&str]) -> Result<Vec<&Tensor>> {
        names
            .iter()
            .map(|n| self.get(&format!("layer{layer}.{n}")))
            .collect()
    }

    /// Total resident bytes (coordinator memory accounting).
    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    fn store() -> Option<WeightStore> {
        let root = default_artifacts_dir();
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root.join("manifest.json")).unwrap();
        Some(WeightStore::load(&root, &m, "tiny-llama").unwrap())
    }

    #[test]
    fn loads_all_layer_weights() {
        let Some(s) = store() else { return };
        assert_eq!(s.n_layers(), 4);
        for l in 0..4 {
            let ws = s.layer_weights(l).unwrap();
            assert_eq!(ws.len(), 9);
            assert_eq!(ws[1].shape, vec![64, 64]); // wq
            assert_eq!(ws[6].shape, vec![64, 172]); // w_gate
        }
    }

    #[test]
    fn embedding_shape_matches_config() {
        let Some(s) = store() else { return };
        let emb = s.get("emb").unwrap();
        assert_eq!(emb.shape, vec![512, 64]);
        assert_eq!(emb.numel(), 512 * 64);
        // weights are non-trivial (not all zeros)
        assert!(emb.data.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn missing_weight_is_an_error() {
        let Some(s) = store() else { return };
        assert!(s.get("layer99.wq").is_err());
        assert!(s.layer_weights(99).is_err());
    }

    #[test]
    fn total_bytes_plausible() {
        let Some(s) = store() else { return };
        // tiny model: ~0.5–2 MB of f32 weights
        let mb = s.total_bytes() as f64 / 1e6;
        assert!((0.2..10.0).contains(&mb), "{mb} MB");
    }
}
