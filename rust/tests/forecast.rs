//! Predictive control-plane contracts, tested through the public
//! simulation API.
//!
//! * **Strict additivity** — with no predictor configured the kernel
//!   schedules no forecast machinery and the metrics JSON carries no
//!   `forecast` key (sim_kernel/fleet golden-replay byte-identity is the
//!   other half of this contract).
//! * **Predictive golden replay** — the full predictive configuration
//!   (estimators, proposals, vetoes, drain gating, oracle mode) is
//!   byte-identically replayable per scenario.
//! * **Proactivity** — under a flash burst the predictive fleet takes
//!   its first capacity action no later than the reactive fleet, and the
//!   forecaster demonstrably observed the traffic it acted on.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, FleetPhase, RoutePolicy, RouterConfig};
use cocoserve::forecast::{
    BurstDetector, Ewma, Holt, HoltWinters, PredictConfig, TrafficForecaster,
};
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimReport, Simulation};
use cocoserve::util::json::Json;
use cocoserve::workload::{SloClass, Trace};

fn fleet_setup(predictor: Option<PredictConfig>) -> FleetSetup {
    let policy = baselines::cocoserve(32);
    let mut fleet = FleetConfig::elastic(2, 5, policy);
    // deliberately slow reactive trigger: the proactivity contract below
    // compares against it, and the Hold band is where predictive acts
    fleet.scale_out_queue = 28.0;
    fleet.cooldown_ticks = 2;
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::KvHeadroom,
            admission_limit: None,
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(fleet),
        controller: cocoserve::autoscale::ControllerConfig { t_up: 2.0, ..Default::default() },
        predictor,
    }
}

fn run(predictor: Option<PredictConfig>, trace: &Trace, duration_s: f64) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(5, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..2)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i),
                baselines::cocoserve(32),
            )
        })
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, fleet_setup(predictor))
        .run(trace, duration_s)
}

#[test]
fn no_predictor_emits_no_forecast_block() {
    let trace = Trace::steady(12.0, 10.0, 5);
    let r = run(None, &trace, 10.0);
    assert!(r.forecast.is_none());
    let doc = r.to_json().to_string();
    assert!(!doc.contains("\"forecast\""), "reactive-only JSON must be untouched");
    let parsed = Json::parse(&doc).unwrap();
    assert!(parsed.req("completed").as_usize().unwrap() > 0);
}

#[test]
fn predictive_fleet_golden_replay_across_scenarios() {
    for (name, trace) in [
        ("diurnal", Trace::diurnal(16.0, 14.0, 77)),
        ("burst", Trace::burst(14.0, 14.0, 77)),
        ("ramp", Trace::ramp(16.0, 14.0, 77)),
    ] {
        let a = run(Some(PredictConfig::default()), &trace, 14.0);
        let b = run(Some(PredictConfig::default()), &trace, 14.0);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "predictive scenario `{name}` not replay-deterministic"
        );
        assert!(a.total_completed() > 0, "scenario `{name}` served nothing");
        let f = a.forecast.expect("forecast block present");
        assert!(f.buckets > 0, "scenario `{name}` closed no rate buckets");
        // the JSON block mirrors the report
        let doc = a.to_json();
        let fj = doc.req("forecast");
        assert_eq!(fj.req("buckets").as_f64(), Some(f.buckets as f64));
        assert_eq!(fj.req("proposed").as_f64(), Some(f.stats.proposed as f64));
    }
}

#[test]
fn oracle_mode_replays_and_reports() {
    let trace = Trace::burst(14.0, 14.0, 31);
    let cfg = Some(PredictConfig { oracle: true, ..Default::default() });
    let a = run(cfg, &trace, 14.0);
    let b = run(cfg, &trace, 14.0);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    let f = a.forecast.expect("forecast block");
    assert!(f.oracle, "oracle flag must surface in the report");
}

#[test]
fn forecaster_observes_every_routed_arrival() {
    // Steady traffic, long enough that every arrival lands in a closed
    // bucket: the estimators' level must be near the true rate, and the
    // bucket count must cover the run.
    let trace = Trace::steady(10.0, 12.0, 9);
    let r = run(Some(PredictConfig::default()), &trace, 12.0);
    let f = r.forecast.unwrap();
    assert!(f.buckets >= 11, "only {} buckets closed over a 12 s run", f.buckets);
    // MAE of a steady Poisson stream is dominated by Poisson noise —
    // it must be a fraction of the rate, not a multiple of it
    assert!(
        f.mae_ewma < 10.0,
        "EWMA one-step MAE {} implausible for a 10 rps stream",
        f.mae_ewma
    );
}

#[test]
fn predictive_acts_no_later_than_reactive_under_burst() {
    // A flash crowd (4× base rate) against a 2-instance fleet: both
    // configurations must add capacity; the predictive one — burst
    // detector + short-horizon replication — must move no later than the
    // reactive queue-depth trigger, and must actually enact something.
    let trace = Trace::burst(16.0, 20.0, 41);
    let reactive = run(None, &trace, 20.0);
    let predictive = run(Some(PredictConfig::default()), &trace, 20.0);

    let first_capacity_action = |r: &SimReport| -> Option<f64> {
        let spin = r
            .fleet_events
            .iter()
            .filter(|e| e.phase == FleetPhase::SpinUp)
            .map(|e| e.t)
            .fold(f64::INFINITY, f64::min);
        let op = r
            .op_events
            .iter()
            .map(|e| e.t)
            .fold(f64::INFINITY, f64::min);
        let t = spin.min(op);
        t.is_finite().then_some(t)
    };

    let p = predictive.forecast.unwrap();
    assert!(p.stats.proposed > 0, "burst must register as a deficit");
    assert!(
        p.stats.enacted > 0,
        "the predictor must enact capacity under a 4x burst (stats: {:?})",
        p.stats
    );
    match (first_capacity_action(&reactive), first_capacity_action(&predictive)) {
        (Some(tr), Some(tp)) => assert!(
            tp <= tr + 1e-9,
            "predictive first action at {tp:.2}s is later than reactive at {tr:.2}s"
        ),
        (None, Some(_)) => {} // predictive acted, reactive never did — fine
        (r, p) => panic!("expected capacity actions, got reactive {r:?} predictive {p:?}"),
    }
}

#[test]
fn per_class_rate_split_is_deterministic_and_leaves_the_total_untouched() {
    // Drive two identically-tagged streams through independent
    // forecasters: the split must be bit-replayable. A third, untagged
    // twin of the same stream pins the classless no-op — the total-rate
    // forecast is bit-identical whether or not classes were observed,
    // and the premium forecast of an untagged stream is exactly zero.
    let forecaster = || {
        TrafficForecaster::new(
            1.0,
            Ewma::new(0.3),
            Holt::new(0.4, 0.2),
            HoltWinters::new(0.3, 0.1, 0.2, 8),
            BurstDetector::new(0.3, 3.0),
        )
    };
    let drive = |tag: bool| -> TrafficForecaster {
        let mut f = forecaster();
        for bucket in 0..40u64 {
            for i in 0..4u64 {
                f.observe(bucket as f64 + 0.2 * i as f64);
                if tag {
                    // one arrival in four is latency-sensitive
                    f.observe_class(if i == 0 {
                        SloClass::LatencySensitive
                    } else {
                        SloClass::BestEffort
                    });
                }
            }
        }
        f.advance(41.0);
        f
    };
    let a = drive(true);
    let b = drive(true);
    assert_eq!(
        a.forecast_premium(2.0).to_bits(),
        b.forecast_premium(2.0).to_bits(),
        "per-class split must replay bit-identically"
    );
    assert_eq!(a.premium_share().to_bits(), b.premium_share().to_bits());
    let untagged = drive(false);
    assert_eq!(
        a.forecast(2.0).to_bits(),
        untagged.forecast(2.0).to_bits(),
        "observing classes must not perturb the total-rate forecast"
    );
    assert_eq!(untagged.forecast_premium(2.0), 0.0, "untagged stream has no premium rate");
    assert_eq!(untagged.premium_share(), 0.0);
    assert!(
        (a.premium_share() - 0.25).abs() < 0.05,
        "smoothed share {} should track the 1-in-4 tagging",
        a.premium_share()
    );
}

#[test]
fn classed_predictive_fleet_replays_and_classless_predictor_ignores_tags() {
    // The full predictive pipeline under a class-aware policy (per-class
    // observation, premium-first deficits, premium spin floor) is
    // replay-deterministic and surfaces the slo block; the same predictive
    // pipeline under the default classless policy produces bytes identical
    // on the tagged trace and its payload-equal untagged twin.
    let classed_trace = Trace::two_tenant_classed(14.0, 14.0, 77);
    let mut setup = fleet_setup(Some(PredictConfig::default()));
    setup.router.policy = RoutePolicy::StrictPriority;
    let run_with = |setup: FleetSetup, trace: &Trace| -> SimReport {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::homogeneous(5, DeviceSpec::a100_40gb());
        let placements: Vec<_> = (0..2)
            .map(|i| {
                (
                    Placement::single_device(cfg.model.n_layers, i),
                    baselines::cocoserve(32),
                )
            })
            .collect();
        Simulation::with_fleet(cfg, cluster, placements, setup).run(trace, 14.0)
    };
    let a = run_with(setup, &classed_trace).to_json().to_string();
    let b = run_with(setup, &classed_trace).to_json().to_string();
    assert_eq!(a, b, "classed predictive run must replay byte-identically");
    assert!(a.contains("\"slo\":"), "class-aware run must carry the slo block");

    let classless = fleet_setup(Some(PredictConfig::default()));
    let tagged = run_with(classless, &classed_trace).to_json().to_string();
    let untagged = run_with(classless, &Trace::two_tenant(14.0, 14.0, 77))
        .to_json()
        .to_string();
    assert_eq!(
        tagged, untagged,
        "a classless predictor must never observe the class tags"
    );
    assert!(!tagged.contains("\"slo\":"), "classless golden must carry no slo key");
}

#[test]
fn predictor_without_fleet_reports_but_never_acts() {
    // A predictor configured on a fixed fleet (no FleetConfig): the
    // forecaster observes and reports, but no capacity action can exist.
    let trace = Trace::steady(12.0, 10.0, 3);
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(2, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..2)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i),
                baselines::cocoserve(32),
            )
        })
        .collect();
    let setup = FleetSetup {
        predictor: Some(PredictConfig::default()),
        ..Default::default()
    };
    let r = Simulation::with_fleet(cfg, cluster, placements, setup).run(&trace, 10.0);
    let f = r.forecast.expect("forecast block present without a fleet");
    assert!(f.buckets > 0);
    assert_eq!(f.stats.proposed, 0, "no fleet → no proposals");
    assert_eq!(f.stats.enacted, 0);
    assert!(r.fleet_events.is_empty());
}
