//! Cross-instance request routing — the fleet's front door.
//!
//! Arrivals land at the coordinator, not at a fixed instance: the event
//! kernel pops an `Arrival`, asks the [`Router`] to pick a serving
//! instance, and dispatches the request as a `Routed` event to that
//! instance. The policy is pluggable ([`RoutePolicy`]) and every decision
//! is deterministic: candidates are examined in ascending instance-id
//! order and every comparison breaks ties toward the lower id, so the same
//! trace always produces the same routing sequence (the fleet golden-replay
//! contract).
//!
//! ### Backpressure
//!
//! Each instance may carry an admission limit (max outstanding requests).
//! When no instance can admit, the request parks in the router's FIFO
//! [`Router::pending`] queue and is retried after every kernel event — the
//! first instance to free capacity drains the queue head. Requests shed by
//! an instance's OOM handling can likewise be handed back for re-routing
//! (see `sim::instance`), which is what lets a fleet survive a single
//! instance's memory cliff without failing the requests outright.
//!
//! ### Barrier-time routing (sharded kernel)
//!
//! Under the sharded event kernel (`SimConfig::shards ≥ 2`), arrivals
//! are *global* events — epoch barriers — so every routing decision is
//! made coordinator-side at a barrier, over candidate state that all
//! shards have fully caught up to. The router itself never observes a
//! half-drained shard. Combined with the deterministic scan order below,
//! this is why the sharded kernel's routing sequence (and hence its
//! metrics JSON) is byte-identical to the sequential kernel's.

use std::collections::VecDeque;

use crate::workload::Request;

/// How the coordinator picks a serving instance for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through admitting instances in id order. Oblivious to load —
    /// the baseline policy real gateways start from.
    RoundRobin,
    /// The instance with the fewest outstanding requests (pending +
    /// running + already-routed-but-undelivered); ties go to the lowest
    /// id. This reproduces the pre-fleet kernel's least-loaded dispatch.
    LeastOutstanding,
    /// The instance whose device set has the most free ledger bytes —
    /// KV-cache headroom — so long decodes land where their cache can
    /// grow; ties go to the lowest id.
    KvHeadroom,
}

/// Routing configuration for a simulation run.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Instance-selection policy.
    pub policy: RoutePolicy,
    /// Max outstanding requests an instance may hold before the router
    /// stops offering it new work (`None` = unlimited, the legacy
    /// behaviour).
    pub admission_limit: Option<usize>,
    /// Hand requests shed by an instance's OOM handling back to the
    /// router for re-routing instead of requeueing them locally.
    pub reroute_on_shed: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: false,
        }
    }
}

/// One instance's routing-relevant state, snapshotted by the kernel at
/// decision time.
#[derive(Debug, Clone, Copy)]
pub struct RouteCandidate {
    /// Is the instance accepting new work (active, past its cold start,
    /// not draining)?
    pub accepting: bool,
    /// Outstanding requests: scheduler pending + running + routed-but-
    /// undelivered.
    pub outstanding: usize,
    /// Free ledger bytes summed over the instance's device set (the
    /// KV-headroom signal).
    pub free_bytes: f64,
}

/// A request parked at the router under admission backpressure.
#[derive(Debug, Clone, Copy)]
pub struct Parked {
    /// The request itself (original arrival time preserved).
    pub req: Request,
    /// OOM-reload penalty the request carries from a previous instance.
    pub penalty: f64,
    /// Was this a shed re-route (vs. a first-time arrival)?
    pub reroute: bool,
}

/// The fleet's request router: policy + admission backpressure + the
/// parked-request queue.
#[derive(Debug)]
pub struct Router {
    /// Routing configuration this router was built with.
    pub cfg: RouterConfig,
    /// Requests no instance could admit, in arrival order. Retried after
    /// every kernel event.
    pub pending: VecDeque<Parked>,
    /// Round-robin cursor (next instance id to try first).
    cursor: usize,
    /// First-time routing decisions made (each trace arrival counts once).
    pub routes: u64,
    /// Re-routing decisions for shed requests.
    pub reroutes: u64,
}

impl Router {
    /// Build a router with the given configuration.
    pub fn new(cfg: RouterConfig) -> Router {
        Router { cfg, pending: VecDeque::new(), cursor: 0, routes: 0, reroutes: 0 }
    }

    /// Park a request that no instance could admit; the kernel retries the
    /// queue head after every event.
    pub fn park(&mut self, req: Request, penalty: f64, reroute: bool) {
        self.pending.push_back(Parked { req, penalty, reroute });
    }

    /// Can this candidate admit one more request under the configured
    /// backpressure limit?
    fn admits(&self, c: &RouteCandidate) -> bool {
        c.accepting
            && match self.cfg.admission_limit {
                Some(limit) => c.outstanding < limit,
                None => true,
            }
    }

    /// Pick an instance for one request, or `None` when every instance is
    /// saturated (the caller parks the request in [`Router::pending`]).
    /// Deterministic: candidates scan in ascending id order; every policy
    /// breaks ties toward the lower id (round-robin toward the cursor).
    pub fn pick(&mut self, candidates: &[RouteCandidate]) -> Option<usize> {
        let n = candidates.len();
        if n == 0 {
            return None;
        }
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                for k in 0..n {
                    let i = (self.cursor + k) % n;
                    if self.admits(&candidates[i]) {
                        self.cursor = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            RoutePolicy::LeastOutstanding => candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| self.admits(c))
                .min_by_key(|&(i, c)| (c.outstanding, i))
                .map(|(i, _)| i),
            RoutePolicy::KvHeadroom => candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| self.admits(c))
                // max free bytes; total_cmp is a total order so ties fall
                // to the lower id via min_by's first-wins semantics
                .min_by(|(ia, a), (ib, b)| {
                    b.free_bytes.total_cmp(&a.free_bytes).then(ia.cmp(ib))
                })
                .map(|(i, _)| i),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(outstanding: usize, free_bytes: f64) -> RouteCandidate {
        RouteCandidate { accepting: true, outstanding, free_bytes }
    }

    fn router(policy: RoutePolicy, limit: Option<usize>) -> Router {
        Router::new(RouterConfig {
            policy,
            admission_limit: limit,
            reroute_on_shed: false,
        })
    }

    #[test]
    fn round_robin_cycles_in_id_order() {
        let mut r = router(RoutePolicy::RoundRobin, None);
        let c = vec![cand(0, 0.0); 3];
        let picks: Vec<_> = (0..5).map(|_| r.pick(&c).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn round_robin_skips_saturated_instances() {
        let mut r = router(RoutePolicy::RoundRobin, Some(4));
        let c = vec![cand(4, 0.0), cand(1, 0.0), cand(4, 0.0)];
        assert_eq!(r.pick(&c), Some(1));
        assert_eq!(r.pick(&c), Some(1), "only instance 1 admits");
    }

    #[test]
    fn least_outstanding_ties_to_lowest_id() {
        let mut r = router(RoutePolicy::LeastOutstanding, None);
        let c = vec![cand(3, 0.0), cand(1, 0.0), cand(1, 0.0)];
        assert_eq!(r.pick(&c), Some(1));
        let even = vec![cand(2, 0.0); 4];
        assert_eq!(r.pick(&even), Some(0));
    }

    #[test]
    fn kv_headroom_prefers_most_free_bytes() {
        let mut r = router(RoutePolicy::KvHeadroom, None);
        let c = vec![cand(0, 1.0), cand(0, 9.0), cand(0, 9.0)];
        assert_eq!(r.pick(&c), Some(1), "ties break to the lower id");
    }

    #[test]
    fn saturation_returns_none() {
        let mut r = router(RoutePolicy::LeastOutstanding, Some(2));
        let c = vec![cand(2, 0.0), cand(5, 0.0)];
        assert_eq!(r.pick(&c), None);
    }

    #[test]
    fn replayed_candidate_stream_routes_identically() {
        // The golden-replay contract: two routers fed the same candidate
        // snapshots make the same decisions — including hidden cursor
        // state. This is what barrier-time routing leans on for parity.
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::KvHeadroom]
        {
            let mut a = router(policy, Some(3));
            let mut b = router(policy, Some(3));
            let mut seed = 0x9e3779b97f4a7c15u64;
            for step in 0..200 {
                let c: Vec<_> = (0..4u64)
                    .map(|i| {
                        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(i + 1);
                        cand((seed >> 60) as usize % 4, (seed >> 32) as f64)
                    })
                    .collect();
                assert_eq!(a.pick(&c), b.pick(&c), "{policy:?} diverged at step {step}");
            }
        }
    }

    #[test]
    fn non_accepting_instances_are_skipped() {
        let mut r = router(RoutePolicy::LeastOutstanding, None);
        let mut c = vec![cand(0, 0.0), cand(9, 0.0)];
        c[0].accepting = false;
        assert_eq!(r.pick(&c), Some(1));
        c[1].accepting = false;
        assert_eq!(r.pick(&c), None);
        assert_eq!(r.pick(&[]), None);
    }
}
