//! Ablation — graduated scale-down (Algorithm 2) vs batch-reduction-only.
//!
//! DESIGN.md design choice 2: Algorithm 2 tries migration, then replica
//! eviction, and only then batch reduction. The ablation compares the full
//! graduated policy against a degenerate policy that jumps straight to
//! phase 3 (what a system without module migration must do), under the
//! same memory-pressure scenario. Expectation: the graduated policy keeps
//! throughput (batch size intact) while both resolve the violations.

use cocoserve::autoscale::{scale_down, Pressure, ScaleDownConfig};
use cocoserve::cluster::{Cluster, GIB};
use cocoserve::model::cost::CostModel;
use cocoserve::model::{ModelConfig, ModuleId, ModuleKind};
use cocoserve::ops::{ModuleOps, PlanExecutor};
use cocoserve::placement::Placement;
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;

struct Outcome {
    resolved: bool,
    final_batch: usize,
    migrations: usize,
    evictions: usize,
}

fn scenario(graduated: bool) -> Outcome {
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let mut cl = Cluster::paper_testbed();
    let mut pl = Placement::single_device(40, 0);
    ops.deploy_instance(&mut cl, &pl).unwrap();
    // KV allocations + co-tenant push device 0 to ~95%
    for l in 0..4 {
        let kv = ModuleId::layer(ModuleKind::KvCache, l);
        cl.device_mut(0).alloc(&ops.tag(&kv, 0), 2.0 * GIB).unwrap();
    }
    cl.device_mut(0).alloc("co-tenant", 5.6 * GIB).unwrap();

    let cfg = if graduated {
        ScaleDownConfig::default()
    } else {
        // degenerate: no migration candidates, no eviction (simulated by
        // zero candidates) — phase 3 only
        ScaleDownConfig { max_migration_candidates: 0, ..Default::default() }
    };
    // batch-only mode also needs the violation tied to batch size so
    // phase 3 can clear it; full mode clears via memory relief.
    let out = scale_down(
        &ops,
        &cl,
        &pl,
        0,
        Pressure::Memory,
        32,
        &cfg,
        |_| 2.0 * GIB,
        |cl, _pl, bs| {
            // violating while device 0 above 90% AND batch demand high;
            // batch reduction relieves KV demand proportionally.
            let mem_over = cl.mem_frac(0) > 0.90;
            mem_over && bs > 8
        },
    );
    // the planner proposed; the executor commits — with dry-run parity
    let dry = out.plan.dry_run(&ops, &cl, &pl).unwrap();
    let executed = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
    assert_eq!(dry, executed, "dry-run must equal executed cost");
    let migrations = out
        .actions
        .iter()
        .filter(|a| matches!(a, cocoserve::autoscale::scale_down::Action::Migrated { .. }))
        .count();
    let evictions = out
        .actions
        .iter()
        .filter(|a| matches!(a, cocoserve::autoscale::scale_down::Action::Evicted { .. }))
        .count();
    Outcome { resolved: out.resolved, final_batch: out.batch_size, migrations, evictions }
}

fn main() {
    println!("Ablation — graduated scale-down vs batch-reduction-only\n");
    let full = scenario(true);
    let batch_only = scenario(false);
    let mut t = Table::new(&["policy", "resolved", "final batch", "migrations",
                             "evictions"]);
    for (name, o) in [("graduated (Alg. 2)", &full), ("batch-only", &batch_only)] {
        t.row(&[
            name.to_string(),
            format!("{}", o.resolved),
            format!("{}", o.final_batch),
            format!("{}", o.migrations),
            format!("{}", o.evictions),
        ]);
    }
    t.print();
    assert!(full.resolved && batch_only.resolved);
    assert!(
        full.final_batch > batch_only.final_batch,
        "graduated policy must preserve more serving capacity"
    );
    println!(
        "\ngraduated policy resolves the violation by migrating {} module(s) \
         and keeps batch {} — batch-only sacrifices throughput (batch {}).",
        full.migrations, full.final_batch, batch_only.final_batch
    );
    let mut rep = Report::new("ablation_scaledown");
    rep.set("graduated_batch", json::num(full.final_batch as f64));
    rep.set("batch_only_batch", json::num(batch_only.final_batch as f64));
    println!("report: {}", rep.write().unwrap().display());
}
