//! Fig. 12 (extension) — proactive vs reactive scaling under dynamic
//! traffic: the predictive control plane's claim check.
//!
//! Three fleet configurations serve identical traces on the same
//! 8-device cluster, across the three dynamic scenarios forecasting is
//! for (diurnal / burst / ramp):
//!
//! * **reactive** — the PR-4 fleet controller alone: mean-outstanding
//!   pressure, cooldown, drain-then-release. Capacity arrives *after*
//!   queues build, and every spin-up then pays `cold_start_s` while the
//!   backlog compounds.
//! * **predictive** — the same reactive controller plus the
//!   `forecast::PredictiveController`: streaming estimators propose
//!   capacity at each action's own enactment latency, replication
//!   bridges burst onsets, drains are forecast-gated.
//! * **oracle** — the predictive controller reading the trace's true
//!   future rates (trace-peeking): the upper bound on what any online
//!   estimator could achieve. Reported, not asserted against.
//!
//! Asserted per the issue's acceptance bar:
//! (a) on diurnal and ramp, predictive strictly improves SLO attainment
//!     over reactive at equal-or-lower device-seconds;
//! (b) on burst, predictive at least halves the burst-onset p99
//!     degradation (onset-window p99 minus pre-burst p99) vs reactive;
//! (c) every cell golden-replays byte-identically.
//!
//! ```bash
//! cargo bench --bench fig12_predictive              # full sweep
//! FIG12_SMOKE=1 cargo bench --bench fig12_predictive  # CI smoke
//! ```

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, FleetPhase, RoutePolicy, RouterConfig};
use cocoserve::forecast::PredictConfig;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::util::stats::P2Quantile;
use cocoserve::workload::Trace;

const N_DEVICES: usize = 8;
const SEED_INSTANCES: usize = 2;
const SEED: u64 = 120;
/// Shared SLO all three deployments are judged against.
const SLO_S: f64 = 20.0;

struct BenchShape {
    rps: f64,
    duration_s: f64,
    smoke: bool,
}

impl BenchShape {
    fn from_env() -> BenchShape {
        let smoke = std::env::var("FIG12_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
            || std::env::args().any(|a| a == "--smoke");
        if smoke {
            BenchShape { rps: 18.0, duration_s: 48.0, smoke }
        } else {
            BenchShape { rps: 24.0, duration_s: 72.0, smoke }
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Reactive,
    Predictive,
    Oracle,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Reactive => "reactive",
            Mode::Predictive => "predictive",
            Mode::Oracle => "oracle",
        }
    }
}

fn sim_config() -> SimConfig {
    let mut cfg = SimConfig::paper_13b();
    cfg.slo_latency_s = SLO_S;
    cfg
}

fn policy() -> SimPolicy {
    baselines::cocoserve(32)
}

/// The shared fleet posture: elastic 2→8, the paper's ~8 s cold start,
/// vacancy harvesting off (capacity is added on demand, not hoarded).
fn setup(mode: Mode) -> FleetSetup {
    let mut fleet = FleetConfig::elastic(SEED_INSTANCES, N_DEVICES, policy());
    fleet.scale_out_queue = 20.0;
    fleet.cooldown_ticks = 2;
    fleet.idle_ticks_before_drain = 2;
    let predictor = match mode {
        Mode::Reactive => None,
        Mode::Predictive => Some(PredictConfig::default()),
        Mode::Oracle => Some(PredictConfig { oracle: true, ..Default::default() }),
    };
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::KvHeadroom,
            admission_limit: None,
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(fleet),
        controller: cocoserve::autoscale::ControllerConfig { t_up: 2.0, ..Default::default() },
        predictor,
    }
}

fn run(mode: Mode, trace: &Trace, duration_s: f64) -> SimReport {
    let cfg = sim_config();
    let cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..SEED_INSTANCES)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy()))
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, setup(mode)).run(trace, duration_s)
}

/// p99 end-to-end latency over completions whose *arrival* fell in
/// `[from, to)` — streamed through the P² estimator (the satellite's
/// O(1)-memory percentile path; exact below five samples).
fn window_p99(r: &SimReport, from: f64, to: f64) -> f64 {
    let mut p = P2Quantile::new(0.99);
    for m in &r.monitors {
        for c in m.completions() {
            if (from..to).contains(&c.arrival_s) {
                p.add(c.e2e_latency());
            }
        }
    }
    p.value()
}

fn main() {
    let shape = BenchShape::from_env();
    println!(
        "Fig. 12 — proactive vs reactive scaling, {N_DEVICES}×A100, elastic \
         {SEED_INSTANCES}→{N_DEVICES}, {:.0} rps target, {:.0}s, SLO ≤ {SLO_S:.0}s{}\n",
        shape.rps,
        shape.duration_s,
        if shape.smoke { " (SMOKE)" } else { "" }
    );

    let scenarios: Vec<(&str, Trace)> = vec![
        ("diurnal", Trace::diurnal(shape.rps, shape.duration_s, SEED)),
        ("burst", Trace::burst(shape.rps, shape.duration_s, SEED)),
        ("ramp", Trace::ramp(shape.rps, shape.duration_s, SEED)),
    ];

    let mut table = Table::new(&[
        "scenario", "mode", "SLO%", "dev·s", "p99", "spins", "proposed", "enacted",
        "vetoed", "drain-veto",
    ]);
    let mut rep = Report::new("fig12_predictive");
    let mut replay_ok = true;

    for (name, trace) in &scenarios {
        let mut cells = Vec::new();
        for mode in [Mode::Reactive, Mode::Predictive, Mode::Oracle] {
            let r = run(mode, trace, shape.duration_s);
            // (c) golden replay per cell
            let again = run(mode, trace, shape.duration_s);
            let identical = r.to_json().to_string() == again.to_json().to_string();
            replay_ok &= identical;
            if !identical {
                eprintln!("WARNING: {name}/{} not replay-deterministic", mode.name());
            }
            let spins = r
                .fleet_events
                .iter()
                .filter(|e| e.phase == FleetPhase::SpinUp)
                .count();
            let f = r.forecast;
            let p99 = r.latency_p2(0.99);
            table.row(&[
                name.to_string(),
                mode.name().to_string(),
                format!("{:.1}", r.slo_attainment() * 100.0),
                format!("{:.0}", r.device_seconds),
                format!("{p99:.2}s"),
                format!("{spins}"),
                f.map_or("-".into(), |f| f.stats.proposed.to_string()),
                f.map_or("-".into(), |f| f.stats.enacted.to_string()),
                f.map_or("-".into(), |f| f.stats.vetoed.to_string()),
                f.map_or("-".into(), |f| f.stats.drain_vetoes.to_string()),
            ]);
            rep.set(
                &format!("{name}_{}", mode.name()),
                json::obj(vec![
                    ("slo_attainment", json::num(r.slo_attainment())),
                    ("device_seconds", json::num(r.device_seconds)),
                    ("p99_s", json::num(p99)),
                    ("completed", json::num(r.total_completed() as f64)),
                    ("spin_ups", json::num(spins as f64)),
                    (
                        "forecast_mae_holt",
                        json::num(f.map_or(0.0, |f| f.mae_holt)),
                    ),
                    (
                        "predictive_enacted",
                        json::num(f.map_or(0.0, |f| f.stats.enacted as f64)),
                    ),
                    (
                        "predictive_vetoed",
                        json::num(f.map_or(0.0, |f| f.stats.vetoed as f64)),
                    ),
                    (
                        "drain_vetoes",
                        json::num(f.map_or(0.0, |f| f.stats.drain_vetoes as f64)),
                    ),
                    ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
                ]),
            );
            cells.push((mode, r));
        }

        let reactive = &cells[0].1;
        let predictive = &cells[1].1;

        match *name {
            // (a) predictive strictly improves SLO attainment at
            // equal-or-lower device-seconds
            "diurnal" | "ramp" => {
                assert!(
                    predictive.slo_attainment() > reactive.slo_attainment(),
                    "{name}: predictive SLO {:.4} must strictly beat reactive {:.4}",
                    predictive.slo_attainment(),
                    reactive.slo_attainment()
                );
                assert!(
                    predictive.device_seconds <= reactive.device_seconds,
                    "{name}: predictive {:.1} dev·s must not exceed reactive {:.1}",
                    predictive.device_seconds,
                    reactive.device_seconds
                );
            }
            // (b) predictive at least halves burst-onset p99 degradation
            "burst" => {
                let (start, end) = (0.4 * shape.duration_s, 0.6 * shape.duration_s);
                let onset_w = 0.5 * (end - start);
                let base_r = window_p99(reactive, 0.0, start);
                let base_p = window_p99(predictive, 0.0, start);
                let deg_r = (window_p99(reactive, start, start + onset_w) - base_r).max(0.0);
                let deg_p =
                    (window_p99(predictive, start, start + onset_w) - base_p).max(0.0);
                println!(
                    "\nburst onset p99 degradation: reactive +{deg_r:.2}s, \
                     predictive +{deg_p:.2}s"
                );
                rep.set(
                    "burst_onset_p99_degradation",
                    json::obj(vec![
                        ("reactive", json::num(deg_r)),
                        ("predictive", json::num(deg_p)),
                    ]),
                );
                assert!(
                    deg_p <= 0.5 * deg_r,
                    "burst onset: predictive degradation {deg_p:.2}s must be ≤ half \
                     of reactive {deg_r:.2}s"
                );
            }
            _ => unreachable!(),
        }

        // the predictor must actually have participated
        let f = predictive.forecast.expect("predictive cell carries a forecast block");
        assert!(f.buckets > 0, "{name}: no rate buckets closed");
        assert!(
            f.stats.proposed > 0,
            "{name}: the predictor never saw a deficit — the scenario is miscalibrated"
        );
    }

    table.print();
    println!(
        "\ngolden replay across all cells: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
