//! The predictive control plane: streaming traffic forecasting + horizon
//! capacity planning.
//!
//! The reactive controllers ([`crate::autoscale::Controller`] per
//! instance, [`crate::coordinator::FleetController`] per fleet) act on
//! *live* pressure — by the time they fire, demand has already arrived,
//! and whole-instance capacity pays `cold_start_s` before it serves a
//! single request. The paper's cost/availability headline depends on
//! scaling *before* demand arrives; this module is that missing half:
//!
//! * [`estimator`] — deterministic O(1)-memory streaming estimators over
//!   the arrival stream (EWMA / Holt / Holt-Winters / burst z-score),
//!   fed from `Routed` events so the predictor sees exactly what the
//!   coordinator routes;
//! * [`capacity`] — the horizon capacity model converting a predicted
//!   rate into required instance-equivalents by inverting the existing
//!   Eq. 4 speedup model and the compiled roofline step costs — one
//!   shared costing path, no parallel formulas;
//! * [`predictive`] — the [`PredictiveController`]: per-action lead
//!   times equal to enactment latency (dry-run plan duration for
//!   replication, `cold_start_s` for spin-up), proposals arbitrated with
//!   the reactive signal (predictive proposes, reactive can
//!   veto/escalate), and forecast-gated scale-down.
//!
//! Wiring: [`crate::sim::FleetSetup`] carries an optional
//! [`PredictConfig`]; with none configured the event kernel schedules no
//! `ForecastTick` events and the metrics JSON is byte-identical to the
//! reactive-only kernel — the subsystem is strictly additive.
//! `benches/fig12_predictive.rs` measures the resulting SLO/cost gains
//! against reactive-only and trace-oracle bounds.

pub mod capacity;
pub mod estimator;
pub mod predictive;

pub use capacity::{replicas_for_speedup, uniform_degree_for_speedup, CapacityModel};
pub use estimator::{BurstDetector, Ewma, Holt, HoltWinters, TrafficForecaster};
pub use predictive::{
    PredictConfig, PredictReport, PredictStats, PredictiveController, PREMIUM_CAPACITY_FRACTION,
};
