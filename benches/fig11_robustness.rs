//! Fig. 11 — robustness: OOM occurrence rate (11a) and SLO attainment (11b).
//!
//! Paper claims: HFT shows ~34% OOM error rate beyond 50 RPS vs CoCoServe's
//! ~2% (17× better); HFT's SLO attainment deteriorates from ~25 RPS and
//! fails past 30; CoCoServe holds near-perfect attainment to ~50 RPS, vLLM
//! in between.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: [f64; 6] = [5.0, 15.0, 25.0, 35.0, 45.0, 55.0];

/// Memory-tight single-device deployment (the robustness stressor).
fn run(policy: SimPolicy, rps: f64, seed: u64) -> (f64, f64) {
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::paper_testbed();
    cluster.device_mut(0).alloc("co-tenant", 12.0 * GIB).unwrap();
    let placement = Placement::single_device(cfg.model.n_layers, 0);
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
    let trace = Trace::generate(
        Arrival::Burst { base: rps * 0.6, burst: rps, start_s: 5.0, end_s: 15.0 },
        LengthDist::alpaca(),
        20.0,
        seed,
    );
    let r = sim.run(&trace, 20.0);
    (r.oom_rate() * 100.0, r.slo_attainment() * 100.0)
}

fn main() {
    println!("Fig. 11 — OOM rate & SLO attainment under bursty load (13B, tight memory)\n");
    let mut t = Table::new(&["rps", "hft OOM%", "coco OOM%", "hft SLO%",
                             "vllm SLO%", "coco SLO%"]);
    let mut rep = Report::new("fig11_robustness");
    let (mut h_oom_hi, mut c_oom_hi) = (0.0f64, 0.0f64);
    for &rps in &RPS {
        let (ho, hs) = run(baselines::hft(16), rps, 21);
        let (vo, vs) = run(baselines::vllm_like(48), rps, 21);
        let (co, cs) = run(baselines::cocoserve(48), rps, 21);
        let _ = vo;
        if rps >= 45.0 {
            h_oom_hi = h_oom_hi.max(ho);
            c_oom_hi = c_oom_hi.max(co.max(0.1));
        }
        t.row(&[
            format!("{rps:.0}"),
            format!("{ho:.1}"),
            format!("{co:.1}"),
            format!("{hs:.1}"),
            format!("{vs:.1}"),
            format!("{cs:.1}"),
        ]);
        rep.set(
            &format!("rps{}", rps as u64),
            json::arr([ho, co, hs, vs, cs].into_iter().map(json::num)),
        );
    }
    t.print();
    println!(
        "\nhigh-load OOM rate: HFT {h_oom_hi:.1}% vs CoCoServe {c_oom_hi:.1}% \
         → {:.0}× stability improvement (paper: 34% vs 2%, 17×)",
        h_oom_hi / c_oom_hi
    );
    println!("report: {}", rep.write().unwrap().display());
}
