//! Request Scheduler (§5): admission, batching, and replica batch-splitting.
//!
//! Decides *what to run next* — the engine (real path) and the simulator
//! (paper-scale path) both execute its decisions, so baseline policies and
//! CoCoServe differ only in configuration:
//!
//! * [`BatchPolicy::Static`] — HFT-style batch-at-a-time: wait for a full
//!   batch (or timeout), run it to completion, then take the next batch.
//! * [`BatchPolicy::Continuous`] — Orca/vLLM-style continuous batching:
//!   decode every step with whatever is running; admit new sequences the
//!   moment slots free.
//!
//! [`split_batch`] implements Fig. 4's workload distribution across layer
//! replicas (batch 15 → shares 8/7 at degree 2).

use std::collections::VecDeque;

use crate::workload::Request;

/// Scheduler policy knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    /// Fixed-size synchronous batches (HFT-like). `timeout_s`: dispatch a
    /// partial batch if the oldest request waited this long.
    Static { timeout_s: f64 },
    /// Continuous batching (vLLM/CoCoServe-like).
    Continuous,
}

/// Admission/batching configuration handed to [`Scheduler::new`].
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Maximum sequences decoded together (also the static batch size).
    pub max_batch: usize,
    /// Which batching discipline to run (see [`BatchPolicy`]).
    pub policy: BatchPolicy,
}

impl SchedulerConfig {
    /// HFT-style static batching: full batches of `batch`, 0.5 s timeout.
    pub fn hft(batch: usize) -> SchedulerConfig {
        SchedulerConfig { max_batch: batch, policy: BatchPolicy::Static { timeout_s: 0.5 } }
    }

    /// Continuous batching with at most `max_batch` concurrent sequences.
    pub fn continuous(max_batch: usize) -> SchedulerConfig {
        SchedulerConfig { max_batch, policy: BatchPolicy::Continuous }
    }
}

/// A sequence the scheduler is tracking.
#[derive(Debug, Clone)]
struct Tracked {
    req: Request,
    /// Tokens generated so far (engine reports progress).
    generated: usize,
    /// Set once the prefill step has run.
    prefilled: bool,
}

/// What the engine should execute next.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Run prefill for these request ids (batched).
    Prefill { request_ids: Vec<u64> },
    /// Run one decode iteration for these request ids.
    Decode { request_ids: Vec<u64> },
    /// Nothing runnable right now.
    Idle,
}

/// The scheduler: pending queue + running set + policy.
#[derive(Debug)]
pub struct Scheduler {
    /// Active policy + batch-size configuration (read-only after `new`).
    pub cfg: SchedulerConfig,
    pending: VecDeque<Tracked>,
    running: Vec<Tracked>,
    /// In Static mode: the current synchronous batch must fully drain
    /// before admission reopens.
    draining: bool,
    completed: u64,
}

impl Scheduler {
    /// Build an empty scheduler with the given configuration.
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler { cfg, pending: VecDeque::new(), running: vec![], draining: false, completed: 0 }
    }

    /// Enqueue a request; it waits in the pending queue until admitted.
    pub fn submit(&mut self, req: Request) {
        self.pending.push_back(Tracked { req, generated: 0, prefilled: false });
    }

    /// Number of requests waiting for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Number of sequences currently in the running set.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Total sequences that produced all their tokens since construction.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// True when there is neither pending nor running work.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.running.is_empty()
    }

    /// Queue depth signal for monitors (pending + running).
    pub fn load(&self) -> usize {
        self.pending.len() + self.running.len()
    }

    /// Earliest future time at which this scheduler could become runnable
    /// without any external state change — the event kernel schedules a
    /// wake-up here. Only static batching has such a deadline (a partial
    /// batch dispatches when its oldest request times out); continuous
    /// batching is runnable immediately whenever it has work.
    pub fn next_deadline(&self) -> Option<f64> {
        match self.cfg.policy {
            BatchPolicy::Continuous => None,
            BatchPolicy::Static { timeout_s } => {
                if self.draining && !self.running.is_empty() {
                    return None;
                }
                self.pending.front().map(|t| t.req.arrival_s + timeout_s)
            }
        }
    }

    /// Decide the next step at time `now`.
    pub fn next_step(&mut self, now: f64) -> Step {
        match self.cfg.policy {
            BatchPolicy::Continuous => self.next_continuous(),
            BatchPolicy::Static { timeout_s } => self.next_static(now, timeout_s),
        }
    }

    fn admit(&mut self, max_new: usize) -> Vec<u64> {
        let mut ids = vec![];
        while ids.len() < max_new {
            let Some(t) = self.pending.pop_front() else { break };
            ids.push(t.req.id);
            self.running.push(t);
        }
        ids
    }

    fn next_continuous(&mut self) -> Step {
        // Admit into free slots; new sequences prefill first.
        let free = self.cfg.max_batch.saturating_sub(self.running.len());
        let admitted = self.admit(free);
        if !admitted.is_empty() {
            return Step::Prefill { request_ids: admitted };
        }
        // Anything admitted-but-not-prefilled (e.g. after engine restart)?
        let unprefilled: Vec<u64> = self
            .running
            .iter()
            .filter(|t| !t.prefilled)
            .map(|t| t.req.id)
            .collect();
        if !unprefilled.is_empty() {
            return Step::Prefill { request_ids: unprefilled };
        }
        if self.running.is_empty() {
            return Step::Idle;
        }
        Step::Decode {
            request_ids: self.running.iter().map(|t| t.req.id).collect(),
        }
    }

    fn next_static(&mut self, now: f64, timeout_s: f64) -> Step {
        if self.running.is_empty() {
            self.draining = false;
        }
        if !self.draining {
            let full = self.pending.len() >= self.cfg.max_batch;
            let timed_out = self
                .pending
                .front()
                .map(|t| now - t.req.arrival_s >= timeout_s)
                .unwrap_or(false);
            if full || (timed_out && !self.pending.is_empty()) {
                let admitted = self.admit(self.cfg.max_batch);
                self.draining = true;
                return Step::Prefill { request_ids: admitted };
            }
            return Step::Idle;
        }
        // drain the current batch to completion
        if self.running.is_empty() {
            self.draining = false;
            return Step::Idle;
        }
        Step::Decode {
            request_ids: self.running.iter().map(|t| t.req.id).collect(),
        }
    }

    /// Engine feedback: the prefill step for these ids ran (1 token each).
    pub fn on_prefilled(&mut self, ids: &[u64]) {
        for t in self.running.iter_mut().filter(|t| ids.contains(&t.req.id)) {
            t.prefilled = true;
            t.generated = 1; // prefill emits the first new token
        }
        self.reap();
    }

    /// Engine feedback: one decode iteration ran for these ids.
    pub fn on_decoded(&mut self, ids: &[u64]) {
        for t in self.running.iter_mut().filter(|t| ids.contains(&t.req.id)) {
            t.generated += 1;
        }
        self.reap();
    }

    /// Remove sequences that produced all their tokens; returns finished ids.
    fn reap(&mut self) -> Vec<u64> {
        let mut done = vec![];
        self.running.retain(|t| {
            if t.generated >= t.req.output_tokens {
                done.push(t.req.id);
                false
            } else {
                true
            }
        });
        self.completed += done.len() as u64;
        done
    }

    /// Finished ids drained since the last call (engine completion stream).
    pub fn take_finished(&mut self) -> Vec<u64> {
        // reap() already removed them; recompute via counters is awkward —
        // so reap directly here too and return.
        self.reap()
    }

    /// Ids still waiting in the pending queue.
    pub fn pending_ids(&self) -> Vec<u64> {
        self.pending.iter().map(|t| t.req.id).collect()
    }

    /// Forcibly remove a running sequence without completing it (vLLM-style
    /// preemption; the caller usually resubmits it).
    pub fn preempt(&mut self, id: u64) -> bool {
        let before = self.running.len();
        self.running.retain(|t| t.req.id != id);
        self.running.len() != before
    }

    /// Running request ids + their remaining tokens (simulator view).
    pub fn running_view(&self) -> Vec<(u64, usize, usize)> {
        self.running
            .iter()
            .map(|t| (t.req.id, t.req.prompt_tokens, t.req.output_tokens - t.generated))
            .collect()
    }
}

/// Fig. 4 workload distribution: split `batch` across `degree` replicas as
/// evenly as possible (15 @ 2 → [8, 7]). Earlier replicas get the +1s.
pub fn split_batch(batch: usize, degree: usize) -> Vec<usize> {
    assert!(degree > 0);
    let base = batch / degree;
    let extra = batch % degree;
    (0..degree)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn req(id: u64, at: f64, out: usize) -> Request {
        Request {
            id,
            arrival_s: at,
            prompt_tokens: 8,
            output_tokens: out,
            class: crate::workload::SloClass::default(),
        }
    }

    #[test]
    fn split_batch_matches_fig4() {
        assert_eq!(split_batch(15, 2), vec![8, 7]);
        assert_eq!(split_batch(15, 1), vec![15]);
        assert_eq!(split_batch(7, 3), vec![3, 2, 2]);
        assert_eq!(split_batch(0, 2), vec![0, 0]);
    }

    #[test]
    fn prop_split_batch_conserves_and_balances() {
        prop::check(
            "split-batch",
            |r: &mut Rng| (r.below(200) as usize, 1 + r.below(8) as usize),
            |&(b, p)| {
                let s = split_batch(b, p);
                if s.iter().sum::<usize>() != b {
                    return Err("sum mismatch".into());
                }
                let mx = *s.iter().max().unwrap();
                let mn = *s.iter().min().unwrap();
                if mx - mn > 1 {
                    return Err(format!("imbalance {s:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn continuous_prefills_then_decodes() {
        let mut s = Scheduler::new(SchedulerConfig::continuous(4));
        s.submit(req(0, 0.0, 3));
        s.submit(req(1, 0.0, 2));
        match s.next_step(0.0) {
            Step::Prefill { request_ids } => assert_eq!(request_ids, vec![0, 1]),
            other => panic!("{other:?}"),
        }
        s.on_prefilled(&[0, 1]);
        match s.next_step(0.1) {
            Step::Decode { request_ids } => assert_eq!(request_ids.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn continuous_admits_mid_flight() {
        let mut s = Scheduler::new(SchedulerConfig::continuous(4));
        s.submit(req(0, 0.0, 10));
        s.next_step(0.0);
        s.on_prefilled(&[0]);
        // a new request arrives while 0 decodes — next step must prefill it
        s.submit(req(1, 0.5, 5));
        match s.next_step(0.5) {
            Step::Prefill { request_ids } => assert_eq!(request_ids, vec![1]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn continuous_respects_max_batch() {
        let mut s = Scheduler::new(SchedulerConfig::continuous(2));
        for i in 0..5 {
            s.submit(req(i, 0.0, 4));
        }
        match s.next_step(0.0) {
            Step::Prefill { request_ids } => assert_eq!(request_ids.len(), 2),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.pending_len(), 3);
    }

    #[test]
    fn completion_frees_slots() {
        let mut s = Scheduler::new(SchedulerConfig::continuous(2));
        s.submit(req(0, 0.0, 1)); // finishes at prefill
        s.submit(req(1, 0.0, 2));
        s.submit(req(2, 0.0, 2));
        s.next_step(0.0);
        s.on_prefilled(&[0, 1]);
        assert_eq!(s.completed(), 1);
        // slot freed → request 2 admitted
        match s.next_step(0.1) {
            Step::Prefill { request_ids } => assert_eq!(request_ids, vec![2]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn static_waits_for_full_batch() {
        let mut s = Scheduler::new(SchedulerConfig::hft(3));
        s.submit(req(0, 0.0, 2));
        s.submit(req(1, 0.0, 2));
        assert_eq!(s.next_step(0.01), Step::Idle); // 2 < 3, no timeout
        s.submit(req(2, 0.1, 2));
        match s.next_step(0.1) {
            Step::Prefill { request_ids } => assert_eq!(request_ids.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn static_timeout_dispatches_partial() {
        let mut s = Scheduler::new(SchedulerConfig::hft(8));
        s.submit(req(0, 0.0, 2));
        assert_eq!(s.next_step(0.1), Step::Idle);
        match s.next_step(0.6) {
            Step::Prefill { request_ids } => assert_eq!(request_ids, vec![0]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn static_drains_before_admitting() {
        let mut s = Scheduler::new(SchedulerConfig::hft(2));
        for i in 0..4 {
            s.submit(req(i, 0.0, 2));
        }
        s.next_step(0.0); // prefill batch {0,1}
        s.on_prefilled(&[0, 1]);
        // batch not drained: new arrivals must NOT be admitted
        match s.next_step(0.2) {
            Step::Decode { request_ids } => assert_eq!(request_ids, vec![0, 1]),
            other => panic!("{other:?}"),
        }
        s.on_decoded(&[0, 1]); // both reach 2/2 → finished
        assert_eq!(s.running_len(), 0);
        match s.next_step(0.3) {
            Step::Prefill { request_ids } => assert_eq!(request_ids, vec![2, 3]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn static_deadline_tracks_oldest_pending() {
        let mut s = Scheduler::new(SchedulerConfig::hft(4));
        assert_eq!(s.next_deadline(), None);
        s.submit(req(0, 1.0, 2));
        s.submit(req(1, 1.5, 2));
        assert_eq!(s.next_deadline(), Some(1.5)); // 1.0 + timeout 0.5
        // dispatch at the deadline, then the batch drains with no deadline
        match s.next_step(1.5) {
            Step::Prefill { request_ids } => assert_eq!(request_ids.len(), 2),
            other => panic!("{other:?}"),
        }
        s.on_prefilled(&[0, 1]);
        assert_eq!(s.next_deadline(), None);
    }

    #[test]
    fn continuous_has_no_deadline() {
        let mut s = Scheduler::new(SchedulerConfig::continuous(4));
        s.submit(req(0, 0.0, 2));
        assert_eq!(s.next_deadline(), None);
    }

    #[test]
    fn prop_conservation_no_request_lost() {
        prop::check(
            "scheduler-conservation",
            |r: &mut Rng| {
                let n = 1 + r.below(30) as usize;
                let max_b = 1 + r.below(8) as usize;
                let cont = r.f64() < 0.5;
                let outs: Vec<usize> =
                    (0..n).map(|_| 1 + r.below(6) as usize).collect();
                (max_b, cont, outs)
            },
            |(max_b, cont, outs)| {
                let cfg = if *cont {
                    SchedulerConfig::continuous(*max_b)
                } else {
                    SchedulerConfig::hft(*max_b)
                };
                let mut s = Scheduler::new(cfg);
                for (i, &o) in outs.iter().enumerate() {
                    s.submit(req(i as u64, 0.0, o));
                }
                let mut guard = 0;
                let mut now = 1.0;
                while !s.is_idle() {
                    guard += 1;
                    if guard > 10_000 {
                        return Err("scheduler stuck".into());
                    }
                    now += 0.01;
                    match s.next_step(now) {
                        Step::Prefill { request_ids } => s.on_prefilled(&request_ids),
                        Step::Decode { request_ids } => s.on_decoded(&request_ids),
                        Step::Idle => {}
                    }
                }
                if s.completed() != outs.len() as u64 {
                    return Err(format!(
                        "completed {} != submitted {}",
                        s.completed(),
                        outs.len()
                    ));
                }
                Ok(())
            },
        );
    }
}
