//! The CoCoServe coordinator — the fleet control plane.
//!
//! Four responsibilities live here:
//!
//! * **Routing** ([`route`]): arrivals land at the coordinator, never at a
//!   fixed instance. A pluggable [`RoutePolicy`] (round-robin /
//!   least-outstanding / KV-headroom-aware) picks the serving instance;
//!   per-instance admission limits push back, parking overflow in a FIFO
//!   the kernel retries; requests shed by an instance's OOM handling can
//!   be re-routed instead of failed.
//! * **Fleet autoscaling** ([`fleet`]): a [`FleetController`] composes the
//!   per-instance module planners with instance lifecycle operations —
//!   spin-up with cold-start latency, drain-then-release — arbitrating
//!   module replication vs. whole-instance scaling by dry-run cost. The
//!   [`CostLedger`] meters device-seconds (a device bills while it holds
//!   any module), the denominator of the paper's 46 % cost-reduction
//!   claim (`benches/fig1_cost_availability.rs`).
//! * **Failure-domain accounting** ([`audit`]): when devices can die
//!   (spot preemption, hardware loss), every module op, failure,
//!   recovery decision, and rollback appends one structured record to
//!   the [`AuditLog`] — the append-only, byte-for-byte diffable trail
//!   the chaos harness (`benches/fig14_chaos.rs`) replays.
//! * **Real-path serving** ([`serve_trace`]): drives the [`TinyEngine`]
//!   with the [`Scheduler`]'s continuous-batching decisions against a
//!   wall-clock arrival process, recording completions in the
//!   [`Monitor`] — the end-to-end driver `examples/quickstart.rs` runs,
//!   with Python off the request path.
//!
//! Paper-scale path: [`crate::sim::Simulation`] executes the routing and
//! fleet decisions inside the deterministic event kernel (same
//! scheduler/autoscaler code over the cost-model substrate). Scaling
//! follows the plan/execute split everywhere: the [`crate::autoscale`]
//! planners emit [`crate::plan::ScalePlan`]s and every ledger/placement
//! mutation flows through [`crate::ops::PlanExecutor`] — so the fleet
//! controller can dry-run-cost a reconfiguration before committing to it.
//!
//! [`TinyEngine`]: crate::engine::TinyEngine
//! [`Scheduler`]: crate::scheduler::Scheduler
//! [`Monitor`]: crate::monitor::Monitor

pub mod audit;
pub mod fleet;
pub mod route;

pub use audit::{AuditKind, AuditLog, AuditRecord};
pub use fleet::{CostLedger, FleetConfig, FleetController, FleetEvent, FleetPhase};
pub use route::{RouteCandidate, RoutePolicy, Router, RouterConfig};

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{SeqState, TinyEngine};
use crate::monitor::{Completion, Monitor};
use crate::scheduler::{Scheduler, SchedulerConfig, Step};
use crate::workload::{synth_prompt_tokens, Trace};

/// Serving configuration for the real path.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Batching policy + batch bound for the scheduler.
    pub scheduler: SchedulerConfig,
    /// End-to-end latency SLO (seconds).
    pub slo_latency_s: f64,
    /// If true, wait for wall-clock arrival times (live serving); if
    /// false, arrivals are admitted as fast as the engine drains them
    /// (max-throughput replay).
    pub realtime: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            scheduler: SchedulerConfig::continuous(8),
            slo_latency_s: 2.0,
            realtime: true,
        }
    }
}

/// Outcome of a serve run.
pub struct ServeReport {
    /// Completion records + SLO accounting for the run.
    pub monitor: Monitor,
    /// Wall-clock duration of the run (seconds).
    pub duration_s: f64,
    /// PJRT executions performed (perf accounting).
    pub executions: u64,
    /// Total tokens generated.
    pub generated_tokens: usize,
    /// Completed request count.
    pub completed: usize,
}

impl ServeReport {
    /// Generated-token throughput over the run.
    pub fn tokens_per_s(&self) -> f64 {
        self.generated_tokens as f64 / self.duration_s.max(1e-9)
    }
}

/// Serve a trace end-to-end on the real engine.
///
/// Requests arrive per the trace's arrival times (wall-clock when
/// `cfg.realtime`); prompts are deterministic synthetic token ids; each
/// request generates its trace-specified number of tokens.
pub fn serve_trace(engine: &TinyEngine, trace: &Trace, cfg: ServeConfig) -> Result<ServeReport> {
    let mut sched = Scheduler::new(cfg.scheduler);
    let mut monitor = Monitor::new(cfg.slo_latency_s);
    let mut seqs: BTreeMap<u64, SeqState> = BTreeMap::new();
    let mut meta: BTreeMap<u64, (f64, usize, usize, crate::workload::SloClass)> = BTreeMap::new();
    let mut next_arrival = 0usize;
    let mut generated = 0usize;
    let start = Instant::now();

    let max_new = engine.max_seq.saturating_sub(1);

    loop {
        let now = start.elapsed().as_secs_f64();

        // admit arrivals whose time has come (or all, in replay mode)
        while next_arrival < trace.requests.len()
            && (!cfg.realtime || trace.requests[next_arrival].arrival_s <= now)
        {
            let r = &trace.requests[next_arrival];
            let prompt = synth_prompt_tokens(
                r.id,
                r.prompt_tokens.min(engine.max_seq / 2),
                engine.cfg.vocab_size,
            );
            let output = r.output_tokens.min(max_new);
            meta.insert(r.id, (r.arrival_s, prompt.len(), output, r.class));
            seqs.insert(r.id, engine.new_sequence(r.id, &prompt));
            sched.submit(crate::workload::Request {
                id: r.id,
                arrival_s: r.arrival_s,
                prompt_tokens: prompt.len(),
                output_tokens: output,
                class: r.class,
            });
            next_arrival += 1;
        }

        if sched.is_idle() && next_arrival >= trace.requests.len() {
            break;
        }

        match sched.next_step(now) {
            Step::Prefill { request_ids } => {
                let mut batch: Vec<&mut SeqState> = Vec::with_capacity(request_ids.len());
                // split_off-style double borrow dance: collect raw ptrs via
                // sequential remove+insert is costly; use unsafe-free
                // approach: take them out of the map, run, put back.
                let mut taken: Vec<SeqState> = request_ids
                    .iter()
                    .map(|id| seqs.remove(id).expect("sequence state"))
                    .collect();
                batch.extend(taken.iter_mut());
                let toks = engine.prefill(&mut batch)?;
                generated += toks.len();
                for s in taken {
                    seqs.insert(s.id, s);
                }
                sched.on_prefilled(&request_ids);
            }
            Step::Decode { request_ids } => {
                let mut taken: Vec<SeqState> = request_ids
                    .iter()
                    .map(|id| seqs.remove(id).expect("sequence state"))
                    .collect();
                let mut batch: Vec<&mut SeqState> = taken.iter_mut().collect();
                let toks = engine.decode(&mut batch)?;
                generated += toks.len();
                for s in taken {
                    seqs.insert(s.id, s);
                }
                sched.on_decoded(&request_ids);
            }
            Step::Idle => {
                if cfg.realtime && next_arrival < trace.requests.len() {
                    let wait = trace.requests[next_arrival].arrival_s - now;
                    if wait > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            wait.min(0.05),
                        ));
                    }
                }
            }
        }

        // record completions (sequences the scheduler dropped)
        let now = start.elapsed().as_secs_f64();
        let done: Vec<u64> = seqs
            .keys()
            .copied()
            .filter(|id| {
                let (_, _, out, _) = meta[id];
                seqs[id].tokens.len() >= meta[id].1 + out
            })
            .collect();
        for id in done {
            let (arrival, prompt, out, class) = meta[&id];
            seqs.remove(&id);
            monitor.record(Completion {
                request_id: id,
                arrival_s: arrival,
                finish_s: now,
                prompt_tokens: prompt,
                output_tokens: out,
                class,
            });
        }
    }

    Ok(ServeReport {
        duration_s: start.elapsed().as_secs_f64(),
        executions: engine.pjrt.executions(),
        generated_tokens: generated,
        completed: monitor.completions().len(),
        monitor,
    })
}
