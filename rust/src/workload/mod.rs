//! Workload generation: request arrivals, length distributions, traces.
//!
//! The paper (§6.1) drives all experiments with Alpaca-derived requests at
//! controlled request rates (RPS 3–50), max generation length 256, each
//! point repeated 5×. We reproduce that shape: Poisson arrivals at a target
//! RPS, prompt lengths drawn from an Alpaca-like lognormal (median ≈ 20
//! tokens, long tail), output lengths geometric-ish capped at
//! `max_new_tokens`. Traces are recordable/replayable so every bench is
//! seed-deterministic.
//!
//! [`scenarios`] packages the arrival shapes + length distributions into a
//! named scenario library (steady / diurnal / burst / ramp / two-tenant
//! mix) that the multi-instance benches sweep.

pub mod scenarios;

pub use scenarios::{DeviceFailure, FailureSchedule};

use crate::util::rng::Rng;

/// Service-level-objective class of a request: which tenant tier it
/// belongs to, and therefore how the control plane treats it under
/// contention. Fieldless and `Copy` so it rides inside [`Request`]
/// everywhere a request travels (trace merge, routing, shed re-routes)
/// at zero cost.
///
/// The default is [`SloClass::BestEffort`]: traces built by the legacy
/// constructors carry it uniformly, and with a classless
/// [`crate::coordinator::RoutePolicy`] the class is never consulted, so
/// every pre-existing golden stays byte-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum SloClass {
    /// Premium tier: holds a latency SLO. Class-aware policies route it
    /// first, may preempt best-effort batches for it, and the per-class
    /// capacity planner provisions against its demand first.
    LatencySensitive,
    /// Throughput tier: absorbs slack capacity, degrades gracefully
    /// under pressure (parked behind premium work, preemptible).
    #[default]
    BestEffort,
}

impl SloClass {
    /// Short stable label used in reports and bench tables.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::LatencySensitive => "latency-sensitive",
            SloClass::BestEffort => "best-effort",
        }
    }
}

/// One inference request. Plain-old-data and `Copy`: the event kernel
/// hands arrivals around by value straight out of the trace — no
/// per-arrival heap clone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Unique id within the trace (per-request state is keyed on it).
    pub id: u64,
    /// Arrival time in seconds from experiment start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Number of tokens the request will generate (ground truth; engines
    /// discover it by hitting EOS, the simulator uses it directly).
    pub output_tokens: usize,
    /// SLO class the request belongs to (defaults to best-effort; rides
    /// through [`Trace::merge`] and every re-route unchanged).
    pub class: SloClass,
}

/// Length distribution parameters (Alpaca-like defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LengthDist {
    /// Underlying-normal mu of the prompt lognormal.
    pub prompt_mu: f64,
    /// Underlying-normal sigma of the prompt lognormal.
    pub prompt_sigma: f64,
    /// Hard cap on sampled prompt lengths.
    pub max_prompt: usize,
    /// Mean output length (geometric), capped at `max_new_tokens` (§6.1: 256).
    pub mean_output: f64,
    /// Hard cap on sampled output lengths (the decoding cutoff).
    pub max_new_tokens: usize,
}

impl LengthDist {
    /// Alpaca-statistics defaults: median prompt ≈ 20 tokens with a long
    /// tail; outputs capped at 256 as in the paper's setup.
    pub fn alpaca() -> LengthDist {
        LengthDist {
            prompt_mu: 3.0, // e^3 ≈ 20 median
            prompt_sigma: 0.7,
            max_prompt: 512,
            mean_output: 64.0,
            max_new_tokens: 256,
        }
    }

    /// Tiny-model variant (prompts fit the 64-token prefill bucket).
    pub fn tiny() -> LengthDist {
        LengthDist {
            prompt_mu: 2.3, // median ≈ 10
            prompt_sigma: 0.5,
            max_prompt: 48,
            mean_output: 12.0,
            max_new_tokens: 32,
        }
    }

    /// Draw one prompt length (clamped to `[1, max_prompt]`).
    pub fn sample_prompt(&self, rng: &mut Rng) -> usize {
        (self.sample_raw_prompt(rng)).clamp(1, self.max_prompt)
    }

    fn sample_raw_prompt(&self, rng: &mut Rng) -> usize {
        rng.lognormal(self.prompt_mu, self.prompt_sigma).round() as usize
    }

    /// Draw one output length (geometric, clamped to `[1, max_new_tokens]`).
    pub fn sample_output(&self, rng: &mut Rng) -> usize {
        // Geometric with the given mean, capped (the cap concentrates mass
        // at max_new_tokens exactly like real decoding cutoffs).
        let p = 1.0 / self.mean_output;
        let u = rng.f64().max(f64::MIN_POSITIVE);
        let g = (u.ln() / (1.0 - p).ln()).ceil() as usize;
        g.clamp(1, self.max_new_tokens)
    }
}

/// Arrival process shapes used by the benches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Poisson process at a constant rate (requests/second).
    Poisson { rps: f64 },
    /// Constant-rate ramp from `from` to `to` RPS over the duration
    /// (the "unpredictable traffic" scenario motivating auto-scaling).
    Ramp { from: f64, to: f64 },
    /// Baseline load plus a burst window at `burst` RPS (Fig. 11 stress).
    Burst { base: f64, burst: f64, start_s: f64, end_s: f64 },
    /// Sinusoidal day/night cycle: rate = mean · (1 + amplitude·sin(2πt/T)).
    /// `amplitude` ∈ [0, 1]; the MorphServe/FlexPipe-style slowly-varying
    /// traffic the scale-up/down loop must track.
    Diurnal { mean: f64, amplitude: f64, period_s: f64 },
}

impl Arrival {
    fn rate_at(&self, t: f64, duration: f64) -> f64 {
        match *self {
            Arrival::Poisson { rps } => rps,
            Arrival::Ramp { from, to } => {
                from + (to - from) * (t / duration).clamp(0.0, 1.0)
            }
            Arrival::Burst { base, burst, start_s, end_s } => {
                if (start_s..end_s).contains(&t) { burst } else { base }
            }
            Arrival::Diurnal { mean, amplitude, period_s } => {
                let phase = std::f64::consts::TAU * t / period_s.max(1e-9);
                (mean * (1.0 + amplitude.clamp(0.0, 1.0) * phase.sin())).max(0.0)
            }
        }
    }
}

/// A reproducible request trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// The requests, ascending by arrival time.
    pub requests: Vec<Request>,
}

impl Trace {
    /// Generate a trace of `duration_s` seconds.
    pub fn generate(
        arrival: Arrival,
        lengths: LengthDist,
        duration_s: f64,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut reqs = Vec::new();
        let mut id = 0;
        loop {
            // Thinning-free approach: step by exponential at the local rate.
            let rate = arrival.rate_at(t, duration_s).max(1e-9);
            t += rng.exponential(rate);
            if t >= duration_s {
                break;
            }
            reqs.push(Request {
                id,
                arrival_s: t,
                prompt_tokens: lengths.sample_prompt(&mut rng),
                output_tokens: lengths.sample_output(&mut rng),
                class: SloClass::default(),
            });
            id += 1;
        }
        Trace { requests: reqs }
    }

    /// Number of requests in the trace.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Does the trace contain no requests?
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Empirical arrival rate over the trace window.
    pub fn mean_rps(&self, duration_s: f64) -> f64 {
        self.requests.len() as f64 / duration_s
    }

    /// Total tokens (prompt + output) — the throughput denominator.
    pub fn total_tokens(&self) -> usize {
        self.requests
            .iter()
            .map(|r| r.prompt_tokens + r.output_tokens)
            .sum()
    }

    /// Tag every request in the trace with `class` (builder-style: the
    /// classed two-tenant scenario tags each tenant's sub-trace before
    /// merging, and the class then rides through [`Trace::merge`]'s id
    /// reassignment untouched).
    pub fn with_class(mut self, class: SloClass) -> Trace {
        for r in &mut self.requests {
            r.class = class;
        }
        self
    }

    /// Requests carrying the given SLO class.
    pub fn count_class(&self, class: SloClass) -> usize {
        self.requests.iter().filter(|r| r.class == class).count()
    }

    /// Merge traces into one, sorted by arrival time with ids reassigned
    /// sequentially (ids must be unique within a trace — the serving path
    /// keys per-request state on them). Ties break by input order, so the
    /// merge is deterministic.
    pub fn merge(parts: Vec<Trace>) -> Trace {
        let mut all: Vec<Request> = parts.into_iter().flat_map(|t| t.requests).collect();
        all.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        for (i, r) in all.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Trace { requests: all }
    }
}

/// Deterministic synthetic token ids for the real-path engine: requests
/// need actual token sequences for the tiny model. Hash-derived from the
/// request id so traces stay reproducible without storing token arrays.
pub fn synth_prompt_tokens(req_id: u64, len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(0x5EED ^ req_id.wrapping_mul(0x9E3779B97F4A7C15));
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_rate_matches() {
        let t = Trace::generate(
            Arrival::Poisson { rps: 20.0 },
            LengthDist::alpaca(),
            100.0,
            1,
        );
        let rps = t.mean_rps(100.0);
        assert!((rps - 20.0).abs() < 2.0, "rps {rps}");
        // arrivals strictly increasing
        for w in t.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let a = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::alpaca(), 10.0, 7);
        let b = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::alpaca(), 10.0, 7);
        assert_eq!(a.requests, b.requests);
        let c = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::alpaca(), 10.0, 8);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn lengths_within_bounds() {
        let d = LengthDist::alpaca();
        let mut rng = Rng::new(3);
        for _ in 0..5000 {
            let p = d.sample_prompt(&mut rng);
            let o = d.sample_output(&mut rng);
            assert!((1..=d.max_prompt).contains(&p));
            assert!((1..=d.max_new_tokens).contains(&o));
        }
    }

    #[test]
    fn prompt_median_about_20() {
        let d = LengthDist::alpaca();
        let mut rng = Rng::new(4);
        let mut v: Vec<usize> = (0..20000).map(|_| d.sample_prompt(&mut rng)).collect();
        v.sort_unstable();
        let med = v[v.len() / 2];
        assert!((15..=26).contains(&med), "median {med}");
    }

    #[test]
    fn output_mean_close_to_target() {
        let d = LengthDist::alpaca();
        let mut rng = Rng::new(5);
        let n = 20000;
        let s: usize = (0..n).map(|_| d.sample_output(&mut rng)).sum();
        let mean = s as f64 / n as f64;
        // cap at 256 pulls the mean slightly below 64
        assert!((50.0..70.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn ramp_rate_increases() {
        let t = Trace::generate(
            Arrival::Ramp { from: 2.0, to: 40.0 },
            LengthDist::alpaca(),
            100.0,
            6,
        );
        let first_half = t.requests.iter().filter(|r| r.arrival_s < 50.0).count();
        let second_half = t.len() - first_half;
        assert!(second_half > 2 * first_half,
                "{first_half} vs {second_half}");
    }

    #[test]
    fn burst_concentrates_arrivals() {
        let t = Trace::generate(
            Arrival::Burst { base: 2.0, burst: 50.0, start_s: 40.0, end_s: 60.0 },
            LengthDist::alpaca(),
            100.0,
            9,
        );
        let in_burst = t.requests.iter()
            .filter(|r| (40.0..60.0).contains(&r.arrival_s))
            .count();
        assert!(in_burst as f64 > 0.6 * t.len() as f64);
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let t = Trace::generate(
            Arrival::Diurnal { mean: 20.0, amplitude: 0.8, period_s: 100.0 },
            LengthDist::alpaca(),
            100.0,
            12,
        );
        // first half-period is the crest, second the trough
        let crest = t.requests.iter().filter(|r| r.arrival_s < 50.0).count();
        let trough = t.len() - crest;
        assert!(crest > 2 * trough, "{crest} vs {trough}");
        // overall mean stays near the configured mean rate
        let rps = t.mean_rps(100.0);
        assert!((rps - 20.0).abs() < 4.0, "rps {rps}");
    }

    #[test]
    fn merge_sorts_and_reassigns_ids() {
        let a = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::alpaca(), 10.0, 1);
        let b = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::tiny(), 10.0, 2);
        let n = a.len() + b.len();
        let m = Trace::merge(vec![a, b]);
        assert_eq!(m.len(), n);
        for (i, w) in m.requests.windows(2).enumerate() {
            assert!(w[1].arrival_s >= w[0].arrival_s, "unsorted at {i}");
        }
        let ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    }

    #[test]
    fn merge_preserves_slo_classes() {
        let a = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::alpaca(), 10.0, 1)
            .with_class(SloClass::LatencySensitive);
        let b = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                LengthDist::tiny(), 10.0, 2);
        let (na, nb) = (a.len(), b.len());
        let m = Trace::merge(vec![a, b]);
        assert_eq!(m.count_class(SloClass::LatencySensitive), na);
        assert_eq!(m.count_class(SloClass::BestEffort), nb);
        // classless constructors default every request to best-effort
        let plain = Trace::generate(Arrival::Poisson { rps: 5.0 },
                                    LengthDist::alpaca(), 10.0, 3);
        assert!(plain.requests.iter().all(|r| r.class == SloClass::BestEffort));
    }

    #[test]
    fn synth_tokens_deterministic_and_in_vocab() {
        let a = synth_prompt_tokens(42, 16, 512);
        let b = synth_prompt_tokens(42, 16, 512);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
        assert_ne!(a, synth_prompt_tokens(43, 16, 512));
    }
}
