"""Model configurations for the CoCoServe compile path.

Two families:

- ``TINY_*``: small LLaMA-style configs that are actually lowered to HLO and
  executed on the CPU PJRT client from the Rust coordinator (the "real path").
- ``PAPER_*``: the LLaMA2-13B / LLaMA2-70B architectural constants from the
  paper (§2.1, §3.3). These are never lowered — they parameterize the Rust
  cost model and the discrete-event simulator — but we keep them here as the
  single source of truth shared (via the artifact manifest) with Rust, and the
  pytest suite asserts the paper's Table 1 numbers from them.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Architectural description of a LLaMA-style decoder-only model."""

    name: str
    vocab_size: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # ---- parameter counts (per the paper's §3.3 accounting) ----------------

    @property
    def attn_params(self) -> int:
        """Q/K/V/O projections: 4 * d_model^2."""
        return 4 * self.d_model * self.d_model

    @property
    def proj_params(self) -> int:
        """A single attention projection (one of Q/K/V/O): d_model^2."""
        return self.d_model * self.d_model

    @property
    def ffn_params(self) -> int:
        """SwiGLU FFN: gate + up (d*ff each) + down (ff*d)."""
        return 3 * self.d_model * self.d_ff

    @property
    def norm_params(self) -> int:
        """Two RMSNorm weight vectors per decoder layer."""
        return 2 * self.d_model

    @property
    def layer_params(self) -> int:
        return self.attn_params + self.ffn_params + self.norm_params

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


# The config that `make artifacts` lowers by default. Small enough that the
# interpret-mode Pallas kernels run in milliseconds on CPU, large enough that
# every module has non-trivial shape structure (multiple heads, SwiGLU ratio).
TINY = ModelConfig(
    name="tiny-llama",
    vocab_size=512,
    d_model=64,
    n_heads=4,
    n_layers=4,
    d_ff=172,
)

# A slightly bigger config used by the wider end-to-end example to show the
# stack is not shape-special-cased.
SMALL = ModelConfig(
    name="small-llama",
    vocab_size=2048,
    d_model=128,
    n_heads=8,
    n_layers=8,
    d_ff=344,
)

# Paper-scale references (LLaMA2-13B / LLaMA2-70B, §2.1 + §3.3). 13B:
# d_model=5120, d_ff=13824, 40 decoder layers. 70B: d_model=8192, d_ff=28672,
# 80 layers (GQA ignored by the paper's arithmetic; we follow the paper).
PAPER_13B = ModelConfig(
    name="llama2-13b",
    vocab_size=32000,
    d_model=5120,
    n_heads=40,
    n_layers=40,
    d_ff=13824,
)

PAPER_70B = ModelConfig(
    name="llama2-70b",
    vocab_size=32000,
    d_model=8192,
    n_heads=64,
    n_layers=80,
    d_ff=28672,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, PAPER_13B, PAPER_70B)}

# Static shape buckets compiled into artifacts. PJRT executables have fixed
# shapes, so the Rust scheduler pads each batch to the nearest bucket.
BATCH_BUCKETS = (1, 2, 4, 8)
PREFILL_SEQ_BUCKETS = (16, 32, 64)
MAX_SEQ_LEN = 128  # KV-cache capacity baked into decode artifacts
