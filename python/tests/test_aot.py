"""AOT export path: HLO-text interchange, weight dumps, manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model


class TestHloText:
    def test_simple_fn_lowers_to_hlo_text(self):
        def fn(x, y):
            return (x @ y + 2.0,)
        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert text.startswith("HloModule")
        assert "dot" in text

    def test_pallas_module_lowers_to_plain_hlo(self):
        """interpret=True Pallas must lower to ops a CPU PJRT can run —
        no mosaic/custom-call in the text."""
        import functools
        cfg = configs.TINY
        w = aot._weight_specs(cfg)
        lowered = jax.jit(
            functools.partial(model.layer_prefill, n_heads=cfg.n_heads)
        ).lower(aot._spec((1, 16, cfg.d_model)),
                aot._spec((1, 16), jnp.int32), *w)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "custom-call" not in text.lower()

    def test_tuple_return_convention(self):
        """All artifacts are lowered return_tuple=True: root is a tuple even
        for single outputs (the Rust side always unwraps a tuple)."""
        def fn(x):
            return (x * 2.0,)
        spec = jax.ShapeDtypeStruct((4,), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        root = [l for l in text.splitlines() if "ROOT" in l]
        assert root and "tuple" in root[0]


class TestWeightDump:
    def test_roundtrip(self, tmp_path):
        cfg = configs.TINY
        index = aot.dump_weights(str(tmp_path), cfg, seed=0)
        weights = model.init_weights(cfg, seed=0)
        # every layer tensor present, bytes identical
        entry = index["layer0.wq"]
        raw = np.fromfile(os.path.join(tmp_path, entry["path"]),
                          dtype=np.float32)
        want = np.asarray(weights["layers"][0]["wq"]).ravel()
        np.testing.assert_array_equal(raw, want)
        assert entry["shape"] == [cfg.d_model, cfg.d_model]

    def test_index_complete(self, tmp_path):
        cfg = configs.TINY
        index = aot.dump_weights(str(tmp_path), cfg, seed=0)
        expect = {f"layer{i}.{n}" for i in range(cfg.n_layers)
                  for n in model.LAYER_WEIGHT_NAMES}
        expect |= {"emb", "w_out", "rms_f"}
        assert set(index) == expect

    def test_seed_determinism(self, tmp_path):
        cfg = configs.TINY
        a = aot.dump_weights(str(tmp_path / "a"), cfg, seed=1)
        b = aot.dump_weights(str(tmp_path / "b"), cfg, seed=1)
        ra = np.fromfile(os.path.join(tmp_path, "a", a["emb"]["path"]),
                         dtype=np.float32)
        rb = np.fromfile(os.path.join(tmp_path, "b", b["emb"]["path"]),
                         dtype=np.float32)
        np.testing.assert_array_equal(ra, rb)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "..", "..", "artifacts",
                                    "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
class TestBuiltManifest:
    """Validates the artifacts/ tree the Rust runtime will consume."""

    @pytest.fixture(scope="class")
    def manifest(self):
        root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        with open(os.path.join(root, "manifest.json")) as f:
            return os.path.abspath(root), json.load(f)

    def test_schema(self, manifest):
        _, m = manifest
        assert m["format"] == 1
        assert m["interchange"] == "hlo-text"
        assert "tiny-llama" in m["configs"]
        assert "llama2-13b" in m["configs"]  # cost-model configs ride along
        assert m["configs"]["llama2-13b"]["d_model"] == 5120

    def test_every_artifact_file_exists_and_parses(self, manifest):
        root, m = manifest
        assert len(m["artifacts"]) > 0
        for e in m["artifacts"]:
            p = os.path.join(root, e["path"])
            assert os.path.exists(p), e["name"]
            with open(p) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), e["name"]

    def test_decode_artifacts_for_every_batch_bucket(self, manifest):
        _, m = manifest
        decode = {(e["module"], e["batch"]) for e in m["artifacts"]
                  if e["phase"] == "decode" and e["config"] == "tiny-llama"}
        for b in m["batch_buckets"]:
            assert ("decoder_layer", b) in decode
            assert ("lm_head", b) in decode

    def test_weight_files_match_declared_shapes(self, manifest):
        root, m = manifest
        idx = m["weights"]["tiny-llama"]
        for name, e in idx.items():
            p = os.path.join(root, e["path"])
            n = int(np.prod(e["shape"]))
            assert os.path.getsize(p) == 4 * n, name

    def test_arg_convention_layer_decode(self, manifest):
        """Rust hardcodes the arg order (hidden, kc, vc, lens, 9 weights)."""
        _, m = manifest
        cfg = m["configs"]["tiny-llama"]
        e = next(e for e in m["artifacts"]
                 if e["name"] == "tiny-llama__layer_decode__b2")
        shapes = [tuple(a["shape"]) for a in e["args"]]
        d, h, hd = cfg["d_model"], cfg["n_heads"], cfg["head_dim"]
        S = m["max_seq_len"]
        assert shapes[0] == (2, 1, d)
        assert shapes[1] == shapes[2] == (2, h, S, hd)
        assert shapes[3] == (2,)
        assert len(shapes) == 4 + 9
