//! Module-operation costing and the **plan executor** (§3.1).
//!
//! Replicate / migrate / evict are the paper's primitive operators. Since
//! the plan/execute redesign, *all* ledger + placement mutation flows
//! through [`PlanExecutor`] (atomic, two-phase) or [`PlanExecution`]
//! (stepwise, used by the simulator's in-flight path):
//!
//! * planners ([`crate::autoscale`]) build a [`crate::plan::ScalePlan`]
//!   without touching any state,
//! * [`crate::plan::ScalePlan::dry_run`] prices it — identical code path,
//!   shadow state — so dry-run cost equals executed cost exactly,
//! * the executor applies it with full rollback: a mid-plan failure leaves
//!   cluster allocations and placement byte-identical to the pre-plan
//!   state.
//!
//! ### Cost model (reproduces Table 2)
//!
//! The paper measures replication of *n* decoder layers of LLaMA-13B at
//! 0.2987 s (n=1) → 0.8938 s (n=40) with memory 1107 MB → 24819 MB, and
//! migration ≈ 45 ms cheaper (no new dataflow hooks to install). We model
//!
//! ```text
//! memory(n) = OVERHEAD + n · (layer_bytes + ACT_BUFFER)       (linear — exact)
//! time(n)   = LAUNCH + n · layer_bytes / (link_bw · (1 − mem_frac_dst))
//! ```
//!
//! The `(1 − mem_frac)` term models transfer slowdown as the target device
//! fills (pinned-buffer contention) — it reproduces the paper's superlinear
//! time growth at n→40 while staying principled (bytes / effective
//! bandwidth). The launch cost is paid once per consecutive run of
//! same-kind, same-destination ops in a plan — the Table 2 batch shape.
//! Post-scaling inter-replica communication setup is the paper's measured
//! 39.1 ms constant.

use crate::cluster::{Cluster, Ledger, LedgerView};
use crate::model::cost::{CostModel, Shape, MIB};
use crate::model::{ModuleId, ModuleKind};
use crate::placement::Placement;
use crate::plan::{ModuleOp, PlanCost, PlanError, ScalePlan};

/// Fixed launch/bookkeeping latency of a replication (hook installation,
/// allocator setup). Calibrated to Table 2's n=1 row.
pub const REPLICATION_LAUNCH_S: f64 = 0.292;
/// Migration launches faster: the source's hooks are reused (§3.1).
pub const MIGRATION_LAUNCH_S: f64 = 0.242;
/// Replica eviction is metadata + a free — near-instant.
pub const EVICT_TIME_S: f64 = 0.002;
/// Fixed runtime overhead added once per operation batch (CUDA context,
/// staging buffers) — Table 2's memory intercept.
pub const OP_OVERHEAD_BYTES: f64 = 499.0 * MIB;
/// Per-layer activation/workspace buffer beyond the weights (Table 2's
/// 608 MiB/layer step vs the 605 MiB weight size).
pub const ACT_BUFFER_BYTES: f64 = 3.0 * MIB;
/// Post-scaling inter-replica communication setup (§6.5: 39.1 ms).
pub const REPLICA_COMM_SETUP_S: f64 = 0.0391;
/// Effective on-device bandwidth of the precision-swap rewrite kernel
/// (streams the layer's weights once at the source width and once at the
/// destination width through HBM — roughly a third of the A100's 1.55 TB/s
/// peak for a fused quantize/dequantize pass). Makes a 13B-layer int8 swap
/// ~1.6 ms: two orders of magnitude cheaper than a migration launch, which
/// is what lets the memory-pressure governor prefer swaps over sheds.
pub const SWAP_REWRITE_BYTES_PER_S: f64 = 600.0e9;

/// Cost of one executed operation.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OpCost {
    /// Wall-clock time the op occupies the transfer engine.
    pub time_s: f64,
    /// Bytes streamed over the interconnect (or through HBM for swaps).
    pub bytes_moved: f64,
    /// Memory newly resident on the destination device.
    pub dst_bytes: f64,
}

impl OpCost {
    /// Sum two costs component-wise (batch accounting).
    pub fn merge(self, other: OpCost) -> OpCost {
        OpCost {
            time_s: self.time_s + other.time_s,
            bytes_moved: self.bytes_moved + other.bytes_moved,
            dst_bytes: self.dst_bytes + other.dst_bytes,
        }
    }
}

/// Why a single module op was refused (the op itself left no trace).
#[derive(Debug)]
pub enum OpError {
    /// The destination device could not hold the copy — includes
    /// [`crate::cluster::AllocError::DeviceFailed`] when the destination
    /// died mid-plan.
    DestinationOom(crate::cluster::AllocError),
    /// `(layer, device)`: the copy already exists there.
    AlreadyResident(usize, usize),
    /// `(layer, device)`: asked to evict/swap a copy that isn't there.
    NoSuchReplica(usize, usize),
}

impl std::fmt::Display for OpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpError::DestinationOom(e) => write!(f, "destination OOM: {e}"),
            OpError::AlreadyResident(l, d) => {
                write!(f, "layer {l} already resident on device {d}")
            }
            OpError::NoSuchReplica(l, d) => {
                write!(f, "no replica of layer {l} on device {d}")
            }
        }
    }
}

impl std::error::Error for OpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OpError::DestinationOom(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::cluster::AllocError> for OpError {
    fn from(e: crate::cluster::AllocError) -> OpError {
        OpError::DestinationOom(e)
    }
}

/// Costing + tagging context for module operations: the cost model, the
/// serving precision, and the instance's ledger tag prefix. Pure — every
/// mutation happens through [`PlanExecutor`] / [`PlanExecution`].
pub struct ModuleOps<'a> {
    /// Analytic cost model the op costs are derived from.
    pub cost_model: &'a CostModel,
    /// Precision of resident weights (2 = bf16 at paper scale, 4 = f32 tiny).
    pub dtype_bytes: usize,
    /// Tag prefix for ledger entries, e.g. "inst0".
    pub tag_prefix: String,
}

impl<'a> ModuleOps<'a> {
    /// Costing context for one instance's ops at the given serving precision.
    pub fn new(cost_model: &'a CostModel, dtype_bytes: usize, tag_prefix: &str) -> Self {
        ModuleOps { cost_model, dtype_bytes, tag_prefix: tag_prefix.into() }
    }

    fn shape(&self) -> Shape {
        Shape { batch: 1, seq: 1, dtype_bytes: self.dtype_bytes }
    }

    /// Resident bytes of a module copy (weights + activation workspace).
    pub fn module_bytes(&self, kind: ModuleKind) -> f64 {
        self.cost_model.weight_bytes(kind, self.shape())
            + if kind == ModuleKind::DecoderLayer { ACT_BUFFER_BYTES } else { 0.0 }
    }

    /// Ledger tag for a module copy on a device.
    pub fn tag(&self, m: &ModuleId, device: usize) -> String {
        format!("{}/{}@{}", self.tag_prefix, m, device)
    }

    /// Resident-byte delta of swapping one decoder layer's weights from
    /// `from`- to `to`-byte elements (negative when quantizing). Only the
    /// weights scale with precision; the activation workspace does not.
    pub fn swap_delta_bytes(&self, from: usize, to: usize) -> f64 {
        let w = |b: usize| {
            self.cost_model
                .weight_bytes(ModuleKind::DecoderLayer, Shape { batch: 1, seq: 1, dtype_bytes: b })
        };
        w(to) - w(from)
    }

    /// Deploy an instance's weights onto the placement's primary devices:
    /// one tagged allocation per decoder layer plus embed + lm_head on the
    /// first layer's device. Charges no time (deployment happens before
    /// serving); the per-module tags are what later migrations move.
    pub fn deploy_instance(
        &self,
        cluster: &mut Cluster,
        placement: &Placement,
    ) -> Result<f64, OpError> {
        let mut total = 0.0;
        for l in 0..placement.n_layers {
            let m = ModuleId::layer(ModuleKind::DecoderLayer, l);
            let d = placement.primary_device(l);
            let bytes = self.module_bytes(ModuleKind::DecoderLayer);
            cluster.device_mut(d).alloc(&self.tag(&m, d), bytes)?;
            total += bytes;
        }
        for kind in [ModuleKind::Embed, ModuleKind::LmHead] {
            let m = ModuleId::global(kind);
            let d = placement.primary_device(0);
            let bytes = self.module_bytes(kind);
            cluster.device_mut(d).alloc(&self.tag(&m, d), bytes)?;
            total += bytes;
        }
        Ok(total)
    }

    /// Transfer time for `bytes` into `dst`, with fill-contention slowdown.
    /// Generic over the ledger view so live execution and shadow planning
    /// observe the destination's fill through the same arithmetic.
    pub fn transfer_time<L: LedgerView + ?Sized>(
        &self,
        ledger: &L,
        src: usize,
        dst: usize,
        bytes: f64,
    ) -> f64 {
        let bw = ledger.link_bw(src, dst);
        let slow = (1.0 - ledger.mem_frac(dst)).max(0.25);
        bytes / (bw * slow)
    }

    /// Table 2 analytic costs for an n-layer operation onto a device at
    /// `dst_mem_frac` fill — used by the bench and by planning (the
    /// controller consults this before executing).
    pub fn table2_cost(&self, n_layers: usize, link_bw: f64, dst_mem_frac: f64,
                       migration: bool) -> (f64, f64) {
        let layer_bytes = self.module_bytes(ModuleKind::DecoderLayer);
        let launch = if migration { MIGRATION_LAUNCH_S } else { REPLICATION_LAUNCH_S };
        let slow = (1.0 - dst_mem_frac).max(0.25);
        let time = launch + n_layers as f64 * layer_bytes / (link_bw * slow);
        let mem = OP_OVERHEAD_BYTES + n_layers as f64 * layer_bytes;
        (time, mem)
    }
}

// ---- plan execution --------------------------------------------------------

/// Launch-amortization classes (replication vs migration hooks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaunchKind {
    Replicate,
    Migrate,
}

/// One reversible effect recorded while applying a plan.
#[derive(Debug, Clone)]
enum UndoEntry {
    /// A ledger tag's size before the op touched it.
    Ledger { device: usize, tag: String, prev_bytes: f64 },
    AddedReplica { layer: usize, device: usize },
    MovedPrimary { layer: usize, from: usize },
    MovedModule { module: ModuleId, prev: Option<usize> },
    RemovedReplica { layer: usize, device: usize },
}

/// Stepwise execution state of one plan: the undo log, the accumulated
/// [`PlanCost`], and the launch-amortization cursor.
///
/// [`ScalePlan::dry_run`] drives one of these over shadow state; the
/// simulator drives one op-at-a-time as `OpCompleted` events fire (so
/// scaling overlaps serving); [`PlanExecutor::execute`] drives one to
/// completion atomically. All three therefore price ops identically.
#[derive(Debug, Default)]
pub struct PlanExecution {
    undo: Vec<UndoEntry>,
    /// Source allocations to release at [`PlanExecution::commit`], as
    /// (device, tag, bytes-at-apply-time). Migration is copy-then-free:
    /// the source copy stays resident (and serving) until the whole plan
    /// lands, so rollback never has to re-acquire memory another actor
    /// may have claimed meanwhile. The recorded *amount* is subtracted at
    /// commit — a later op in the same plan may legitimately re-allocate
    /// under the same tag (evict-then-replicate, migrate-back), and its
    /// bytes must survive the commit.
    pending_frees: Vec<(usize, String, f64)>,
    cost: PlanCost,
    last_launch: Option<(LaunchKind, usize)>,
    applied: usize,
    eager_frees: bool,
}

impl PlanExecution {
    /// Fresh two-phase execution: frees deferred to commit, rollback-safe.
    pub fn new() -> PlanExecution {
        PlanExecution::default()
    }

    /// Planner mode: frees apply immediately so a shadow search observes
    /// the relief an op buys (Algorithm 2's violation predicate). Not
    /// rollback-safe — planners discard their shadows instead.
    pub fn eager() -> PlanExecution {
        PlanExecution { eager_frees: true, ..PlanExecution::default() }
    }

    /// Release the current allocation under `tag` now (eager/planner
    /// mode) or at commit (two-phase mode). Returns the bytes released.
    fn release<L: Ledger + ?Sized>(&mut self, ledger: &mut L, device: usize, tag: String) -> f64 {
        let bytes = ledger.alloc_bytes(device, &tag);
        if self.eager_frees {
            let _ = ledger.free(device, &tag);
        } else if bytes > 0.0 {
            self.pending_frees.push((device, tag, bytes));
        }
        bytes
    }

    /// Commit the plan: release every deferred source allocation and
    /// return the accumulated cost. Call after the last op applied.
    /// Frees subtract the amount recorded at apply time, never the whole
    /// tag — bytes a later op re-allocated under the same tag survive.
    pub fn commit<L: Ledger + ?Sized>(mut self, ledger: &mut L) -> PlanCost {
        for (device, tag, bytes) in self.pending_frees.drain(..) {
            let remaining = (ledger.alloc_bytes(device, &tag) - bytes).max(0.0);
            let _ = ledger.resize(device, &tag, remaining);
        }
        self.cost
    }

    /// Ops applied so far.
    pub fn applied(&self) -> usize {
        self.applied
    }

    /// Cost accumulated so far.
    pub fn cost(&self) -> &PlanCost {
        &self.cost
    }

    /// Consume the execution, keeping only its accumulated cost (for
    /// callers that neither commit nor roll back, e.g. shadow pricing).
    pub fn into_cost(self) -> PlanCost {
        self.cost
    }

    /// Launch cost for this op: paid once per consecutive run of same-kind
    /// ops to the same destination (Table 2 batch amortization). Pure —
    /// the cursor advances via [`PlanExecution::note_launch`] only after
    /// the op's fallible part succeeded, so a failed op leaves no trace.
    fn launch_cost(&self, kind: LaunchKind, dst: usize) -> f64 {
        if self.last_launch == Some((kind, dst)) {
            return 0.0;
        }
        match kind {
            LaunchKind::Replicate => REPLICATION_LAUNCH_S,
            LaunchKind::Migrate => MIGRATION_LAUNCH_S,
        }
    }

    fn note_launch(&mut self, kind: LaunchKind, dst: usize) {
        self.last_launch = Some((kind, dst));
    }

    /// Apply one op against a ledger (live [`Cluster`] or a planner's
    /// [`crate::cluster::ShadowLedger`]), recording its inverse. On `Err`
    /// the op itself left no trace; previously applied ops stay applied
    /// (call [`PlanExecution::rollback`] to unwind them).
    pub fn apply_next<L: Ledger + ?Sized>(
        &mut self,
        ops: &ModuleOps<'_>,
        ledger: &mut L,
        placement: &mut Placement,
        op: &ModuleOp,
    ) -> Result<OpCost, OpError> {
        let cost = match *op {
            ModuleOp::Replicate { layer, dst } => {
                if placement.holds(layer, dst) {
                    return Err(OpError::AlreadyResident(layer, dst));
                }
                let src = placement.primary_device(layer);
                let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
                let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
                let time = self.launch_cost(LaunchKind::Replicate, dst)
                    + ops.transfer_time(ledger, src, dst, bytes);
                let tag = ops.tag(&m, dst);
                let prev_bytes = ledger.alloc_bytes(dst, &tag);
                ledger.alloc(dst, &tag, bytes)?;
                self.note_launch(LaunchKind::Replicate, dst);
                self.undo.push(UndoEntry::Ledger { device: dst, tag, prev_bytes });
                placement.add_replica(layer, dst);
                self.undo.push(UndoEntry::AddedReplica { layer, device: dst });
                OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes }
            }
            ModuleOp::MigrateLayer { layer, dst } => {
                let src = placement.primary_device(layer);
                if src == dst || placement.holds(layer, dst) {
                    return Err(OpError::AlreadyResident(layer, dst));
                }
                let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
                let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
                let time = self.launch_cost(LaunchKind::Migrate, dst)
                    + ops.transfer_time(ledger, src, dst, bytes);
                let dst_tag = ops.tag(&m, dst);
                let prev_bytes = ledger.alloc_bytes(dst, &dst_tag);
                ledger.alloc(dst, &dst_tag, bytes)?;
                self.note_launch(LaunchKind::Migrate, dst);
                self.undo.push(UndoEntry::Ledger { device: dst, tag: dst_tag, prev_bytes });
                // Copy-then-free: the source copy is released only when
                // the plan commits (migration must never lose the module,
                // and rollback must never need to re-acquire memory).
                self.release(ledger, src, ops.tag(&m, src));
                placement.migrate_layer(layer, dst);
                self.undo.push(UndoEntry::MovedPrimary { layer, from: src });
                OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes }
            }
            ModuleOp::MigrateModule { module, dst, payload_bytes } => {
                let src = placement.module_device(module);
                if src == dst {
                    return Err(OpError::AlreadyResident(module.layer.unwrap_or(0), dst));
                }
                let bytes = ops.module_bytes(module.kind) + payload_bytes;
                let time = self.launch_cost(LaunchKind::Migrate, dst)
                    + ops.transfer_time(ledger, src, dst, bytes);
                let dst_tag = ops.tag(&module, dst);
                let prev_bytes = ledger.alloc_bytes(dst, &dst_tag);
                ledger.alloc(dst, &dst_tag, bytes)?;
                self.note_launch(LaunchKind::Migrate, dst);
                self.undo.push(UndoEntry::Ledger { device: dst, tag: dst_tag, prev_bytes });
                self.release(ledger, src, ops.tag(&module, src));
                let prev = placement.module_override(module);
                placement.migrate_module(module, dst);
                self.undo.push(UndoEntry::MovedModule { module, prev });
                OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes }
            }
            ModuleOp::Evict { layer, device } => {
                if !placement.remove_replica(layer, device) {
                    return Err(OpError::NoSuchReplica(layer, device));
                }
                self.undo.push(UndoEntry::RemovedReplica { layer, device });
                let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
                let freed = self.release(ledger, device, ops.tag(&m, device));
                // an eviction breaks a transfer batch: the next transfer
                // pays its launch again
                self.last_launch = None;
                OpCost { time_s: EVICT_TIME_S, bytes_moved: 0.0, dst_bytes: -freed }
            }
            ModuleOp::SwapPrecision { layer, device, from, to } => {
                if !placement.holds(layer, device) {
                    return Err(OpError::NoSuchReplica(layer, device));
                }
                let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
                let tag = ops.tag(&m, device);
                let prev_bytes = ledger.alloc_bytes(device, &tag);
                let delta = ops.swap_delta_bytes(from, to);
                // In-place resize: a shrink lands immediately (the rewrite
                // frees the high-precision copy as it streams), a grow
                // OOM-checks like any allocation.
                ledger.resize(device, &tag, (prev_bytes + delta).max(0.0))?;
                self.undo.push(UndoEntry::Ledger { device, tag, prev_bytes });
                // The rewrite streams the weights once at each width
                // through HBM — no inter-device transfer, no launch
                // amortization class; it does break a transfer batch
                // (different engine), so the next transfer pays its launch.
                let w = |b: usize| {
                    ops.cost_model.weight_bytes(
                        ModuleKind::DecoderLayer,
                        Shape { batch: 1, seq: 1, dtype_bytes: b },
                    )
                };
                let rewritten = w(from) + w(to);
                self.last_launch = None;
                OpCost {
                    time_s: rewritten / SWAP_REWRITE_BYTES_PER_S,
                    bytes_moved: rewritten,
                    dst_bytes: delta,
                }
            }
        };
        self.applied += 1;
        self.cost.push(cost);
        Ok(cost)
    }

    /// Unwind every applied op, newest first, restoring the exact ledger
    /// sizes and placement entries recorded before each effect. Source
    /// frees were deferred to commit, so rollback only ever *releases*
    /// destination allocations — it cannot fail; placement inverses
    /// tolerate entries a concurrent actor already reverted.
    pub fn rollback<L: Ledger + ?Sized>(mut self, ledger: &mut L, placement: &mut Placement) {
        debug_assert!(!self.eager_frees, "eager (planner) executions are not rolled back");
        self.pending_frees.clear(); // sources were never freed
        for entry in self.undo.drain(..).rev() {
            match entry {
                UndoEntry::Ledger { device, tag, prev_bytes } => {
                    ledger.restore_alloc(device, &tag, prev_bytes);
                }
                UndoEntry::AddedReplica { layer, device } => {
                    placement.remove_replica(layer, device);
                }
                UndoEntry::MovedPrimary { layer, from } => {
                    if placement.primary_device(layer) != from
                        && !placement.holds(layer, from)
                    {
                        placement.migrate_layer(layer, from);
                    }
                }
                UndoEntry::MovedModule { module, prev } => match prev {
                    Some(d) => placement.migrate_module(module, d),
                    None => {
                        placement.unmigrate_module(module);
                    }
                },
                UndoEntry::RemovedReplica { layer, device } => {
                    if !placement.holds(layer, device) {
                        placement.add_replica(layer, device);
                    }
                }
            }
        }
    }
}

/// Atomic plan executor: two-phase **prepare** (validate against the
/// current state, touching nothing) then **commit** (apply op-by-op; the
/// first failure rolls every applied op back). Either the whole plan
/// lands, or cluster allocations and placement are byte-identical to the
/// pre-call state.
pub struct PlanExecutor<'a> {
    /// Costing + tagging context the executor prices ops through.
    pub ops: &'a ModuleOps<'a>,
}

impl<'a> PlanExecutor<'a> {
    /// Executor bound to one instance's costing context.
    pub fn new(ops: &'a ModuleOps<'a>) -> PlanExecutor<'a> {
        PlanExecutor { ops }
    }

    /// Validate then apply the whole plan; the first failing op rolls
    /// every applied op back and reports its index and cause.
    pub fn execute(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        plan: &ScalePlan,
    ) -> Result<PlanCost, PlanError> {
        plan.validate(self.ops, cluster, placement)?;
        let mut exec = PlanExecution::new();
        for (i, op) in plan.ops.iter().enumerate() {
            if let Err(error) = exec.apply_next(self.ops, cluster, placement, op) {
                exec.rollback(cluster, placement);
                return Err(PlanError::Failed { op_idx: i, error });
            }
        }
        Ok(exec.commit(cluster))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::ModelConfig;

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(40, 0);
        (cm, cluster, placement)
    }

    fn replicate(
        ops: &ModuleOps<'_>,
        cl: &mut Cluster,
        pl: &mut Placement,
        layer: usize,
        dst: usize,
    ) -> Result<PlanCost, PlanError> {
        PlanExecutor::new(ops).execute(cl, pl, &ScalePlan::replicate_batch(&[layer], dst))
    }

    #[test]
    fn replicate_allocates_and_registers() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let c = replicate(&ops, &mut cl, &mut pl, 5, 1).unwrap();
        assert!(pl.layer_devices(5).contains(&1));
        assert!(cl.device(1).used_bytes() > 600.0 * MIB);
        assert!(c.total.time_s > REPLICATION_LAUNCH_S);
        assert!(c.total.time_s < 1.0, "sub-second op: {}", c.total.time_s);
    }

    #[test]
    fn replicate_twice_rejected() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        replicate(&ops, &mut cl, &mut pl, 5, 1).unwrap();
        assert!(matches!(
            replicate(&ops, &mut cl, &mut pl, 5, 1),
            Err(PlanError::Rejected { op_idx: 0, .. })
        ));
    }

    #[test]
    fn migrate_moves_bytes_between_ledgers() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // seed the source ledger with the layer's residency
        let m = ModuleId::layer(ModuleKind::DecoderLayer, 3);
        let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        cl.device_mut(0).alloc(&ops.tag(&m, 0), bytes).unwrap();

        let before_src = cl.device(0).used_bytes();
        PlanExecutor::new(&ops)
            .execute(&mut cl, &mut pl, &ScalePlan::migrate_batch(&[3], 2))
            .unwrap();
        assert_eq!(pl.primary_device(3), 2);
        assert!(cl.device(0).used_bytes() < before_src);
        assert!((cl.device(2).used_bytes() - bytes).abs() < 1.0);
    }

    #[test]
    fn migration_cheaper_than_replication() {
        let (cm, cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let bw = cl.link_bw(0, 1);
        for n in [1, 10, 20, 40] {
            let (tr, _) = ops.table2_cost(n, bw, 0.1, false);
            let (tm, _) = ops.table2_cost(n, bw, 0.1, true);
            assert!(tm < tr, "n={n}: migration {tm} !< replication {tr}");
            assert!((tr - tm - 0.05).abs() < 0.01);
        }
    }

    /// Table 2's headline properties: sub-second ops, ~3× time for 40×
    /// layers, exactly-linear memory at 608 MiB/layer + 499 MiB overhead.
    #[test]
    fn table2_shape_reproduced() {
        let (cm, cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let bw = cl.link_bw(0, 1);
        let frac = |n: usize| (499.0 + 608.0 * n as f64) * MIB / cl.device(0).spec.mem_bytes;
        let (t1, m1) = ops.table2_cost(1, bw, frac(1), false);
        let (t40, m40) = ops.table2_cost(40, bw, frac(40), false);
        assert!((0.25..0.40).contains(&t1), "t1={t1}");
        assert!((0.60..1.30).contains(&t40), "t40={t40}");
        assert!(t40 / t1 < 5.0, "40x layers only ~3x time: {}", t40 / t1);
        assert!((m1 / MIB - 1107.0).abs() < 5.0, "m1={}", m1 / MIB);
        assert!((m40 / MIB - 24819.0).abs() < 50.0, "m40={}", m40 / MIB);
    }

    #[test]
    fn evict_frees_memory() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        replicate(&ops, &mut cl, &mut pl, 7, 1).unwrap();
        let used = cl.device(1).used_bytes();
        let evict = ScalePlan { ops: vec![ModuleOp::Evict { layer: 7, device: 1 }] };
        let c = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &evict).unwrap();
        assert!(cl.device(1).used_bytes() < used);
        assert!(c.total.dst_bytes < 0.0, "eviction frees destination bytes");
        assert_eq!(pl.degree(7), 1);
        assert!(matches!(
            PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &evict),
            Err(PlanError::Rejected { op_idx: 0, .. })
        ));
    }

    #[test]
    fn kv_cache_migration_charges_payload() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let kv = ModuleId::layer(ModuleKind::KvCache, 0);
        let payload = 2.0e9; // 2 GB of cache
        let plan = ScalePlan {
            ops: vec![ModuleOp::MigrateModule { module: kv, dst: 3, payload_bytes: payload }],
        };
        let c = PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &plan).unwrap();
        assert!(c.total.bytes_moved >= payload);
        assert_eq!(pl.module_device(kv), 3);
        assert!(cl.device(3).used_bytes() >= payload);
    }

    #[test]
    fn oom_destination_rejected_without_state_change() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        cl.device_mut(1).alloc("hog", 39.9 * 1024.0 * MIB).unwrap();
        let r = replicate(&ops, &mut cl, &mut pl, 0, 1);
        assert!(matches!(r, Err(PlanError::Rejected { .. })));
        assert_eq!(pl.degree(0), 1);
    }

    #[test]
    fn replication_batch_amortizes_launch() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ex = PlanExecutor::new(&ops);
        let batch = ex
            .execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[0, 1, 2, 3], 1))
            .unwrap();
        let mut cl2 = Cluster::paper_testbed();
        let mut pl2 = Placement::single_device(40, 0);
        let mut single = OpCost::default();
        for l in 0..4usize {
            let c = ex
                .execute(&mut cl2, &mut pl2, &ScalePlan::replicate_batch(&[l], 1))
                .unwrap();
            single = single.merge(c.total);
        }
        assert!(batch.total.time_s < single.time_s);
    }

    #[test]
    fn mid_plan_failure_rolls_back_applied_ops() {
        // The simulator's in-flight path applies ops without re-validating
        // the whole plan, so a later op can hit a genuine OOM; rollback
        // must restore the pre-plan state exactly.
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let layer_bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        let hog = cl.device(1).free_bytes() - 1.5 * layer_bytes;
        cl.device_mut(1).alloc("hog", hog).unwrap();
        let used_before = cl.device(1).used_bytes();

        let plan = ScalePlan::replicate_batch(&[0, 1], 1);
        let mut exec = PlanExecution::new();
        assert!(exec.apply_next(&ops, &mut cl, &mut pl, &plan.ops[0]).is_ok());
        assert!(matches!(
            exec.apply_next(&ops, &mut cl, &mut pl, &plan.ops[1]),
            Err(OpError::DestinationOom(_))
        ));
        assert_eq!(pl.degree(0), 2, "first replica really landed");
        exec.rollback(&mut cl, &mut pl);
        assert_eq!(pl.degree(0), 1, "replica retracted");
        assert_eq!(cl.device(1).used_bytes(), used_before);
    }

    #[test]
    fn migration_defers_source_free_to_commit() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let m = ModuleId::layer(ModuleKind::DecoderLayer, 3);
        let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        cl.device_mut(0).alloc(&ops.tag(&m, 0), bytes).unwrap();
        let src_before = cl.device(0).used_bytes();

        let plan = ScalePlan::migrate_batch(&[3], 2);
        let mut exec = PlanExecution::new();
        exec.apply_next(&ops, &mut cl, &mut pl, &plan.ops[0]).unwrap();
        // both copies resident while the plan is in flight (copy-then-free)
        assert_eq!(cl.device(0).used_bytes(), src_before);
        assert!(cl.device(2).used_bytes() >= bytes);
        assert_eq!(pl.primary_device(3), 2);
        exec.commit(&mut cl);
        assert!(cl.device(0).used_bytes() < src_before, "source freed at commit");
    }

    #[test]
    fn commit_preserves_bytes_reallocated_under_a_pending_tag() {
        // evict-then-replicate the same layer on the same device: the
        // replicate lands new bytes under the tag whose old bytes are
        // pending free — commit must subtract only the evicted amount.
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let ex = PlanExecutor::new(&ops);
        ex.execute(&mut cl, &mut pl, &ScalePlan::replicate_batch(&[7], 1)).unwrap();
        let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        let tag = ops.tag(&ModuleId::layer(ModuleKind::DecoderLayer, 7), 1);

        let plan = ScalePlan {
            ops: vec![
                ModuleOp::Evict { layer: 7, device: 1 },
                ModuleOp::Replicate { layer: 7, dst: 1 },
            ],
        };
        ex.execute(&mut cl, &mut pl, &plan).unwrap();
        assert_eq!(pl.degree(7), 2, "replica re-established");
        assert!(
            (cl.device(1).alloc_bytes(&tag) - bytes).abs() < 1.0,
            "commit must not destroy the re-allocated copy: {} vs {bytes}",
            cl.device(1).alloc_bytes(&tag)
        );
    }

    #[test]
    fn failed_op_does_not_consume_launch_amortization() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let free = cl.device(1).free_bytes();
        cl.device_mut(1).alloc("hog", free - 1.0).unwrap();
        let mut exec = PlanExecution::new();
        let op = ModuleOp::Replicate { layer: 0, dst: 1 };
        assert!(exec.apply_next(&ops, &mut cl, &mut pl, &op).is_err());
        // space frees up; the retried op must still pay its launch
        cl.device_mut(1).free("hog").unwrap();
        let c = exec.apply_next(&ops, &mut cl, &mut pl, &op).unwrap();
        assert!(
            c.time_s > REPLICATION_LAUNCH_S,
            "launch not charged after a failed attempt: {}",
            c.time_s
        );
    }

    #[test]
    fn stepwise_execution_matches_atomic_cost() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let plan = ScalePlan::replicate_batch(&[0, 1, 2], 1);
        let dry = plan.dry_run(&ops, &cl, &pl).unwrap();
        let mut exec = PlanExecution::new();
        for op in &plan.ops {
            exec.apply_next(&ops, &mut cl, &mut pl, op).unwrap();
        }
        assert_eq!(exec.applied(), 3);
        assert_eq!(*exec.cost(), dry, "stepwise == dry-run, bit for bit");
    }

    #[test]
    fn swap_precision_shrinks_ledger_and_rolls_back_exactly() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        ops.deploy_instance(&mut cl, &pl).unwrap();
        let tag = ops.tag(&ModuleId::layer(ModuleKind::DecoderLayer, 4), 0);
        let before = cl.device(0).alloc_bytes(&tag);

        let op = ModuleOp::SwapPrecision { layer: 4, device: 0, from: 2, to: 1 };
        let mut exec = PlanExecution::new();
        let c = exec.apply_next(&ops, &mut cl, &mut pl, &op).unwrap();
        assert_eq!(c.dst_bytes, ops.swap_delta_bytes(2, 1));
        assert!(c.dst_bytes < 0.0 && c.time_s < 0.01, "cheap, frees bytes");
        assert_eq!(cl.device(0).alloc_bytes(&tag), before + ops.swap_delta_bytes(2, 1));
        exec.rollback(&mut cl, &mut pl);
        assert_eq!(cl.device(0).alloc_bytes(&tag), before, "bit-exact restore");
    }

    #[test]
    fn swap_precision_requires_residency_and_oom_checks_growth() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        ops.deploy_instance(&mut cl, &pl).unwrap();
        let mut exec = PlanExecution::new();
        // layer 0 is on device 0, not device 2
        let astray = ModuleOp::SwapPrecision { layer: 0, device: 2, from: 2, to: 1 };
        assert!(matches!(
            exec.apply_next(&ops, &mut cl, &mut pl, &astray),
            Err(OpError::NoSuchReplica(0, 2))
        ));
        // an up-swap (1B -> 4B) needs headroom; a stuffed device rejects it
        let free = cl.device(0).free_bytes();
        cl.device_mut(0).alloc("hog", free - 1.0).unwrap();
        let grow = ModuleOp::SwapPrecision { layer: 0, device: 0, from: 2, to: 4 };
        assert!(matches!(
            exec.apply_next(&ops, &mut cl, &mut pl, &grow),
            Err(OpError::DestinationOom(_))
        ));
    }
}
