//! Fig. 10 / §6.3 — multi-instance serving at fleet scale.
//!
//! Paper claims (shape, at 2–4 instances): CoCo×2 beats HFT×2 (−14%/−27%
//! latency, +17%/+39% throughput); HFT×4 beats CoCo×2 only modestly while
//! using ~2× the memory — the 46% cost-reduction claim. This bench scales
//! the comparison to a 16-device fleet (CoCo×8 and HFT×8 on half of it,
//! HFT×16 on all of it — a 13B instance needs a whole A100) and —
//! going beyond the paper's steady-Poisson setup — sweeps the full
//! scenario library (steady, diurnal, burst, ramp, two-tenant mix), the
//! dynamic-traffic regimes where module scaling should earn its keep.
//!
//! Every cell is produced by the deterministic event kernel: the bench
//! re-runs one configuration per scenario and asserts the metrics JSON is
//! byte-identical (golden replay) before reporting.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::Trace;

// The paper's 4-device shape scaled ×4: CoCo×8 and HFT×8 deploy on half
// the fleet (the idle half is CoCo's replica-harvesting headroom, exactly
// like CoCo×2 vs HFT×2 on the 4×A100 testbed); HFT×16 occupies every
// device — the 2× footprint whose throughput CoCo approaches at ~half the
// memory (the 46% cost-reduction claim).
const N_DEVICES: usize = 16;
const RPS: f64 = 60.0;
const DURATION_S: f64 = 20.0;
const SEED: u64 = 13;

fn run(n_instances: usize, policy: SimPolicy, trace: &Trace) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let placements: Vec<_> = (0..n_instances)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % N_DEVICES),
                policy,
            )
        })
        .collect();
    let cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    let sim = Simulation::new(cfg, cluster, placements);
    sim.run(trace, DURATION_S)
}

fn main() {
    let sweep = Trace::scenario_sweep(RPS, DURATION_S, SEED);
    println!(
        "Fig. 10 — multi-instance (13B, {N_DEVICES}×A100, {RPS:.0} rps aggregate, \
         {} scenarios)\n",
        sweep.len()
    );
    let mut t = Table::new(&[
        "scenario", "hft×8 lat", "hft×16 lat", "coco×8 lat",
        "hft×8 thr", "hft×16 thr", "coco×8 thr", "coco/hft×16 mem",
    ]);
    let mut rep = Report::new("fig10_multi_instance");
    let mut replay_ok = true;

    for (name, trace) in sweep {
        let h8 = run(8, baselines::hft(16), &trace);
        let h16 = run(16, baselines::hft(16), &trace);
        let c8 = run(8, baselines::cocoserve(64), &trace);

        // golden replay: identical seed ⇒ byte-identical metrics JSON
        let c8_again = run(8, baselines::cocoserve(64), &trace);
        let identical = c8.to_json().to_string() == c8_again.to_json().to_string();
        replay_ok &= identical;
        if !identical {
            eprintln!("WARNING: scenario `{name}` was not replay-deterministic");
        }

        let (l8, l16, lc) = (
            h8.merged_latency().mean(),
            h16.merged_latency().mean(),
            c8.merged_latency().mean(),
        );
        let (t8, t16, tc) = (
            h8.total_throughput_tps(),
            h16.total_throughput_tps(),
            c8.total_throughput_tps(),
        );
        let mem_ratio = c8.peak_mem_bytes / h16.peak_mem_bytes.max(1.0);
        t.row(&[
            name.to_string(),
            format!("{l8:.2}"),
            format!("{l16:.2}"),
            format!("{lc:.2}"),
            format!("{t8:.0}"),
            format!("{t16:.0}"),
            format!("{tc:.0}"),
            format!("{:.1}%", mem_ratio * 100.0),
        ]);
        rep.set(
            name,
            json::obj(vec![
                ("lat_mean_s", json::arr([l8, l16, lc].into_iter().map(json::num))),
                ("throughput_tps", json::arr([t8, t16, tc].into_iter().map(json::num))),
                (
                    "peak_mem_gib",
                    json::arr(
                        [h8.peak_mem_bytes, h16.peak_mem_bytes, c8.peak_mem_bytes]
                            .into_iter()
                            .map(|b| json::num(b / GIB)),
                    ),
                ),
                ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
                ("coco_scale_ups", json::num(c8.scale_ups as f64)),
                ("coco_scale_downs", json::num(c8.scale_downs as f64)),
            ]),
        );
    }

    t.print();
    println!(
        "\ngolden replay across all scenarios: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
