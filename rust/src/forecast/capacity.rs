//! Horizon capacity model: predicted arrival rate → required capacity.
//!
//! The predictive controller needs one conversion: "the forecaster says
//! λ requests/second will arrive `h` seconds from now — how many
//! instance-equivalents of serving capacity does that take, and how many
//! layer replicas (or whole instances) close the gap?" This module does
//! that conversion by **inverting the costing the kernel already
//! enacts**, not by introducing a parallel formula:
//!
//! * the sustainable per-instance request rate μ comes from the compiled
//!   roofline step costs ([`crate::placement::PlacementProfile`]
//!   `prefill_step_time` / `decode_step_time` — the exact arithmetic a
//!   serving step is charged in the simulator), amortized over a mean
//!   request's one prefill + ō decode steps;
//! * the capacity contribution of a replicated placement is its Eq. 4
//!   speedup ([`crate::autoscale::speedup::s_homo_from_norm`] — the same
//!   closed form Algorithm 1 maximizes), and replica requirements come
//!   from inverting that closed form.

use crate::autoscale::speedup::s_homo_from_norm;
use crate::model::cost::CostModel;
use crate::placement::PlacementProfile;

/// Invert Eq. 4 for a uniform strategy: the smallest per-layer degree
/// `p` with `S_homo(γ, [p; n]) ≥ target`. Returns 1 for targets ≤ 1;
/// saturates at `usize::MAX` when γ alone caps the speedup below the
/// target (communication dominates — no degree reaches it).
pub fn uniform_degree_for_speedup(gamma: f64, target: f64) -> usize {
    if target <= 1.0 {
        return 1;
    }
    // S = 1 / (γ + (1−γ)/p)  ⇒  p = (1−γ) / (1/S − γ)
    let denom = 1.0 / target - gamma;
    if denom <= 0.0 {
        return usize::MAX;
    }
    ((1.0 - gamma) / denom).ceil() as usize
}

/// Invert Eq. 4 incrementally: how many single-replica additions (each
/// taking one degree-1 layer to degree 2, the cheapest Algorithm 1 move,
/// shrinking ‖1 ⊘ P‖₁ by ½) does it take to lift a placement with the
/// given norm to `target` speedup? Saturates at `n_layers` (every layer
/// already at degree ≥ 2 would need deeper replication — the caller
/// falls back to whole-instance scaling there).
pub fn replicas_for_speedup(
    gamma: f64,
    n_layers: usize,
    inv_p_norm: f64,
    target: f64,
) -> usize {
    if target <= s_homo_from_norm(gamma, n_layers, inv_p_norm) {
        return 0;
    }
    // target norm from Eq. 4: S = 1/(γ + (1−γ)/n · norm)
    let denom = 1.0 / target - gamma;
    if denom <= 0.0 {
        return n_layers;
    }
    let target_norm = n_layers as f64 * denom / (1.0 - gamma);
    let deficit = inv_p_norm - target_norm;
    ((deficit / 0.5).ceil().max(0.0) as usize).min(n_layers)
}

/// The horizon capacity model: a predicted rate in, required
/// instance-equivalents (and the replica count closing a fractional
/// deficit) out. Built once per simulation from the shared
/// [`CostModel`]; see the module docs for the shared-costing argument.
#[derive(Debug, Clone, Copy)]
pub struct CapacityModel {
    /// Sustainable request rate of one unreplicated reference instance
    /// (requests/second), from the compiled roofline step costs.
    pub mu_base_rps: f64,
    /// Eq. 4 cluster coefficient γ.
    pub gamma: f64,
    /// Decoder-layer count of the served model.
    pub n_layers: usize,
    /// Fraction of μ the planner is willing to load an instance to —
    /// the calibration margin absorbing contention, batch underfill and
    /// prompt-length tails the mean-request amortization cannot see.
    pub target_util: f64,
    /// Effective FLOPs of the reference placement's bottleneck device
    /// ([`PlacementProfile::min_eff_flops`]) — the denominator of the
    /// heterogeneous speed factor. On a homogeneous fleet every instance
    /// matches the reference and the factor is exactly 1.0.
    pub ref_eff_flops: f64,
}

impl CapacityModel {
    /// Derive μ from a reference placement's compiled step costs: a mean
    /// request occupies one prefill step (at `mean_prompt` tokens) and
    /// `mean_output` decode steps (at the mean decode context), shared
    /// across a `batch`-wide cohort.
    pub fn from_profile(
        cost: &CostModel,
        profile: &PlacementProfile,
        dtype_bytes: usize,
        batch: usize,
        mean_prompt: usize,
        mean_output: usize,
        gamma: f64,
        target_util: f64,
    ) -> CapacityModel {
        let batch = batch.max(1);
        let prefill = profile.prefill_step_time(cost, dtype_bytes, batch, mean_prompt.max(1));
        let mean_ctx = (mean_prompt + mean_output / 2).max(1);
        let decode = profile.decode_step_time(cost, dtype_bytes, batch, mean_ctx);
        let per_cohort = prefill + mean_output as f64 * decode;
        CapacityModel {
            mu_base_rps: batch as f64 / per_cohort.max(1e-9),
            gamma,
            n_layers: profile.n_layers,
            target_util: target_util.clamp(0.05, 1.0),
            ref_eff_flops: profile.min_eff_flops(),
        }
    }

    /// Instance-equivalents needed to serve `rps` at the target
    /// utilization.
    pub fn required_equivalents(&self, rps: f64) -> f64 {
        rps.max(0.0) / (self.mu_base_rps * self.target_util).max(1e-9)
    }

    /// Capacity contribution of one instance with the given
    /// ‖1 ⊘ P‖₁, in instance-equivalents: its Eq. 4 speedup (an
    /// unreplicated placement contributes exactly 1.0).
    pub fn equivalents_of(&self, inv_p_norm: f64) -> f64 {
        s_homo_from_norm(self.gamma, self.n_layers, inv_p_norm)
    }

    /// Replicas that lift an instance with the given norm by
    /// `deficit_eq` instance-equivalents (via the Eq. 4 inversion).
    pub fn replicas_for_deficit(&self, inv_p_norm: f64, deficit_eq: f64) -> usize {
        let target = self.equivalents_of(inv_p_norm) + deficit_eq.max(0.0);
        replicas_for_speedup(self.gamma, self.n_layers, inv_p_norm, target)
    }

    /// Heterogeneous speed factor of an instance whose pipeline
    /// bottleneck runs at `min_eff_flops`: the ratio to the reference
    /// device. A V100-hosted instance on an H100-referenced model prices
    /// below 1.0; on a homogeneous fleet the ratio is *exactly* 1.0
    /// (same value over itself), so every legacy number is bit-identical.
    pub fn speed_factor(&self, min_eff_flops: f64) -> f64 {
        if self.ref_eff_flops <= 0.0 || min_eff_flops <= 0.0 {
            return 1.0;
        }
        min_eff_flops / self.ref_eff_flops
    }

    /// Capacity contribution of one instance in reference-device
    /// instance-equivalents: its Eq. 4 speedup scaled by the
    /// heterogeneous speed factor of its bottleneck device.
    pub fn device_equivalents(&self, inv_p_norm: f64, min_eff_flops: f64) -> f64 {
        self.equivalents_of(inv_p_norm) * self.speed_factor(min_eff_flops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::placement::Placement;
    use crate::sim::SimConfig;

    fn model() -> CapacityModel {
        let cfg = SimConfig::paper_13b();
        let cost = cfg.cost_model();
        let cluster = Cluster::paper_testbed();
        let pl = Placement::single_device(cfg.model.n_layers, 0);
        let profile = PlacementProfile::compile(&pl, &cluster, 0);
        CapacityModel::from_profile(&cost, &profile, cfg.dtype_bytes, 16, 96, 64, 0.05, 0.6)
    }

    #[test]
    fn mu_lands_in_a_plausible_band_for_13b_on_a100() {
        let m = model();
        // a 13B instance on one A100 sustains single-digit-to-tens rps
        assert!(
            (1.0..200.0).contains(&m.mu_base_rps),
            "mu {} rps out of band",
            m.mu_base_rps
        );
    }

    #[test]
    fn required_equivalents_is_linear_and_clamped() {
        let m = model();
        let one = m.required_equivalents(m.mu_base_rps * m.target_util);
        assert!((one - 1.0).abs() < 1e-9, "exactly μ·util needs 1.0 eq, got {one}");
        assert!((m.required_equivalents(2.0 * m.mu_base_rps * m.target_util) - 2.0).abs() < 1e-9);
        assert_eq!(m.required_equivalents(-5.0), 0.0);
    }

    #[test]
    fn uniform_degree_inversion_roundtrips_eq4() {
        for &gamma in &[0.0, 0.05, 0.2] {
            for &target in &[1.0, 1.5, 2.0, 3.5] {
                let p = uniform_degree_for_speedup(gamma, target);
                if p == usize::MAX {
                    continue;
                }
                let n = 40;
                let got = s_homo_from_norm(gamma, n, n as f64 / p as f64);
                assert!(
                    got + 1e-9 >= target,
                    "γ={gamma} target={target}: degree {p} gives only {got}"
                );
                if p > 1 {
                    let under = s_homo_from_norm(gamma, n, n as f64 / (p - 1) as f64);
                    assert!(under < target, "degree {} already reaches {target}", p - 1);
                }
            }
        }
    }

    #[test]
    fn gamma_bound_saturates_the_inversion() {
        // γ = 0.5 caps S below 2: no degree reaches it
        assert_eq!(uniform_degree_for_speedup(0.5, 2.5), usize::MAX);
        assert_eq!(uniform_degree_for_speedup(0.5, 1.0), 1);
    }

    #[test]
    fn replicas_for_speedup_roundtrips_eq4() {
        let (gamma, n) = (0.05, 40usize);
        let norm = n as f64; // unreplicated
        for &target in &[1.05, 1.2, 1.4] {
            let k = replicas_for_speedup(gamma, n, norm, target);
            assert!(k > 0 && k <= n, "k={k}");
            let got = s_homo_from_norm(gamma, n, norm - 0.5 * k as f64);
            assert!(got + 1e-9 >= target, "{k} replicas give {got} < {target}");
            let under = s_homo_from_norm(gamma, n, norm - 0.5 * (k - 1) as f64);
            assert!(under < target, "{} replicas already reach {target}", k - 1);
        }
        assert_eq!(replicas_for_speedup(gamma, n, norm, 0.9), 0, "already satisfied");
        // unreachable targets saturate at n_layers
        assert_eq!(replicas_for_speedup(0.5, n, norm, 3.0), n);
    }

    #[test]
    fn capacity_model_replica_helper_matches_inversion() {
        let m = model();
        let norm = m.n_layers as f64;
        let k = m.replicas_for_deficit(norm, 0.25);
        let lifted = m.equivalents_of(norm - 0.5 * k as f64);
        assert!(lifted + 1e-9 >= 1.25, "{k} replicas lift to {lifted}");
        assert_eq!(m.replicas_for_deficit(norm, 0.0), 0);
    }

    #[test]
    fn speed_factor_is_exactly_one_on_homogeneous_fleets() {
        let m = model();
        assert!(m.ref_eff_flops > 0.0);
        // bit-exact: a factor derived from the same device cancels
        assert_eq!(m.speed_factor(m.ref_eff_flops), 1.0);
        assert_eq!(
            m.device_equivalents(m.n_layers as f64, m.ref_eff_flops),
            m.equivalents_of(m.n_layers as f64)
        );
        // degenerate inputs fall back to the homogeneous factor
        assert_eq!(m.speed_factor(0.0), 1.0);
        let degenerate = CapacityModel { ref_eff_flops: 0.0, ..m };
        assert_eq!(degenerate.speed_factor(123.0), 1.0);
    }

    #[test]
    fn two_generation_cluster_prices_slow_instances_below_fast_ones() {
        use crate::cluster::DeviceSpec;
        let cfg = SimConfig::paper_13b();
        let cost = cfg.cost_model();
        // generation 0: A100 (devices 0-1), generation 1: V100 (devices 2-3)
        let cluster = Cluster::mixed(vec![
            DeviceSpec::a100_40gb(),
            DeviceSpec::a100_40gb(),
            DeviceSpec::v100_32gb(),
            DeviceSpec::v100_32gb(),
        ]);
        let fast = Placement::single_device(cfg.model.n_layers, 0);
        let slow = Placement::single_device(cfg.model.n_layers, 2);
        let fast_p = PlacementProfile::compile(&fast, &cluster, 0);
        let slow_p = PlacementProfile::compile(&slow, &cluster, 0);
        let m = CapacityModel::from_profile(
            &cost, &fast_p, cfg.dtype_bytes, 16, 96, 64, 0.05, 0.6,
        );
        let norm = m.n_layers as f64;
        // the A100-referenced model rates the A100 instance at exactly
        // its homogeneous equivalents, and the V100 instance strictly
        // below it, in proportion to effective FLOPs
        let fast_eq = m.device_equivalents(norm, fast_p.min_eff_flops());
        let slow_eq = m.device_equivalents(norm, slow_p.min_eff_flops());
        assert_eq!(fast_eq, m.equivalents_of(norm));
        assert!(
            slow_eq < fast_eq,
            "V100 instance ({slow_eq}) must price below A100 ({fast_eq})"
        );
        let ratio = slow_eq / fast_eq;
        let flops_ratio = slow_p.min_eff_flops() / fast_p.min_eff_flops();
        assert!(
            (ratio - flops_ratio).abs() < 1e-12,
            "equivalents ratio {ratio} must track FLOPs ratio {flops_ratio}"
        );
    }
}
