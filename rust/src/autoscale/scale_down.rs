//! Algorithm 2 — Scale-Down via Module Reduction (§4.2), as a **pure
//! planner**.
//!
//! A graduated three-phase intervention, each phase costlier than the last,
//! planned only until the violation predicate clears:
//!
//! 1. **Module Migration** — move §3.3-selected modules (KV caches under
//!    memory pressure, attention/FFN blocks under compute pressure) off the
//!    violating device to the optimal destination.
//! 2. **Replica Eviction** — drop co-located layer replicas, lowest-impact
//!    first.
//! 3. **Performance Reduction** — step the batch size down by Δbs and
//!    offload, trading the instance's own throughput for stability.
//!
//! The planner walks a copy-on-write [`ShadowLedger`] plus a shadow
//! placement (the violation predicate observes the shadow state each
//! phase would leave behind — the cluster is never cloned) and returns a
//! [`ScaleDownPlan`]: module ops for phases 1–2 plus the phase-3 batch
//! decision. Nothing is mutated here — the caller executes the plan
//! through [`crate::ops::PlanExecutor`] or in flight via the simulation
//! kernel, and applies `batch_size` itself.

use crate::cluster::{Cluster, LedgerView, ShadowLedger};
use crate::model::{ModuleId, ModuleKind};
use crate::ops::{ModuleOps, PlanExecution};
use crate::placement::Placement;
use crate::plan::{ModuleOp, PlanCost, ScalePlan};

/// What kind of pressure is the violating device under? Determines the
/// §3.3 module filter (memory → KV cache first; compute → attn/FFN).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pressure {
    /// Memory-dominated: relieve resident bytes first.
    Memory,
    /// Compute-dominated: relieve FLOPs-dense modules first.
    Compute,
}

/// Tuning knobs for Algorithm 2.
#[derive(Debug, Clone, Copy)]
pub struct ScaleDownConfig {
    /// Δbs — batch-size adjustment step (paper suggests e.g. 5).
    pub batch_step: usize,
    /// Candidate cap for phase 1 (§4.2: "determines the number of
    /// candidates based on the analysis in §3.3").
    pub max_migration_candidates: usize,
    /// Headroom a destination must keep after receiving a module.
    pub dst_headroom_frac: f64,
}

impl Default for ScaleDownConfig {
    fn default() -> Self {
        ScaleDownConfig {
            batch_step: 5,
            max_migration_candidates: 4,
            dst_headroom_frac: 0.1,
        }
    }
}

/// One remediation step planned by Algorithm 2 (for logs + tests + benches).
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Phase 1: a module planned to move off the violating device.
    Migrated { module: ModuleId, from: usize, to: usize },
    /// Phase 2: a co-located replica planned for eviction.
    Evicted { layer: usize, device: usize },
    /// Phase 3: the serving batch stepped down by Δbs.
    BatchReduced { from: usize, to: usize },
    /// Phase 3 companion: pending work offloaded from the device.
    Offloaded { device: usize },
}

/// Outcome of a scale-down planning round.
#[derive(Debug, Clone)]
pub struct ScaleDownPlan {
    /// Executable module ops (phases 1–2); phase 3 is batch-only.
    pub plan: ScalePlan,
    /// Every remediation step planned, in phase order.
    pub actions: Vec<Action>,
    /// Did the violation predicate clear on the planned end state?
    pub resolved: bool,
    /// Possibly-reduced batch size the caller should adopt.
    pub batch_size: usize,
    /// Dry-run cost against the planning-time state — equals the executed
    /// cost when the plan is applied to that same state.
    pub cost: PlanCost,
}

/// Memory fraction above which a device counts as violating for the
/// kernel's standard OOM/memory-pressure predicate.
pub const MEM_VIOLATION_FRAC: f64 = 0.92;

/// The kernel's standard OOM-violation predicate for Algorithm 2: the hot
/// device is above [`MEM_VIOLATION_FRAC`] of its memory (and an SLO is
/// actually configured — a zero SLO disables the check). One named
/// definition shared by the controller tick and the emergency
/// scale-down path, so the two loops can never drift apart.
pub fn memory_violation(
    hot: usize,
    slo_latency_s: f64,
) -> impl FnMut(&ShadowLedger<'_>, &Placement, usize) -> bool {
    move |ledger, _placement, _batch| {
        ledger.mem_frac(hot) > MEM_VIOLATION_FRAC && slo_latency_s > 0.0
    }
}

/// `FilterModules` (§4.2 phase 1): migration candidates on `src`, ordered
/// by the §3.3 analysis for the pressure kind.
pub fn filter_modules(
    placement: &Placement,
    src: usize,
    pressure: Pressure,
    cap: usize,
) -> Vec<ModuleId> {
    let mut out: Vec<ModuleId> = Vec::new();
    let layers_here = placement.primaries_on(src);
    match pressure {
        Pressure::Memory => {
            // KV caches first (§3.3: "migrating the KV Cache proves
            // advantageous" for memory relief), then whole layers.
            for &l in &layers_here {
                let kv = ModuleId::layer(ModuleKind::KvCache, l);
                if placement.module_device(kv) == src {
                    out.push(kv);
                }
            }
            for &l in &layers_here {
                out.push(ModuleId::layer(ModuleKind::DecoderLayer, l));
            }
        }
        Pressure::Compute => {
            // Compute-dense modules first: attention blocks, then FFNs,
            // then whole layers (§3.3 densities 0.275 / 0.268 GFLOPs/MB).
            for &l in &layers_here {
                let attn = ModuleId::layer(ModuleKind::Attn, l);
                if placement.module_device(attn) == src {
                    out.push(attn);
                }
            }
            for &l in &layers_here {
                let ffn = ModuleId::layer(ModuleKind::Ffn, l);
                if placement.module_device(ffn) == src {
                    out.push(ffn);
                }
            }
            for &l in &layers_here {
                out.push(ModuleId::layer(ModuleKind::DecoderLayer, l));
            }
        }
    }
    out.truncate(cap);
    out
}

/// `FindOptimalDestination`: the non-violating device with the most free
/// memory that can hold `bytes` while keeping `headroom_frac` free.
/// Generic over the ledger view so the planner can consult its shadow.
pub fn find_optimal_destination<V: LedgerView + ?Sized>(
    view: &V,
    src: usize,
    bytes: f64,
    headroom_frac: f64,
) -> Option<usize> {
    view.by_free_memory().into_iter().find(|&d| {
        d != src && view.free_bytes(d) - bytes >= headroom_frac * view.mem_bytes(d)
    })
}

/// `SortEvicteesBy` (§4.2 phase 2): replicas co-located on the violating
/// device, lowest serving impact first. Impact proxy: replicas of layers
/// with the highest remaining degree lose the least parallelism, and
/// run-edge replicas break no continuity.
pub fn sort_evictees(placement: &Placement, device: usize) -> Vec<usize> {
    let mut evictees = placement.replicas_on(device);
    evictees.sort_by_key(|&l| {
        (
            std::cmp::Reverse(placement.degree(l)),
            placement.continuity_with(device, l),
        )
    });
    evictees
}

/// Algorithm 2 as a pure planner. `is_violating(shadow, placement, batch)`
/// is the SLO/OOM predicate (θ comparison), evaluated against the shadow
/// ledger state each planned step would produce; `kv_bytes(layer)` reports
/// the live cache payload for KV migrations.
pub fn scale_down(
    ops: &ModuleOps<'_>,
    cluster: &Cluster,
    placement: &Placement,
    src: usize,
    pressure: Pressure,
    batch_size: usize,
    cfg: &ScaleDownConfig,
    kv_bytes: impl Fn(usize) -> f64,
    mut is_violating: impl FnMut(&ShadowLedger<'_>, &Placement, usize) -> bool,
) -> ScaleDownPlan {
    let mut shadow_cl = ShadowLedger::new(cluster);
    let mut shadow_pl = placement.clone();
    let mut exec = PlanExecution::eager();
    let mut out = ScaleDownPlan {
        plan: ScalePlan::new(),
        actions: vec![],
        resolved: false,
        batch_size,
        cost: PlanCost::default(),
    };
    fn finish(mut out: ScaleDownPlan, exec: PlanExecution, resolved: bool) -> ScaleDownPlan {
        out.cost = exec.into_cost();
        out.resolved = resolved;
        out
    }

    if !is_violating(&shadow_cl, &shadow_pl, out.batch_size) {
        return finish(out, exec, true);
    }

    // ---- Phase 1: Module Migration -------------------------------------
    for m in filter_modules(&shadow_pl, src, pressure, cfg.max_migration_candidates) {
        let payload = match m.kind {
            ModuleKind::KvCache => kv_bytes(m.layer.unwrap_or(0)),
            _ => 0.0,
        };
        let bytes = ops.module_bytes(m.kind) + payload;
        let Some(dst) =
            find_optimal_destination(&shadow_cl, src, bytes, cfg.dst_headroom_frac)
        else {
            continue;
        };
        let op = if m.kind == ModuleKind::DecoderLayer {
            ModuleOp::MigrateLayer { layer: m.layer.unwrap(), dst }
        } else {
            ModuleOp::MigrateModule { module: m, dst, payload_bytes: payload }
        };
        if exec.apply_next(ops, &mut shadow_cl, &mut shadow_pl, &op).is_ok() {
            out.plan.push(op);
            out.actions.push(Action::Migrated { module: m, from: src, to: dst });
            if !is_violating(&shadow_cl, &shadow_pl, out.batch_size) {
                return finish(out, exec, true);
            }
        }
    }

    // ---- Phase 2: Replica Eviction --------------------------------------
    for layer in sort_evictees(&shadow_pl, src) {
        let op = ModuleOp::Evict { layer, device: src };
        if exec.apply_next(ops, &mut shadow_cl, &mut shadow_pl, &op).is_ok() {
            out.plan.push(op);
            out.actions.push(Action::Evicted { layer, device: src });
            if !is_violating(&shadow_cl, &shadow_pl, out.batch_size) {
                return finish(out, exec, true);
            }
        }
    }

    // ---- Phase 3: Performance Reduction ----------------------------------
    while is_violating(&shadow_cl, &shadow_pl, out.batch_size) && out.batch_size >= 1 {
        let from = out.batch_size;
        let to = from.saturating_sub(cfg.batch_step).max(1);
        if to == from {
            // batch floor reached; offload as the last resort and stop.
            out.actions.push(Action::Offloaded { device: src });
            let resolved = !is_violating(&shadow_cl, &shadow_pl, out.batch_size);
            return finish(out, exec, resolved);
        }
        out.batch_size = to;
        out.actions.push(Action::BatchReduced { from, to });
        out.actions.push(Action::Offloaded { device: src });
        if !is_violating(&shadow_cl, &shadow_pl, out.batch_size) {
            return finish(out, exec, true);
        }
    }
    let resolved = !is_violating(&shadow_cl, &shadow_pl, out.batch_size);
    finish(out, exec, resolved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, GIB};
    use crate::model::cost::{CostModel, MIB};
    use crate::model::ModelConfig;
    use crate::ops::PlanExecutor;

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let mut cl = Cluster::paper_testbed();
        cl.device_mut(0).alloc("inst0/model", 24.2 * GIB).unwrap();
        (cm, cl, Placement::single_device(40, 0))
    }

    fn replicate(
        ops: &ModuleOps<'_>,
        cl: &mut Cluster,
        pl: &mut Placement,
        layer: usize,
        dst: usize,
    ) {
        PlanExecutor::new(ops)
            .execute(cl, pl, &ScalePlan::replicate_batch(&[layer], dst))
            .unwrap();
    }

    #[test]
    fn memory_violation_predicate_matches_the_documented_threshold() {
        let mut cl = Cluster::paper_testbed();
        let cap = cl.device(0).spec.mem_bytes;
        cl.device_mut(0).alloc("load", cap * 0.95).unwrap();
        let pl = Placement::single_device(40, 0);
        let shadow = ShadowLedger::new(&cl);
        // above the line with an SLO configured → violating
        assert!(memory_violation(0, 15.0)(&shadow, &pl, 16));
        // a different (empty) hot device → healthy
        assert!(!memory_violation(1, 15.0)(&shadow, &pl, 16));
        // zero SLO disables the check entirely
        assert!(!memory_violation(0, 0.0)(&shadow, &pl, 16));
        // exactly at the threshold is not a violation (strict >)
        let mut at = Cluster::paper_testbed();
        let at_line = at.device(2).spec.mem_bytes * MEM_VIOLATION_FRAC;
        at.device_mut(2).alloc("load", at_line).unwrap();
        let shadow_at = ShadowLedger::new(&at);
        assert!(!memory_violation(2, 15.0)(&shadow_at, &pl, 16));
    }

    #[test]
    fn already_healthy_is_noop() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(), |_| 0.0, |_, _, _| false,
        );
        assert!(out.resolved);
        assert!(out.actions.is_empty());
        assert!(out.plan.is_empty());
        assert_eq!(out.batch_size, 15);
    }

    #[test]
    fn planner_leaves_inputs_untouched() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let used: Vec<f64> = (0..cl.n()).map(|d| cl.device(d).used_bytes()).collect();
        let _ = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(), |_| 1.0 * GIB, |_, _, _| true,
        );
        for d in 0..cl.n() {
            assert_eq!(cl.device(d).used_bytes(), used[d], "planner mutated device {d}");
        }
        assert_eq!(pl.migrations().count(), 0, "planner mutated placement");
    }

    #[test]
    fn phase1_migration_resolves_memory_pressure() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // per-layer KV allocations on device 0 (the engine's tag scheme) +
        // extra load pushing the device above the violation line
        for l in 0..4 {
            let kv = ModuleId::layer(ModuleKind::KvCache, l);
            cl.device_mut(0).alloc(&ops.tag(&kv, 0), 2.0 * GIB).unwrap();
        }
        cl.device_mut(0).alloc("activations", 6.0 * GIB).unwrap();
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(),
            |_| 2.0 * GIB, // each KV cache holds 2 GiB
            // violating while device 0 is above 90%
            |cl, _, _| cl.mem_frac(0) > 0.90,
        );
        assert!(out.resolved, "actions: {:?}", out.actions);
        assert!(out
            .actions
            .iter()
            .all(|a| matches!(a, Action::Migrated { .. })));
        assert_eq!(out.batch_size, 15, "phase 1 must not touch batch size");
        // first migration target is a KV cache (§3.3 ordering)
        if let Action::Migrated { module, .. } = &out.actions[0] {
            assert_eq!(module.kind, ModuleKind::KvCache);
        }
        // the planned ops execute cleanly and resolve the real violation
        let executed =
            PlanExecutor::new(&ops).execute(&mut cl, &mut pl, &out.plan).unwrap();
        assert_eq!(executed, out.cost, "executed cost == planned cost");
        assert!(cl.device(0).mem_frac() <= 0.90, "execution clears the violation");
        pl.validate(cl.n()).unwrap();
    }

    #[test]
    fn compute_pressure_prefers_attention_modules() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let mut calls = 0;
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Compute, 15,
            &ScaleDownConfig::default(), |_| 0.0,
            move |_, _, _| {
                calls += 1;
                calls <= 2 // clears after one migration
            },
        );
        assert!(out.resolved);
        if let Action::Migrated { module, .. } = &out.actions[0] {
            assert_eq!(module.kind, ModuleKind::Attn);
        } else {
            panic!("expected migration, got {:?}", out.actions[0]);
        }
    }

    #[test]
    fn phase2_evicts_replicas_when_migration_insufficient() {
        let (cm, mut cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // replicas ON device 0 belonging to a placement homed on device 1
        let mut pl = Placement::single_device(40, 1);
        for l in 0..4 {
            replicate(&ops, &mut cl, &mut pl, l, 0);
        }
        let mut violations = 6; // phase 1 (4 candidates) won't clear it
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(), |_| 0.0,
            move |_, _, _| {
                violations -= 1;
                violations > 0
            },
        );
        assert!(out.resolved);
        assert!(out.actions.iter().any(|a| matches!(a, Action::Evicted { .. })));
        assert_eq!(out.batch_size, 15);
    }

    #[test]
    fn phase3_reduces_batch_to_floor() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // never clears: every phase runs; batch walks 15 → 10 → 5 → 1
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(), |_| 0.0, |_, _, _| true,
        );
        assert!(!out.resolved);
        assert_eq!(out.batch_size, 1);
        let reductions: Vec<_> = out
            .actions
            .iter()
            .filter_map(|a| match a {
                Action::BatchReduced { from, to } => Some((*from, *to)),
                _ => None,
            })
            .collect();
        assert_eq!(reductions, vec![(15, 10), (10, 5), (5, 1)]);
        assert!(out.actions.iter().any(|a| matches!(a, Action::Offloaded { .. })));
    }

    #[test]
    fn batch_clears_mid_way() {
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 20,
            &ScaleDownConfig::default(), |_| 0.0,
            |_, _, bs| bs > 10,
        );
        assert!(out.resolved);
        assert_eq!(out.batch_size, 10);
    }

    #[test]
    fn graduated_cost_ordering() {
        // phase 1+2 must not reduce batch; only phase 3 does — the
        // "remediation with lower performance impact first" guarantee.
        let (cm, cl, pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let mut phase_seen = vec![];
        let out = scale_down(
            &ops, &cl, &pl, 0, Pressure::Memory, 15,
            &ScaleDownConfig::default(), |_| 1.0 * GIB,
            |_, _, _| true,
        );
        for a in &out.actions {
            phase_seen.push(match a {
                Action::Migrated { .. } => 1,
                Action::Evicted { .. } => 2,
                Action::BatchReduced { .. } | Action::Offloaded { .. } => 3,
            });
        }
        let mut sorted = phase_seen.clone();
        sorted.sort_unstable();
        assert_eq!(phase_seen, sorted, "phases out of order: {phase_seen:?}");
    }

    #[test]
    fn evictee_order_prefers_high_degree() {
        let (cm, mut cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let mut pl = Placement::single_device(40, 1);
        replicate(&ops, &mut cl, &mut pl, 5, 0);
        replicate(&ops, &mut cl, &mut pl, 6, 0);
        replicate(&ops, &mut cl, &mut pl, 6, 2); // degree 3
        let ev = sort_evictees(&pl, 0);
        assert_eq!(ev[0], 6, "highest-degree replica evicted first");
    }

    #[test]
    fn destination_keeps_headroom() {
        let mut cl = Cluster::paper_testbed();
        cl.device_mut(1).alloc("x", 35.0 * GIB).unwrap();
        cl.device_mut(2).alloc("x", 20.0 * GIB).unwrap();
        cl.device_mut(3).alloc("x", 39.0 * GIB).unwrap();
        let dst = find_optimal_destination(&cl, 0, 500.0 * MIB, 0.1).unwrap();
        assert_eq!(dst, 2, "most-free eligible device");
        // nothing fits a 30 GiB payload with 10% headroom except… nothing
        assert_eq!(find_optimal_destination(&cl, 0, 30.0 * GIB, 0.1), None);
    }
}
