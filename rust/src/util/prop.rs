//! Tiny property-testing harness (std-only `proptest` replacement).
//!
//! Runs a property over many PRNG-generated cases; on failure it reports the
//! case index and seed so the exact case replays with
//! `PROP_SEED=<seed> PROP_CASE=<i> cargo test <name>`. Used by the
//! coordinator invariant tests (placement validity, scheduler conservation,
//! KV-cache accounting, autoscaler monotonicity).

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0C0_5E21)
}

/// Run `prop` over `default_cases()` generated cases.
///
/// `gen` draws a case from the PRNG; `prop` returns `Err(reason)` to fail.
/// Panics with the replay seed/case on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = base_seed();
    let only: Option<usize> = std::env::var("PROP_CASE")
        .ok()
        .and_then(|s| s.parse().ok());
    let cases = default_cases();
    for i in 0..cases {
        if let Some(o) = only {
            if i != o {
                continue;
            }
        }
        // Independent stream per case: failures replay without running
        // the preceding cases.
        let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property `{name}` failed on case {i}/{cases}: {msg}\n\
                 case: {case:?}\n\
                 replay: PROP_SEED={seed} PROP_CASE={i}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("tautology", |r| r.below(100), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property `find-big` failed")]
    fn fails_and_reports_case() {
        check(
            "find-big",
            |r| r.below(1000),
            |&x| if x < 500 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = vec![];
        check("collect", |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("collect", |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
