//! Sharded-kernel parity: `SimConfig::shards ≥ 2` must be **byte-identical**
//! to the sequential kernel on every scenario.
//!
//! The sharded kernel partitions instance-local events into per-shard
//! queues (`instance % shards`) and drains epoch windows in parallel
//! between coordinator barriers; the merged stream must replay the exact
//! sequential order (time → kind-priority → instance-id → FIFO). These
//! tests assert the strongest observable form of that contract: the full
//! metrics JSON — latency histograms, routing counters, op-event logs,
//! billing integrals, placement vectors — compared as raw bytes
//! (`Vec<u8>`), for fixed fleets, elastic fleets, and the predictive
//! control plane, across the five workload scenarios.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, RoutePolicy, RouterConfig};
use cocoserve::forecast::PredictConfig;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, Simulation};
use cocoserve::workload::Trace;

/// Run one scenario at a given shard count and return the golden bytes.
fn golden(shards: usize, setup: FleetSetup, trace: &Trace, duration_s: f64) -> Vec<u8> {
    let mut cfg = SimConfig::paper_13b();
    cfg.shards = shards;
    let n_devices = 5;
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..3)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % n_devices),
                baselines::cocoserve(32),
            )
        })
        .collect();
    let sim = Simulation::with_fleet(cfg, cluster, placements, setup);
    sim.run(trace, duration_s).to_json().to_string().into_bytes()
}

fn fixed_fleet() -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        ..Default::default()
    }
}

fn elastic_fleet() -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::KvHeadroom,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 5, baselines::cocoserve(32))),
        ..Default::default()
    }
}

fn predictive_fleet() -> FleetSetup {
    let mut setup = elastic_fleet();
    setup.predictor = Some(PredictConfig::default());
    setup
}

/// The headline acceptance test: on all five scenarios, shard counts
/// 2 and 4 reproduce the sequential kernel's metrics JSON byte-for-byte.
#[test]
fn sharded_kernel_is_byte_identical_on_all_scenarios() {
    for (name, trace) in Trace::scenario_sweep(18.0, 10.0, 77) {
        let setup = fixed_fleet();
        let seq = golden(1, setup, &trace, 10.0);
        for shards in [2, 4] {
            let sharded = golden(shards, setup, &trace, 10.0);
            assert_eq!(
                seq, sharded,
                "scenario {name}: shards={shards} diverged from sequential kernel"
            );
        }
    }
}

/// Elastic fleets exercise spin-up/drain (instances appearing mid-run,
/// so shard membership changes) — parity must survive that too.
#[test]
fn sharded_kernel_is_byte_identical_with_elastic_fleet() {
    for (name, trace) in Trace::scenario_sweep(20.0, 10.0, 91) {
        let setup = elastic_fleet();
        let seq = golden(1, setup, &trace, 10.0);
        let sharded = golden(3, setup, &trace, 10.0);
        assert_eq!(seq, sharded, "scenario {name}: elastic fleet diverged at shards=3");
    }
}

/// The predictive control plane adds `ForecastTick` barriers and
/// observation-order-sensitive estimators; burst is the scenario that
/// stresses forecast-driven scale-out hardest.
#[test]
fn sharded_kernel_is_byte_identical_with_predictor() {
    for (name, trace) in [
        ("burst", Trace::burst(24.0, 12.0, 13)),
        ("diurnal", Trace::diurnal(16.0, 12.0, 13)),
    ] {
        let setup = predictive_fleet();
        let seq = golden(1, setup, &trace, 12.0);
        for shards in [2, 8] {
            let sharded = golden(shards, setup, &trace, 12.0);
            assert_eq!(
                seq, sharded,
                "scenario {name}: predictive fleet diverged at shards={shards}"
            );
        }
    }
}

fn classed_fleet(policy: RoutePolicy) -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy,
            admission_limit: Some(64),
            be_admission_limit: Some(48),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 5, baselines::cocoserve(32))),
        ..Default::default()
    }
}

/// Class-aware routing adds parked-queue reordering, per-class admission
/// caps, and mid-step preemption — all of which must still merge into the
/// exact sequential event order under sharding. Cells: both class-aware
/// policies × both classed scenarios, shards ∈ {1, 4} compared as raw
/// golden bytes.
#[test]
fn sharded_kernel_is_byte_identical_with_class_aware_routing() {
    for (name, trace) in [
        ("two_tenant_classed", Trace::two_tenant_classed(18.0, 10.0, 77)),
        ("burst_classed", Trace::burst_classed(18.0, 10.0, 77)),
    ] {
        for policy in [RoutePolicy::StrictPriority, RoutePolicy::WeightedFair] {
            let setup = classed_fleet(policy);
            let seq = golden(1, setup, &trace, 10.0);
            let sharded = golden(4, setup, &trace, 10.0);
            assert_eq!(
                seq, sharded,
                "scenario {name}: {policy:?} diverged at shards=4"
            );
            assert!(
                String::from_utf8(seq).unwrap().contains("\"slo\":"),
                "scenario {name}: {policy:?} golden must carry the slo block"
            );
        }
    }
}

/// The classless no-op half of the contract: a classless policy run on a
/// class-tagged trace produces bytes identical to the same run on the
/// payload-equal untagged trace (`two_tenant_classed` and `two_tenant`
/// differ only in their tags), and neither document carries an `slo` key.
#[test]
fn classless_policy_ignores_class_tags_byte_for_byte() {
    let classed = Trace::two_tenant_classed(18.0, 10.0, 77);
    let classless = Trace::two_tenant(18.0, 10.0, 77);
    for setup in [fixed_fleet(), elastic_fleet()] {
        let tagged = golden(1, setup, &classed, 10.0);
        let untagged = golden(1, setup, &classless, 10.0);
        assert_eq!(
            tagged, untagged,
            "a classless policy must never observe the class tags"
        );
        assert!(
            !String::from_utf8(tagged).unwrap().contains("\"slo\":"),
            "classless golden must carry no slo key"
        );
    }
}

/// More shards than instances (each shard holds at most one instance)
/// is the degenerate-partition edge case.
#[test]
fn more_shards_than_instances_is_still_identical() {
    let trace = Trace::steady(18.0, 8.0, 5);
    let setup = fixed_fleet();
    let seq = golden(1, setup, &trace, 8.0);
    let sharded = golden(16, setup, &trace, 8.0);
    assert_eq!(seq, sharded, "shards=16 over 3 instances diverged");
}
