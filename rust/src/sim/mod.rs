//! Event-driven cluster simulator — the paper-scale experiment harness.
//!
//! Runs LLaMA-13B/70B-class instances over the A100-calibrated [`cluster`]
//! using the [`model::cost`] arithmetic for step latencies (roofline:
//! compute-bound prefill, memory-bound decode — §2.1), the real
//! [`scheduler`], [`placement`], [`ops`] and [`autoscale`] code paths, and
//! the [`kvcache`] allocators for memory accounting. This is the substrate
//! substitution documented in DESIGN.md: the tensors are not computed (that
//! is the tiny-model real path in [`engine`]), but every *decision* the
//! serving system makes — batching, placement, scaling, OOM handling — is
//! executed by the same code a real deployment would run.
//!
//! ### Kernel architecture
//!
//! The simulator is a discrete-event kernel, not a lockstep tick loop:
//!
//! * [`events`] — a deterministic binary-heap event queue (arrivals,
//!   controller ticks, scaling-op starts/completions, step completions,
//!   wake-ups), tie-broken by kind priority, instance id and FIFO order;
//! * [`instance`] — the per-instance serving state machine (prefill/decode
//!   roofline steps, KV admission, per-policy OOM handling, in-flight
//!   plan-op application);
//! * [`metrics`] — [`SimReport`] accounting plus the deterministic metrics
//!   JSON the golden-replay tests and benches assert on;
//! * this module — a thin orchestrator: it pops events, routes arrivals
//!   through the [`crate::coordinator`] router (`Routed` events, admission
//!   backpressure, OOM-shed re-routing), computes cross-instance
//!   contention, admits controller-planned [`crate::plan::ScalePlan`]s,
//!   runs the fleet controller (spin-up / drain-then-release, module-vs-
//!   instance arbitration) and — when a predictor is configured — the
//!   [`crate::forecast`] control plane (`ForecastTick` events feeding the
//!   streaming estimators; predictive proposals arbitrated against the
//!   reactive signal; forecast-gated drains), meters device-seconds, and
//!   asks ready instances to start their next step.
//!
//! ### In-flight scaling (the §3.1 non-disruption claim, made measurable)
//!
//! A controller tick runs the **pure planners** over the live state and
//! emits a plan; the kernel schedules one `OpStarted`/`OpCompleted` pair
//! per op, with durations from the plan's dry-run costing. Serving
//! continues while ops are in flight: replication never blocks the source
//! (only the §6.5 communication-setup barrier pauses the instance when
//! the plan lands), migration blocks new steps only while the moved
//! module is in transit, and a mid-flight failure rolls the whole plan
//! back. There is no global pause — scaling events interleave with
//! request completions in the event log, which is exactly what the
//! golden-replay suite asserts.
//!
//! [`cluster`]: crate::cluster
//! [`model::cost`]: crate::model::cost
//! [`scheduler`]: crate::scheduler
//! [`placement`]: crate::placement
//! [`ops`]: crate::ops
//! [`autoscale`]: crate::autoscale
//! [`kvcache`]: crate::kvcache
//! [`engine`]: crate::engine

pub mod events;
pub(crate) mod instance;
pub mod metrics;

pub use metrics::{AuditBlock, OpEvent, OpPhase, ScaleStats, SimReport, SloBlock};

use crate::autoscale::{
    memory_violation, scale_up, Controller, ControllerConfig, PlanCtx, PlannedDecision,
    ScaleDownConfig, ScaleUpConfig, ScaleUpPlan,
};
use crate::cluster::Cluster;
use crate::coordinator::fleet::{FleetPressure, ScaleOutChoice};
use crate::coordinator::{
    AuditKind, AuditLog, CostLedger, FleetConfig, FleetController, FleetEvent,
    FleetPhase, RouteCandidate, Router, RouterConfig,
};
use crate::forecast::{CapacityModel, PredictConfig, PredictiveController};
use crate::mempress::{MempressConfig, MempressReport};
use crate::model::cost::CostModel;
use crate::model::{ModelConfig, ModuleKind};
use crate::monitor::FleetInputs;
use crate::ops::ModuleOps;
use crate::placement::{Placement, PlacementProfile};
use crate::plan::{PlanCost, ScalePlan};
use crate::scheduler::SchedulerConfig;
use crate::telemetry::{
    DecisionAction, DecisionActor, MarkKind, OpSpanPhase, ReqPhase,
};
use crate::workload::{FailureSchedule, Request, Trace};

use events::{Event, EventKind, EventQueue, EventSink, ShardedEventQueue};
use instance::{FailRecovery, Instance, Lifecycle, OpOutcome, StepCtx, StepStart};

/// Serving-path pause when a replication plan lands (synchronization
/// barrier while dataflow hooks swap in; the weight copies themselves
/// overlap serving — §8 measures <3 % neighbour jitter).
pub const SYNC_PAUSE_S: f64 = 0.05;

/// Fraction of a decode step the SMs are actually busy (bandwidth-bound
/// GEMV) — the compute-utilization signal NVML reports in Fig. 2.
pub const DECODE_BUSY_FRACTION: f64 = 0.65;

/// Vacancy floor (`GetEligibleNodes`) the kernel's scale-up planning
/// uses, for both the per-instance controller and the fleet arbitration —
/// stricter than `ScaleUpConfig::default`'s 0.3 because replicas must
/// leave headroom for the serving KV growing next to them.
pub(crate) const SCALE_UP_MIN_VACANCY: f64 = 0.45;

/// Size of the intersection of two sorted, deduplicated device slices
/// (two-pointer merge — the allocation-free `BTreeSet::intersection`).
fn sorted_intersection_count(a: &[usize], b: &[usize]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// γ for Eq. 4 (Algorithm 1 / the capacity model): the configured value,
/// or derived from the cluster's device-0 constants for the homogeneous
/// default. One definition shared by the controller tick, the fleet
/// arbitration, and the predictive capacity model.
fn default_gamma(cfg: &SimConfig, cluster: &Cluster) -> f64 {
    cfg.gamma.unwrap_or_else(|| {
        let spec = &cluster.device(0).spec;
        crate::autoscale::speedup::gamma(
            0.3,
            spec.effective_flops(),
            cfg.model.d_model as f64,
            spec.link_bw,
        )
    })
}

/// What an instance does when a KV allocation hits device OOM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OomBehavior {
    /// HFT-like: the step fails; affected requests pay a heavy reload
    /// penalty and retry (the paper's 37 s latency cliff, Fig. 3).
    FailBatch,
    /// vLLM-like: preempt the newest sequences (drop + requeue) until the
    /// allocation fits.
    Preempt,
    /// CoCoServe: trigger Algorithm 2 (migrate KV / evict / reduce batch).
    ScaleDown,
}

/// Per-instance serving policy — baselines and CoCoServe differ only here.
#[derive(Debug, Clone, Copy)]
pub struct SimPolicy {
    /// Batching policy (continuous vs static) + batch bound.
    pub scheduler: SchedulerConfig,
    /// Paged (vLLM/CoCo) vs contiguous max-length (HFT) KV allocation.
    pub paged_kv: bool,
    /// Run the §5 controller loop (CoCoServe only).
    pub autoscale: bool,
    /// What a KV-admission OOM does under this policy.
    pub oom: OomBehavior,
}

/// Simulation-wide configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Architecture of the simulated model (layer count, dims).
    pub model: ModelConfig,
    /// bf16 at paper scale.
    pub dtype_bytes: usize,
    /// End-to-end latency SLO (seconds).
    pub slo_latency_s: f64,
    /// Controller tick period (seconds).
    pub controller_tick_s: f64,
    /// γ for Algorithm 1 (Eq. 4). Derived from cluster constants if None.
    pub gamma: Option<f64>,
    /// Penalty charged to requests caught in an HFT OOM (model reload —
    /// §2.3 reports 8–25 s for a 13B instance).
    pub oom_penalty_s: f64,
    /// Max sequences a device's KV pool aims to hold (HFT contiguous cap).
    pub max_seq_len: usize,
    /// Cap on layer replicas the auto-scaler may hold per instance — the
    /// cost/benefit knob behind Fig. 10's "+9% memory over HFT×2" point
    /// (unbounded harvesting would converge to full model copies).
    pub replica_budget: usize,
    /// Event-queue shards (instance groups drained between coordinator
    /// barriers). `1` (the default everywhere) runs the single-queue
    /// sequential loop; `≥ 2` runs the epoch-barrier sharded kernel,
    /// whose golden metrics JSON is byte-identical to the sequential
    /// one — asserted per scenario in `rust/tests/shard_parity.rs` and
    /// by the CI smoke step.
    pub shards: usize,
    /// Memory-pressure governor (None = ungoverned — instances mirror
    /// their live KV reservation with an unbounded pool, no `mempress`
    /// key appears in the metrics JSON, and every golden replay is
    /// byte-identical to the pre-governor kernel). Some = each instance
    /// pre-grants a finite KV pool and walks the §2.3 escalation ladder
    /// (grow/shrink pool → int8 layer swaps → wait → shed) before any
    /// request is shed.
    pub mempress: Option<MempressConfig>,
    /// Deterministic tracing & telemetry (None = off — the kernel
    /// records nothing, instances push nothing, and every golden metrics
    /// document stays byte-identical to the pre-telemetry kernel; see
    /// `rust/tests/telemetry.rs`). Some = request/op/step spans, decision
    /// records and the streaming timeline are recorded in simulation
    /// time, so the exported trace replays byte-identically across runs
    /// and shard counts.
    pub telemetry: Option<crate::telemetry::TelemetryConfig>,
}

impl SimConfig {
    /// The single construction site of the simulator's [`CostModel`]:
    /// [`Simulation::new`] builds it once here and shares it by reference
    /// (through [`instance::StepCtx`]) with every instance, planner and
    /// test fixture.
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.model.clone())
    }

    /// The paper's primary 13B experiment shape (§6.1 constants).
    pub fn paper_13b() -> SimConfig {
        SimConfig {
            model: ModelConfig::llama2_13b(),
            dtype_bytes: 2,
            slo_latency_s: 15.0,
            controller_tick_s: 1.0,
            gamma: None,
            oom_penalty_s: 12.0,
            max_seq_len: 512,
            replica_budget: 12,
            shards: 1,
            mempress: None,
            telemetry: None,
        }
    }

    /// The 70B variant: same knobs over the larger architecture.
    pub fn paper_70b() -> SimConfig {
        SimConfig { model: ModelConfig::llama2_70b(), ..SimConfig::paper_13b() }
    }
}

/// Coordinator wiring for a simulation run: routing policy, optional
/// fleet autoscaling, and the per-instance §5 controller thresholds. The
/// default is the pre-fleet behaviour — least-outstanding routing, no
/// admission limit, no instance lifecycle management.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetSetup {
    /// Routing policy + admission backpressure + shed re-routing.
    pub router: RouterConfig,
    /// Fleet-level instance autoscaling (None = fixed fleet).
    pub fleet: Option<FleetConfig>,
    /// Threshold configuration of the per-instance controllers.
    pub controller: ControllerConfig,
    /// Predictive control plane (None = reactive only — the kernel then
    /// schedules no `ForecastTick` events and the metrics JSON is
    /// byte-identical to the pre-forecast kernel). Predictive capacity
    /// actions require `fleet` to be configured too; without it the
    /// forecaster still runs and reports, but proposes nothing.
    pub predictor: Option<PredictConfig>,
}

/// The simulator: an event kernel over per-instance state machines.
pub struct Simulation {
    /// Simulation-wide configuration the kernel was built with.
    pub cfg: SimConfig,
    /// The device ledgers every instance allocates against.
    pub cluster: Cluster,
    cost: CostModel,
    instances: Vec<Instance>,
    controller: Controller,
    /// The coordinator's request router (front door of the fleet).
    router: Router,
    /// Fleet-level lifecycle controller (None = fixed fleet).
    fleet: Option<FleetController>,
    /// Predictive control plane (None = reactive only).
    predictive: Option<PredictiveController>,
    /// Device-seconds cost meter.
    ledger: CostLedger,
    /// Per-instance (placement_rev, billed device set) — the ledger's
    /// incremental-update cache.
    bill_cache: Vec<(u64, Vec<usize>)>,
    /// Timestamped fleet lifecycle log (spin-up / drain / release).
    fleet_events: Vec<FleetEvent>,
    /// Seed-deterministic device-failure schedule (empty = no failures —
    /// the kernel schedules no `DeviceFailed` events and every golden
    /// stays byte-identical to the pre-failure-domain kernel).
    failures: FailureSchedule,
    /// Append-only audit trail (`Some` iff a failure schedule is
    /// configured — the strictly additive `audit` key of the metrics
    /// JSON).
    audit: Option<AuditLog>,
    now: f64,
    scale: ScaleStats,
    peak_mem: f64,
    /// Events popped off the queue (fleet-scale bench throughput metric).
    events_processed: u64,
    /// Serving steps started (prefill + decode) across the fleet.
    steps_started: u64,
    /// Deterministic span/decision/timeline recorder (disabled — and
    /// free — unless `SimConfig::telemetry` is set).
    tracer: crate::telemetry::Tracer,
}

impl Simulation {
    /// Build a simulation: each entry of `placements` is one instance with
    /// its policy; instance weights are deployed onto the ledgers. Uses
    /// the default [`FleetSetup`] (legacy least-outstanding routing, no
    /// fleet autoscaling).
    pub fn new(
        cfg: SimConfig,
        cluster: Cluster,
        placements: Vec<(Placement, SimPolicy)>,
    ) -> Simulation {
        Simulation::with_fleet(cfg, cluster, placements, FleetSetup::default())
    }

    /// Build a simulation with explicit coordinator wiring (routing
    /// policy, fleet autoscaling, controller thresholds).
    pub fn with_fleet(
        cfg: SimConfig,
        cluster: Cluster,
        placements: Vec<(Placement, SimPolicy)>,
        setup: FleetSetup,
    ) -> Simulation {
        let cost = cfg.cost_model();
        let mut cluster = cluster;
        let reroute = setup.router.reroute_on_shed;
        let preempt = setup.router.policy.class_aware();
        let instances: Vec<Instance> = placements
            .into_iter()
            .enumerate()
            .map(|(i, (placement, policy))| {
                let mut inst = Instance::deploy(i, placement, policy, &cfg, &cost, &mut cluster);
                inst.reroute_shed = reroute;
                inst.preempt_premium = preempt;
                inst
            })
            .collect();
        let mut ledger = CostLedger::new(cluster.n());
        let bill_cache: Vec<(u64, Vec<usize>)> = instances
            .iter()
            .map(|inst| {
                let devs = inst.profile.device_set.clone();
                for &d in &devs {
                    ledger.acquire(d);
                }
                (inst.placement_rev, devs)
            })
            .collect();
        // The predictor's capacity conversion is derived from the same
        // cost model and compiled step costs the kernel charges serving
        // steps with — one costing path (see forecast::capacity).
        let predictive = setup.predictor.map(|pc| {
            let reference = Placement::single_device(cfg.model.n_layers, 0);
            let profile = PlacementProfile::compile(&reference, &cluster, 0);
            let cap = CapacityModel::from_profile(
                &cost,
                &profile,
                cfg.dtype_bytes,
                pc.batch,
                pc.mean_prompt,
                pc.mean_output,
                default_gamma(&cfg, &cluster),
                pc.target_util,
            );
            PredictiveController::new(pc, cap)
        });
        let tracer = crate::telemetry::Tracer::new(cfg.telemetry.as_ref());
        Simulation {
            cfg,
            cluster,
            cost,
            instances,
            controller: Controller::new(setup.controller),
            router: Router::new(setup.router),
            fleet: setup.fleet.map(FleetController::new),
            predictive,
            ledger,
            bill_cache,
            fleet_events: Vec::new(),
            failures: FailureSchedule::default(),
            audit: None,
            now: 0.0,
            scale: ScaleStats::default(),
            peak_mem: 0.0,
            events_processed: 0,
            steps_started: 0,
            tracer,
        }
    }

    /// Configure a seed-deterministic device-failure schedule. A
    /// non-empty schedule arms the append-only audit trail: every module
    /// op, failure, recovery decision and rollback from here on lands as
    /// a structured record under the metrics JSON's `audit` key. An
    /// empty schedule is a no-op (no `DeviceFailed` events, no `audit`
    /// key — byte-identical goldens).
    pub fn with_failures(mut self, schedule: FailureSchedule) -> Simulation {
        if !schedule.is_empty() {
            self.audit = Some(AuditLog::new());
        }
        self.failures = schedule;
        self
    }

    /// Append one audit record (no-op without a failure schedule).
    fn audit_push(
        &mut self,
        kind: AuditKind,
        instance: Option<usize>,
        device: Option<usize>,
        detail: impl Into<String>,
    ) {
        if let Some(log) = &mut self.audit {
            log.push(self.now, kind, instance, device, detail);
        }
    }

    fn gamma(&self) -> f64 {
        default_gamma(&self.cfg, &self.cluster)
    }

    // ---- routing (the coordinator's front door) ---------------------------

    /// Instance `i`'s outstanding load: scheduler pending + running, plus
    /// requests already routed this timestamp but not yet delivered (the
    /// in-flight count lives on the instance itself, next to the rest of
    /// its shard-local state). The one load definition behind routing
    /// decisions and the fleet telemetry window, so coinciding decisions
    /// observe each other and the controllers read the numbers the
    /// router acts on.
    fn outstanding(&self, i: usize) -> usize {
        self.instances[i].scheduler.load() + self.instances[i].outstanding_routes as usize
    }

    /// Snapshot every instance's routing-relevant state for one decision.
    fn route_candidates(&self) -> Vec<RouteCandidate> {
        self.instances
            .iter()
            .enumerate()
            .map(|(i, inst)| RouteCandidate {
                accepting: inst.accepting(self.now),
                outstanding: self.outstanding(i),
                free_bytes: inst
                    .profile
                    .device_set
                    .iter()
                    .map(|&d| self.cluster.device(d).free_bytes())
                    .sum(),
            })
            .collect()
    }

    /// Route one arrival: pick an instance and schedule its `Routed`
    /// delivery at the current time, or park the request under admission
    /// backpressure.
    fn route_arrival(&mut self, request_idx: usize, req: Request, q: &mut dyn EventSink) {
        let cands = self.route_candidates();
        match self.router.pick(&cands, req.class) {
            Some(i) => {
                self.router.routes += 1;
                self.router.class_routes[Router::class_idx(req.class)] += 1;
                self.instances[i].outstanding_routes += 1;
                self.tracer.req(self.now, req.id, i as i64, ReqPhase::Routed);
                q.push(self.now, EventKind::Routed { request_idx, instance: i });
            }
            None => {
                self.tracer.req(self.now, req.id, -1, ReqPhase::Parked);
                self.router.park(req, 0.0, false);
            }
        }
    }

    /// Hand requests shed by OOM handling back through the router
    /// (re-route), parking them if no instance admits right now. The
    /// shedding instance is excluded from its own re-route pick — the
    /// point of shedding is to move the request *away* from the OOMing
    /// instance; parked overflow may still return to it at a later event
    /// when nothing else admits.
    fn collect_shed(&mut self) {
        for i in 0..self.instances.len() {
            if self.instances[i].shed_outbox.is_empty() {
                continue;
            }
            let shed = std::mem::take(&mut self.instances[i].shed_outbox);
            for s in shed {
                // The shed record carries the request's SLO class and
                // accumulated penalty — both must survive the re-route
                // (FailBatch, DeviceFailed, and preemption all funnel
                // through here), or class-aware policies would silently
                // demote re-routed premium work.
                let req = Request {
                    id: s.id,
                    arrival_s: s.arrival_s,
                    prompt_tokens: s.prompt_tokens,
                    output_tokens: s.output_tokens,
                    class: s.class,
                };
                let phase = match s.cause {
                    crate::telemetry::ShedCause::SloPreempt => ReqPhase::Preempted,
                    _ => ReqPhase::Shed,
                };
                self.tracer.req(self.now, req.id, i as i64, phase);
                let mut cands = self.route_candidates();
                cands[i].accepting = false;
                match self.router.pick(&cands, req.class) {
                    Some(j) => {
                        self.router.reroutes += 1;
                        self.tracer.req(self.now, req.id, j as i64, ReqPhase::Rerouted);
                        self.instances[j].deliver(req, s.penalty);
                    }
                    None => {
                        self.tracer.req(self.now, req.id, -1, ReqPhase::Parked);
                        self.router.park(req, s.penalty, true);
                    }
                }
            }
        }
    }

    /// Retry parked requests until the policy's next pick fails to route.
    /// Classless policies serve the queue head (FIFO — the pre-SLO-class
    /// behaviour, byte-identical); class-aware policies let the router
    /// choose which parked entry goes next (strict priority or weighted
    /// fair queuing), and a failed route for *that* entry ends the drain.
    fn drain_parked(&mut self) {
        while let Some(idx) = self.router.next_parked() {
            let parked = self.router.pending[idx];
            let cands = self.route_candidates();
            let Some(i) = self.router.pick(&cands, parked.req.class) else { break };
            let parked = self.router.take_parked(idx);
            self.tracer.req(
                self.now,
                parked.req.id,
                i as i64,
                if parked.reroute { ReqPhase::Rerouted } else { ReqPhase::Admitted },
            );
            if parked.reroute {
                self.router.reroutes += 1;
            } else {
                self.router.routes += 1;
                self.router.class_routes[Router::class_idx(parked.req.class)] += 1;
                // a parked arrival delivers straight from the queue (no
                // Routed event), so this is where the forecaster sees it
                // — demand must not vanish from the rate signal exactly
                // when the fleet is saturated. Shed re-routes stay
                // excluded: same demand again, not new demand.
                if let Some(p) = &mut self.predictive {
                    p.forecaster.observe(self.now);
                    if self.router.cfg.policy.class_aware() {
                        p.forecaster.observe_class(parked.req.class);
                    }
                }
            }
            self.instances[i].deliver(parked.req, parked.penalty);
        }
    }

    // ---- device-seconds billing -------------------------------------------

    /// Reconcile the cost ledger with any placement that moved during this
    /// event (plan ops landing, rollbacks, emergency scale-downs). The
    /// ledger was already advanced to `now` at the event pop, so the
    /// refcount flip is exactly timed. O(1) per unmoved instance.
    fn sync_billing(&mut self) {
        for i in 0..self.instances.len() {
            let rev = self.instances[i].placement_rev;
            if self.bill_cache[i].0 == rev {
                continue;
            }
            if self.instances[i].lifecycle != Lifecycle::Retired {
                for &d in &self.instances[i].profile.device_set {
                    self.ledger.acquire(d);
                }
            }
            for &d in &self.bill_cache[i].1 {
                self.ledger.release(d);
            }
            let devs = if self.instances[i].lifecycle == Lifecycle::Retired {
                Vec::new()
            } else {
                self.instances[i].profile.device_set.clone()
            };
            self.bill_cache[i] = (rev, devs);
        }
    }

    /// A device died (spot preemption or hardware loss). In order:
    ///
    /// 1. the device's ledger clears and it refuses all future work
    ///    ([`crate::cluster::Device::fail`] — every placement/routing
    ///    filter skips it from here on);
    /// 2. its billing stops at exactly this instant (the cost ledger was
    ///    already advanced to `now` at the event pop), and the corpse is
    ///    stripped from every cached billing list so later reconciliation
    ///    never double-releases it;
    /// 3. every entangled instance repairs itself in ascending-id order
    ///    ([`Instance::recover_from_failure`]): in-flight plans roll back
    ///    via the undo log (never re-acquiring memory), dead replicas
    ///    drop, sole-copy modules emergency-migrate to survivors, live
    ///    requests shed to the router — or, when no survivor has room,
    ///    the instance force-releases with every tag freed;
    /// 4. the normal dispatch tail re-routes the shed requests
    ///    (`collect_shed` → `drain_parked`) — no request is lost.
    ///
    /// Every step appends a structured record to the audit trail.
    fn on_device_failed(&mut self, device: usize) {
        let lost = self.cluster.device_mut(device).fail();
        let holders = self.ledger.fail_device(device);
        for entry in &mut self.bill_cache {
            entry.1.retain(|&d| d != device);
        }
        self.audit_push(
            AuditKind::DeviceFailed,
            None,
            Some(device),
            format!("lost_bytes={lost:.0} holders={holders}"),
        );
        self.tracer.mark(self.now, -1, MarkKind::DeviceFailed, device as f64);
        for i in 0..self.instances.len() {
            if self.instances[i].lifecycle == Lifecycle::Retired {
                continue;
            }
            let ctx = StepCtx { cfg: &self.cfg, cost: &self.cost, now: self.now };
            let outcome = self.instances[i].recover_from_failure(
                &ctx,
                &mut self.cluster,
                device,
                &mut self.scale,
            );
            match outcome {
                FailRecovery::Untouched => {}
                FailRecovery::Recovered {
                    plan_aborted,
                    replicas_dropped,
                    promoted,
                    migrated,
                    shed,
                } => {
                    if plan_aborted {
                        self.audit_push(
                            AuditKind::PlanRollback,
                            Some(i),
                            Some(device),
                            "in-flight plan rolled back (no re-acquire)",
                        );
                        self.tracer.mark(self.now, i as i64, MarkKind::Rollback, device as f64);
                    }
                    for l in replicas_dropped {
                        self.audit_push(
                            AuditKind::ReplicaDropped,
                            Some(i),
                            Some(device),
                            format!("L{l}"),
                        );
                    }
                    for (l, dst) in promoted {
                        self.audit_push(
                            AuditKind::EmergencyMigration,
                            Some(i),
                            Some(dst),
                            format!("promote L{l}->d{dst}"),
                        );
                    }
                    for (desc, dst, bytes) in migrated {
                        self.audit_push(
                            AuditKind::EmergencyMigration,
                            Some(i),
                            Some(dst),
                            format!("refetch {desc}->d{dst} bytes={bytes:.0}"),
                        );
                    }
                    if shed > 0 {
                        self.audit_push(
                            AuditKind::RequestsShed,
                            Some(i),
                            None,
                            format!("shed={shed}"),
                        );
                    }
                }
                FailRecovery::Lost { plan_aborted, shed } => {
                    if plan_aborted {
                        self.audit_push(
                            AuditKind::PlanRollback,
                            Some(i),
                            Some(device),
                            "in-flight plan rolled back (no re-acquire)",
                        );
                        self.tracer.mark(self.now, i as i64, MarkKind::Rollback, device as f64);
                    }
                    if shed > 0 {
                        self.audit_push(
                            AuditKind::RequestsShed,
                            Some(i),
                            None,
                            format!("shed={shed}"),
                        );
                    }
                    self.audit_push(
                        AuditKind::ForcedRelease,
                        Some(i),
                        None,
                        "released outside drain protocol",
                    );
                    self.audit_push(
                        AuditKind::InstanceLost,
                        Some(i),
                        Some(device),
                        "no surviving device had room",
                    );
                    // force_release retires without bumping the placement
                    // revision — settle its billing here, not in
                    // sync_billing
                    for &d in &self.bill_cache[i].1 {
                        self.ledger.release(d);
                    }
                    self.bill_cache[i] =
                        (self.instances[i].placement_rev, Vec::new());
                }
            }
        }
    }

    /// Device contention factor: overlap-weighted slowdown from other
    /// instances' in-flight steps. An instance whose device set overlaps
    /// ours by a fraction f contributes +f (full co-location doubles step
    /// time; a single shared device out of four adds 25%). This yields the
    /// §8 behaviour: spread replicas barely perturb neighbours.
    ///
    /// Runs on every step start, so the device sets come precompiled
    /// (sorted, deduplicated) from the instances' placement profiles and
    /// the overlap is a two-pointer merge — no per-call set construction.
    fn contention(&self, inst_id: usize) -> f64 {
        let mine = &self.instances[inst_id].profile.primary_set;
        let mut factor = 1.0;
        for other in &self.instances {
            if other.id == inst_id || other.busy_until.is_none() {
                continue;
            }
            let shared = sorted_intersection_count(mine, &other.profile.device_set);
            if shared > 0 {
                factor += shared as f64 / mine.len().max(1) as f64;
            }
        }
        factor
    }

    /// One §5 control tick: run the planners for every autoscaling
    /// instance and admit emitted plans for in-flight execution.
    fn controller_tick(&mut self, q: &mut dyn EventSink) {
        for i in 0..self.instances.len() {
            if !self.instances[i].policy.autoscale
                || self.instances[i].lifecycle != Lifecycle::Active
            {
                continue;
            }
            // one plan in flight per instance — its execution is the
            // natural cooldown for further background scaling
            if self.instances[i].inflight.is_some() {
                continue;
            }
            let view = {
                let cluster = &self.cluster;
                self.instances[i].monitor.controller_view(cluster, self.now.max(1e-9))
            };
            // stage 1 (thresholds + cooldown) is cheap; the planning
            // context is only assembled when the controller wants to act
            let decision = self.controller.decide(&view);
            if matches!(decision, crate::autoscale::Decision::None) {
                continue;
            }
            let gamma = self.gamma();
            let held: usize = (0..self.instances[i].placement.n_layers)
                .map(|l| self.instances[i].placement.degree(l) - 1)
                .sum();
            let remaining = self.cfg.replica_budget.saturating_sub(held);
            let hot = self.instances[i].hottest_primary_device(&self.cluster);
            let kv_per_layer = self.instances[i].kv.stats().reserved_bytes
                / self.instances[i].placement.n_layers as f64;
            let slo = self.cfg.slo_latency_s;
            let ops =
                ModuleOps::new(&self.cost, self.cfg.dtype_bytes, &format!("inst{i}"));
            let ctx = PlanCtx {
                ops: &ops,
                cluster: &self.cluster,
                placement: &self.instances[i].placement,
                up_cfg: ScaleUpConfig {
                    gamma,
                    min_vacancy: SCALE_UP_MIN_VACANCY,
                    max_ops_per_round: remaining,
                },
                down_cfg: ScaleDownConfig::default(),
                batch_size: self.instances[i].batch_size,
                kv_bytes_per_layer: kv_per_layer,
                down_src: Some(hot),
            };
            let planned = self.controller.plan(decision, &ctx, memory_violation(hot, slo));
            match planned {
                PlannedDecision::None => {}
                PlannedDecision::ScaleUp(up) => {
                    self.scale.scale_ups += 1;
                    self.admit(i, up.plan, up.cost, None, q);
                }
                PlannedDecision::ScaleDown(down) => {
                    self.scale.scale_downs += 1;
                    self.admit(i, down.plan, down.cost, Some(down.batch_size), q);
                }
            }
        }
    }

    // ---- fleet lifecycle (spin-up / drain / release) ----------------------

    /// One fleet-controller tick: release drained instances, read the
    /// aggregate pressure signal, and scale out (module replication vs.
    /// whole-instance spin-up, arbitrated by dry-run cost) or drain.
    /// Runs before the per-instance controllers on every `ControllerTick`.
    fn fleet_tick(&mut self, q: &mut dyn EventSink) {
        if self.fleet.is_none() {
            return;
        }
        // 1. drain-then-release: a draining instance that emptied out
        //    frees every ledger allocation; its devices stop billing now.
        for i in 0..self.instances.len() {
            if self.instances[i].lifecycle == Lifecycle::Draining && self.instances[i].drained() {
                self.instances[i].release(&mut self.cluster);
                for &d in &self.bill_cache[i].1 {
                    self.ledger.release(d);
                }
                self.bill_cache[i] = (self.instances[i].placement_rev, Vec::new());
                self.tracer.mark(self.now, i as i64, MarkKind::Release, 0.0);
                self.fleet_events.push(FleetEvent {
                    t: self.now,
                    instance: i,
                    phase: FleetPhase::Release,
                });
            }
        }
        // 2. telemetry spine: one FleetInputs window per tick (assembled
        //    through the monitor's fleet-signal type), shared by the
        //    reactive pressure classifier and the predictive controller.
        let mut inputs = FleetInputs::default();
        for i in 0..self.instances.len() {
            let inst = &self.instances[i];
            inputs.add_instance(
                inst.lifecycle != Lifecycle::Retired,
                inst.accepting(self.now),
                self.outstanding(i),
            );
        }
        inputs.parked = self.router.pending.len();
        // Class-aware fleets split the window per class: the premium
        // fields feed the premium-first pressure walk below. Classless
        // fleets leave them zero and take the exact pre-SLO-class path.
        let class_aware = self.router.cfg.policy.class_aware();
        if class_aware {
            inputs.premium_parked =
                self.router.parked_of(crate::workload::SloClass::LatencySensitive);
            for inst in &self.instances {
                if inst.lifecycle != Lifecycle::Retired {
                    inputs.premium_outstanding += inst.premium_live();
                }
            }
        }
        // 3. arbitration (precedence documented in DESIGN.md): a live
        //    ScaleOut always wins; a live ScaleIn is forecast-gated; the
        //    Hold band is where predictive proposals act. The cooldown
        //    snapshot is taken BEFORE pressure() decrements it, so a
        //    predictive action observes the same spacing a reactive one
        //    would — the shared window has no off-by-one tick.
        let was_cooling = self.fleet.as_ref().expect("fleet").cooling_down();
        let pressure = if class_aware {
            self.fleet.as_mut().expect("fleet").pressure_classed(&inputs)
        } else {
            self.fleet.as_mut().expect("fleet").pressure(&inputs)
        };
        match pressure {
            FleetPressure::Hold => {
                self.tracer.decision(
                    self.now,
                    DecisionActor::Fleet,
                    DecisionAction::Hold,
                    -1,
                    inputs.mean_outstanding(),
                    0.0,
                    -1.0,
                    -1.0,
                );
                if !was_cooling {
                    self.predictive_tick(&inputs, q);
                }
            }
            FleetPressure::ScaleOut => self.fleet_scale_out(&inputs, q),
            FleetPressure::ScaleIn => self.fleet_scale_in(&inputs),
        }
    }

    /// Reactive scale-in: drain the least-loaded active instance (ties
    /// drain the youngest — LIFO elasticity, deterministic), unless the
    /// predictor says its capacity is needed again within the drain
    /// horizon (a cold start plus margin — what re-acquiring the
    /// capacity would cost).
    fn fleet_scale_in(&mut self, inputs: &FleetInputs) {
        let cand = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, inst)| inst.lifecycle == Lifecycle::Active)
            .min_by_key(|&(i, inst)| (inst.scheduler.load(), std::cmp::Reverse(i)))
            .map(|(i, _)| i);
        let Some(i) = cand else { return };
        if self.predictive.is_some() {
            let fc = self.fleet.as_ref().expect("fleet mode").cfg;
            let horizon = fc.cold_start_s
                + self.predictive.as_ref().expect("predictor").cfg.drain_margin_s;
            let after = self.capacity_equivalents_at(horizon, Some(i));
            if self.predictive.as_ref().expect("predictor").block_drain(after, horizon) {
                self.predictive.as_mut().expect("predictor").stats.drain_vetoes += 1;
                self.tracer.decision(
                    self.now,
                    DecisionActor::Predictive,
                    DecisionAction::DrainVetoed,
                    i as i64,
                    inputs.mean_outstanding(),
                    self.predictive.as_ref().expect("predictor").deficit_at(horizon, after),
                    -1.0,
                    -1.0,
                );
                // the drain never happened: hand the reactive cooldown
                // back so the veto of a no-op cannot suppress the very
                // predictive provisioning the forecast calls for
                self.fleet.as_mut().expect("fleet mode").cancel_action();
                return;
            }
        }
        self.instances[i].lifecycle = Lifecycle::Draining;
        self.tracer.decision(
            self.now,
            DecisionActor::Fleet,
            DecisionAction::DrainInstance,
            i as i64,
            inputs.mean_outstanding(),
            0.0,
            -1.0,
            -1.0,
        );
        self.tracer.mark(self.now, i as i64, MarkKind::Drain, 0.0);
        self.fleet_events.push(FleetEvent {
            t: self.now,
            instance: i,
            phase: FleetPhase::Drain,
        });
    }

    /// Serving capacity in instance-equivalents *as of* `horizon_s`
    /// seconds from now: each active instance that will be past its cold
    /// start by then contributes its Eq. 4 speedup (1.0 unreplicated),
    /// optionally excluding one instance (drain what-if). Counting
    /// capacity at the horizon — not just what accepts right now — is
    /// what stops the predictive controller re-spinning for a deficit an
    /// in-flight cold start already covers. Predictor-only (the capacity
    /// conversion lives there). On a heterogeneous fleet each instance is
    /// weighted by its pipeline-bottleneck speed factor
    /// ([`CapacityModel::device_equivalents`]) — a V100-hosted instance
    /// counts for less than an H100 one, so deficit math and drain gating
    /// stay honest on mixed generations. Homogeneous fleets get a factor
    /// of exactly 1.0 (bit-identical to the unweighted sum).
    fn capacity_equivalents_at(&self, horizon_s: f64, exclude: Option<usize>) -> f64 {
        let cap = &self.predictive.as_ref().expect("predictor").cap;
        let by = self.now + horizon_s + 1e-9;
        self.instances
            .iter()
            .enumerate()
            .filter(|&(i, inst)| {
                Some(i) != exclude
                    && inst.lifecycle == Lifecycle::Active
                    && inst.active_after <= by
            })
            .map(|(_, inst)| {
                cap.device_equivalents(
                    inst.placement.inv_p_norm(),
                    inst.profile.min_eff_flops(),
                )
            })
            .sum()
    }

    /// One predictive control tick (the Hold band of the arbitration):
    /// compare forecasted demand against live capacity at each action's
    /// own enactment latency and enact what the lead time allows —
    /// replication (horizon = the plan's dry-run duration) bridges an
    /// imminent deficit, spin-up (horizon = `cold_start_s`) covers a
    /// sustained one, and a burst may need both in the same tick.
    /// Proposals are subject to the reactive veto; enactments arm the
    /// shared fleet cooldown.
    fn predictive_tick(&mut self, inputs: &FleetInputs, q: &mut dyn EventSink) {
        if self.predictive.is_none() || self.fleet.is_none() {
            return;
        }
        if self.fleet.as_ref().expect("fleet mode").cooling_down() {
            return;
        }
        let fc = self.fleet.as_ref().expect("fleet mode").cfg;
        // each deficit compares demand at a horizon against the capacity
        // that will be live AT that horizon — an instance already cold-
        // starting counts toward the spin-horizon capacity, so one
        // deficit cannot trigger a redundant second spin-up
        let bucket_s = self.predictive.as_ref().expect("predictor").cfg.bucket_s;
        let cap_spin = self.capacity_equivalents_at(fc.cold_start_s, None);
        let cap_next = self.capacity_equivalents_at(bucket_s, None);
        // Premium-first planning: under a class-aware policy the deficit
        // of the latency-sensitive class alone (judged against its
        // immediate capacity claim) is a first-class spin trigger, with
        // its own lower floor. Exactly 0.0 for classless configs — the
        // guard below and the veto max are then bit-identical to the
        // pre-SLO-class arithmetic.
        let premium_deficit = if self.router.cfg.policy.class_aware() {
            self.predictive
                .as_ref()
                .expect("predictor")
                .premium_deficit_at(fc.cold_start_s, cap_spin)
                .max(0.0)
        } else {
            0.0
        };
        let (deficit_spin, deficit_next) = {
            let p = self.predictive.as_ref().expect("predictor");
            (
                p.deficit_at(fc.cold_start_s, cap_spin),
                p.deficit_at(bucket_s, cap_next),
            )
        };
        if deficit_spin <= 0.0 && deficit_next <= 0.0 && premium_deficit <= 0.0 {
            return;
        }
        {
            let p = self.predictive.as_mut().expect("predictor");
            p.stats.proposed += 1;
            if p.reactive_veto(
                inputs.mean_outstanding(),
                fc.scale_in_queue,
                deficit_spin.max(deficit_next).max(premium_deficit),
            ) {
                p.stats.vetoed += 1;
                self.tracer.decision(
                    self.now,
                    DecisionActor::Predictive,
                    DecisionAction::PredictiveVetoed,
                    -1,
                    inputs.mean_outstanding(),
                    deficit_spin.max(deficit_next).max(premium_deficit),
                    -1.0,
                    -1.0,
                );
                return;
            }
        }
        let mut acted = false;
        // replication first: its lead time is the plan's own dry-run
        // duration, priced exactly as the kernel schedules the op events
        if let Some((i, up)) = self.replication_option() {
            let h_rep = up.cost.total.time_s;
            let cap_rep = self.capacity_equivalents_at(h_rep, None);
            let deficit_rep = self
                .predictive
                .as_ref()
                .expect("predictor")
                .deficit_at(h_rep, cap_rep);
            if deficit_rep > 0.0 {
                self.scale.scale_ups += 1;
                self.tracer.decision(
                    self.now,
                    DecisionActor::Predictive,
                    DecisionAction::PredictedReplicate,
                    i as i64,
                    inputs.mean_outstanding(),
                    deficit_rep,
                    h_rep,
                    fc.cold_start_s,
                );
                self.admit(i, up.plan, up.cost, None, q);
                acted = true;
            }
        }
        // spin-up covers a deficit at least an instance-equivalent deep
        // at its own lead time (cold_start_s — activation is gated on
        // exactly that)
        let spin_floor = self.predictive.as_ref().expect("predictor").cfg.spin_deficit_eq;
        if deficit_spin >= spin_floor && inputs.live < fc.max_instances {
            if let Some(dev) = self.spin_candidate() {
                self.tracer.decision(
                    self.now,
                    DecisionActor::Predictive,
                    DecisionAction::PredictedSpinUp,
                    self.instances.len() as i64,
                    inputs.mean_outstanding(),
                    deficit_spin,
                    fc.cold_start_s,
                    -1.0,
                );
                self.spin_up(dev, q);
                acted = true;
            }
        }
        // premium-first spin: a latency-sensitive deficit past its (lower)
        // floor warrants the instance even when the mixed deficit is too
        // shallow — the premium class's SLO is planned against first
        let premium_floor =
            self.predictive.as_ref().expect("predictor").cfg.premium_spin_deficit_eq;
        if !acted && premium_deficit >= premium_floor && inputs.live < fc.max_instances {
            if let Some(dev) = self.spin_candidate() {
                self.tracer.decision(
                    self.now,
                    DecisionActor::Predictive,
                    DecisionAction::PredictedSpinUp,
                    self.instances.len() as i64,
                    inputs.mean_outstanding(),
                    premium_deficit,
                    fc.cold_start_s,
                    -1.0,
                );
                self.spin_up(dev, q);
                acted = true;
            }
        }
        if acted {
            self.fleet.as_mut().expect("fleet mode").arm_cooldown();
            self.predictive.as_mut().expect("predictor").stats.enacted += 1;
        }
    }

    /// Option A of any scale-out: one Algorithm 1 replication round on
    /// the busiest accepting instance that still has replica budget and
    /// no plan in flight. The returned plan carries its dry-run cost —
    /// both the arbitration price and (for the predictive path) the
    /// action's lead time.
    fn replication_option(&self) -> Option<(usize, ScaleUpPlan)> {
        let busiest = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, inst)| inst.accepting(self.now) && inst.inflight.is_none())
            .max_by_key(|&(i, inst)| (inst.scheduler.load(), std::cmp::Reverse(i)))
            .map(|(i, _)| i)?;
        let i = busiest;
        let held: usize = (0..self.instances[i].placement.n_layers)
            .map(|l| self.instances[i].placement.degree(l) - 1)
            .sum();
        let remaining = self.cfg.replica_budget.saturating_sub(held);
        if remaining == 0 {
            return None;
        }
        let gamma = self.gamma();
        let ops = ModuleOps::new(&self.cost, self.cfg.dtype_bytes, &format!("inst{i}"));
        let up_cfg = ScaleUpConfig {
            gamma,
            min_vacancy: SCALE_UP_MIN_VACANCY,
            max_ops_per_round: remaining.min(4),
        };
        let up = scale_up(&ops, &self.cluster, &self.instances[i].placement, &up_cfg);
        if up.plan.is_empty() {
            None
        } else {
            Some((i, up))
        }
    }

    /// Option B of any scale-out: the device with the most free memory
    /// that fits a whole fresh single-device instance (with 2% headroom).
    fn spin_candidate(&self) -> Option<usize> {
        let ops = ModuleOps::new(&self.cost, self.cfg.dtype_bytes, "fleet-probe");
        let inst_bytes = ops.module_bytes(ModuleKind::DecoderLayer)
            * self.cfg.model.n_layers as f64
            + ops.module_bytes(ModuleKind::Embed)
            + ops.module_bytes(ModuleKind::LmHead);
        self.cluster
            .by_free_memory()
            .into_iter()
            .find(|&d| self.cluster.device(d).free_bytes() >= inst_bytes * 1.02)
    }

    /// Scale-out arbitration: price a replication round on the busiest
    /// instance against a whole-instance spin-up, per instance-equivalent
    /// of added capacity, and execute the cheaper option. Replication
    /// flows through the normal in-flight plan path; spin-up deploys a new
    /// instance that starts accepting traffic after the cold start.
    ///
    /// On a mixed fleet both sides are priced in the *same* currency —
    /// device-0-relative equivalents: a replication round on a slow
    /// instance yields proportionally less capacity, and a spin-up on a
    /// slow device pays a proportionally longer effective cold start
    /// (same capacity, later). On a homogeneous fleet every factor is
    /// exactly 1.0, so the arbitration inputs are bit-identical to the
    /// unweighted ones.
    fn fleet_scale_out(&mut self, inputs: &FleetInputs, q: &mut dyn EventSink) {
        let replication = self.replication_option();
        let fc = self.fleet.as_ref().expect("fleet mode").cfg;
        let spin_dev = self.spin_candidate();
        // device 0 is the pricing reference (it always exists; scenario
        // constructors put the seed instance there)
        let ref_eff = self.cluster.device(0).spec.effective_flops();
        let speed = |eff: f64| {
            if ref_eff <= 0.0 || eff <= 0.0 { 1.0 } else { eff / ref_eff }
        };
        // priced exactly as enacted: cold_start_s covers process launch +
        // weight load (see FleetConfig), and spin_up gates activation on
        // cold_start_s alone — a slower device delivers fewer reference
        // equivalents per wall-second of cold start, priced as more
        // seconds per reference equivalent
        let spin_cost = spin_dev.map(|d| {
            fc.cold_start_s / speed(self.cluster.device(d).spec.effective_flops())
        });
        let rep_option = replication
            .as_ref()
            .map(|(i, up)| {
                (
                    up.cost.total.time_s,
                    up.planned.len() as f64 / self.cfg.model.n_layers.max(1) as f64
                        * speed(self.instances[*i].profile.min_eff_flops()),
                )
            });
        let choice = self.fleet.as_ref().expect("fleet").arbitrate(rep_option, spin_cost);
        // the arbitration's per-equivalent prices — what the decision
        // record reports as chosen vs rejected (−1.0 = option unavailable)
        let rep_price = rep_option
            .map(|(c, eq)| c / eq.max(1e-9))
            .unwrap_or(-1.0);
        let spin_price = spin_cost.unwrap_or(-1.0);
        match choice {
            ScaleOutChoice::Replicate => {
                let (i, up) = replication.expect("arbitrated option exists");
                self.scale.scale_ups += 1;
                self.tracer.decision(
                    self.now,
                    DecisionActor::Fleet,
                    DecisionAction::ScaleOutReplicate,
                    i as i64,
                    inputs.mean_outstanding(),
                    0.0,
                    rep_price,
                    spin_price,
                );
                self.admit(i, up.plan, up.cost, None, q);
            }
            ScaleOutChoice::SpinUp => {
                self.tracer.decision(
                    self.now,
                    DecisionActor::Fleet,
                    DecisionAction::ScaleOutSpinUp,
                    self.instances.len() as i64,
                    inputs.mean_outstanding(),
                    0.0,
                    spin_price,
                    rep_price,
                );
                self.spin_up(spin_dev.expect("arbitrated option exists"), q);
            }
            ScaleOutChoice::Neither => {
                self.tracer.decision(
                    self.now,
                    DecisionActor::Fleet,
                    DecisionAction::ScaleOutNone,
                    -1,
                    inputs.mean_outstanding(),
                    0.0,
                    -1.0,
                    rep_price.max(spin_price),
                );
            }
        }
    }

    /// Deploy a new instance on `device`. Weights are resident (and its
    /// devices billed) from now; the router starts offering it traffic
    /// after the configured cold start.
    fn spin_up(&mut self, device: usize, q: &mut dyn EventSink) {
        let id = self.instances.len();
        let fc = self.fleet.as_ref().expect("fleet mode").cfg;
        let placement = Placement::single_device(self.cfg.model.n_layers, device);
        let mut inst =
            Instance::deploy(id, placement, fc.policy, &self.cfg, &self.cost, &mut self.cluster);
        inst.active_after = self.now + fc.cold_start_s;
        inst.reroute_shed = self.router.cfg.reroute_on_shed;
        inst.preempt_premium = self.router.cfg.policy.class_aware();
        let active_after = inst.active_after;
        let devs = inst.profile.device_set.clone();
        for &d in &devs {
            self.ledger.acquire(d);
        }
        self.bill_cache.push((inst.placement_rev, devs));
        self.instances.push(inst);
        self.tracer.mark(self.now, id as i64, MarkKind::SpinUp, device as f64);
        self.fleet_events.push(FleetEvent { t: self.now, instance: id, phase: FleetPhase::SpinUp });
        // wake at activation so parked requests route promptly even when
        // no other event happens to fire first
        self.schedule_wake(id, active_after, q);
    }

    /// Admit a plan for in-flight execution: schedule its op events with
    /// the dry-run durations. Batch-only plans (phase-3 relief) apply
    /// immediately and schedule nothing.
    fn admit(
        &mut self,
        i: usize,
        plan: ScalePlan,
        cost: PlanCost,
        batch_after: Option<usize>,
        q: &mut dyn EventSink,
    ) {
        if plan.is_empty() {
            if let Some(b) = batch_after {
                self.instances[i].batch_size = b;
            }
            return;
        }
        let (epoch, spans) = self.instances[i].admit_plan(self.now, plan, cost, batch_after);
        for (op_idx, &(start, end)) in spans.iter().enumerate() {
            q.push(start, EventKind::OpStarted { instance: i, op_idx, epoch });
            q.push(end, EventKind::OpCompleted { instance: i, op_idx, epoch });
        }
    }

    /// Schedule a wake-up for instance `i` at `at`, unless one is already
    /// pending at or before that time.
    fn schedule_wake(&mut self, i: usize, at: f64, q: &mut dyn EventSink) {
        let now = self.now;
        let inst = &mut self.instances[i];
        let covered =
            matches!(inst.scheduled_wake, Some(w) if w > now && w <= at + 1e-12);
        if !covered {
            inst.scheduled_wake = Some(at);
            q.push(at, EventKind::Wake { instance: i });
        }
    }

    /// Ask an idle instance to start its next step; schedule the follow-up
    /// event (completion, timeout wake, op-block wake, or OOM-backoff
    /// wake).
    fn try_start(&mut self, i: usize, q: &mut dyn EventSink) {
        if self.instances[i].busy_until.is_some() {
            return;
        }
        let contention = self.contention(i);
        let ctx = StepCtx { cfg: &self.cfg, cost: &self.cost, now: self.now };
        let outcome =
            self.instances[i].start_step(&ctx, &mut self.cluster, contention, &mut self.scale);
        // Sample the fleet-wide memory peak right after this instance's KV
        // mirror grew — before a later instance's OOM handling in the same
        // readiness sweep can release memory and mask the transient peak.
        self.peak_mem = self.peak_mem.max(self.cluster.total_used_bytes());
        match outcome {
            StepStart::Busy { until, token } => {
                self.steps_started += 1;
                let (batch, decode) = self.instances[i].last_step_shape;
                self.tracer.step(self.now, until - self.now, i, batch, decode);
                q.push(until, EventKind::StepComplete { instance: i, token });
            }
            StepStart::Idle => {
                // A static batch waiting to fill dispatches at its timeout
                // even if no other event fires first.
                if let Some(deadline) = self.instances[i].scheduler.next_deadline() {
                    if deadline > self.now {
                        self.schedule_wake(i, deadline, q);
                    }
                }
            }
            StepStart::Blocked { until } => {
                // A migration transfer (or the post-replication barrier)
                // holds the serving path; re-poll when it clears.
                self.schedule_wake(i, until, q);
            }
            StepStart::OomStall => {
                // A governed instance may have parked a precision-swap
                // plan during the episode — admit it as in-flight op
                // events before scheduling the retry poll.
                self.mempress_pickup(i, q);
                // Back off one controller period before retrying, matching
                // the recovery cadence of the lockstep loop this kernel
                // replaced (any earlier arrival re-polls the instance too).
                let at = self.now + self.cfg.controller_tick_s;
                self.schedule_wake(i, at, q);
            }
        }
    }

    /// Pick up a swap plan the governor parked during `handle_oom`
    /// (rung 2 of the escalation ladder) and admit it through the same
    /// dry-run → op-event machinery every background scaling plan uses —
    /// swaps pay real rewrite time and roll back on conflict like any
    /// other in-flight plan.
    fn mempress_pickup(&mut self, i: usize, q: &mut dyn EventSink) {
        if self.instances[i].inflight.is_some() {
            return; // a plan already executes; the parked one waits
        }
        let Some(plan) = self.instances[i]
            .governor
            .as_mut()
            .and_then(|g| g.take_swap_request())
        else {
            return;
        };
        let ops = ModuleOps::new(
            &self.cost,
            self.cfg.dtype_bytes,
            &format!("inst{}", self.instances[i].id),
        );
        match plan.dry_run(&ops, &self.cluster, &self.instances[i].placement) {
            // dry-run cost drives the op events, so the executed total
            // equals it bit-for-bit (shared `apply_next` arithmetic)
            Ok(cost) => self.admit(i, plan, cost, None, q),
            // stale against the live ledger (e.g. an emergency scale-down
            // landed between park and pickup): drop it, the next episode
            // re-plans from fresh state
            Err(_) => {}
        }
    }

    fn all_idle(&self) -> bool {
        self.router.pending.is_empty()
            // a routed-but-undelivered request still has its Routed event
            // in the queue — the fleet is not idle until it lands
            && self.instances.iter().all(|i| {
                i.outstanding_routes == 0
                    && i.scheduler.is_idle()
                    && i.busy_until.is_none()
                    && i.inflight.is_none()
            })
    }

    // ---- the event loop ---------------------------------------------------

    /// Seed the queue: the first arrival, the controller tick train, and
    /// (when a predictor is configured) the forecast tick train + oracle.
    fn seed(&mut self, trace: &Trace, drain_deadline: f64, q: &mut dyn EventSink) {
        if let Some(r) = trace.requests.first() {
            q.push(r.arrival_s, EventKind::Arrival { request_idx: 0 });
        }
        q.push(self.cfg.controller_tick_s, EventKind::ControllerTick);
        // the failure schedule is part of the seeded initial conditions:
        // same schedule, same seed → same event stream, byte-identical run
        for f in &self.failures.failures {
            q.push(f.t, EventKind::DeviceFailed { device: f.device });
        }
        if let Some(p) = &mut self.predictive {
            if p.cfg.oracle {
                // trace-peeking upper bound: install the true per-bucket
                // arrival rates (covering the drain window too)
                let bucket = p.cfg.bucket_s;
                let n_buckets = (drain_deadline / bucket).ceil().max(1.0) as usize;
                let mut rates = vec![0.0; n_buckets];
                for r in &trace.requests {
                    let idx = ((r.arrival_s / bucket) as usize).min(n_buckets - 1);
                    rates[idx] += 1.0;
                }
                for r in &mut rates {
                    *r /= bucket;
                }
                p.forecaster.set_oracle(rates);
            }
            q.push(self.cfg.controller_tick_s, EventKind::ForecastTick);
        }
    }

    /// Process one popped event: the handler match plus the coordinator
    /// follow-ups (shed re-routes, parked retries, the readiness sweep,
    /// billing reconciliation). **This is the one dispatch body both
    /// drive loops share** — the sequential loop feeds it from a single
    /// [`EventQueue`], the sharded loop from [`ShardedEventQueue`]'s
    /// merged stream. Same events in the same order through the same
    /// code is what makes the two kernels' metrics JSON byte-identical.
    fn dispatch(
        &mut self,
        ev: Event,
        trace: &Trace,
        next_req: &mut usize,
        q: &mut dyn EventSink,
    ) {
        self.now = ev.time;
        self.events_processed += 1;
        // bill device-seconds up to this event at the pre-event rate
        self.ledger.advance(self.now);
        // close due timeline windows before this event mutates state —
        // the window boundary samples the world as of its close time
        if self.tracer.timeline_due(self.now) {
            let outstanding = self.timeline_outstanding();
            let busy = self.total_busy_seconds();
            let dev_s = self.ledger.device_seconds();
            self.tracer.roll(self.now, outstanding, dev_s, busy, self.cluster.n());
        }

        match ev.kind {
            EventKind::Arrival { request_idx } => {
                // Request is Copy: arrivals index into the trace, no
                // per-arrival heap clone.
                let req = trace.requests[request_idx];
                *next_req = request_idx + 1;
                if let Some(r) = trace.requests.get(*next_req) {
                    q.push(r.arrival_s, EventKind::Arrival { request_idx: *next_req });
                }
                self.tracer.req(self.now, req.id, -1, ReqPhase::Arrival);
                self.route_arrival(request_idx, req, q);
            }
            EventKind::Routed { request_idx, instance } => {
                // the predictor sees what the coordinator routes
                if let Some(p) = &mut self.predictive {
                    p.forecaster.observe(self.now);
                    if self.router.cfg.policy.class_aware() {
                        p.forecaster.observe_class(trace.requests[request_idx].class);
                    }
                }
                if self.instances[instance].lifecycle == Lifecycle::Retired {
                    // Defensive: a same-timestamp DeviceFailed cannot
                    // outrun a Routed event (priority 1 < 4), but if a
                    // target ever retires under an undelivered route,
                    // park the request for re-routing instead of
                    // delivering to a corpse.
                    let inst = &mut self.instances[instance];
                    inst.outstanding_routes = inst.outstanding_routes.saturating_sub(1);
                    self.tracer.req(
                        self.now,
                        trace.requests[request_idx].id,
                        -1,
                        ReqPhase::Parked,
                    );
                    self.router.park(trace.requests[request_idx], 0.0, true);
                } else {
                    self.instances[instance].outstanding_routes -= 1;
                    self.tracer.req(
                        self.now,
                        trace.requests[request_idx].id,
                        instance as i64,
                        ReqPhase::Admitted,
                    );
                    self.instances[instance].deliver(trace.requests[request_idx], 0.0);
                }
            }
            EventKind::DeviceFailed { device } => self.on_device_failed(device),
            EventKind::ForecastTick => {
                // close rate buckets up to now (quiet gaps decay the
                // estimators) right before the coinciding controller
                // tick consumes the forecast
                if let Some(p) = &mut self.predictive {
                    p.forecaster.advance(self.now);
                    q.push(self.now + self.cfg.controller_tick_s, EventKind::ForecastTick);
                }
            }
            EventKind::ControllerTick => {
                self.fleet_tick(q);
                self.controller_tick(q);
                q.push(self.now + self.cfg.controller_tick_s, EventKind::ControllerTick);
            }
            EventKind::OpStarted { instance, op_idx, epoch } => {
                // the op + its dry-run cost must be read off the in-flight
                // plan BEFORE the handler advances it (span inputs)
                let pre = self.instances[instance].inflight.as_ref().and_then(|fl| {
                    (fl.epoch == epoch).then(|| {
                        (fl.plan.ops.get(op_idx).copied(), fl.costs.get(op_idx).copied())
                    })
                });
                let outcome = self.instances[instance].on_op_started(self.now, op_idx, epoch);
                if let OpOutcome::Started { desc } = outcome {
                    if let Some((Some(op), Some(cost))) = pre {
                        self.tracer.op(
                            self.now,
                            instance,
                            op_idx,
                            op,
                            cost.time_s,
                            0.0,
                            OpSpanPhase::Started,
                        );
                    }
                    self.audit_push(
                        AuditKind::ModuleOp,
                        Some(instance),
                        None,
                        format!("started {desc}"),
                    );
                    self.scale.events.push(OpEvent {
                        t: self.now,
                        instance,
                        op_idx,
                        phase: OpPhase::Started,
                        desc,
                    });
                }
            }
            EventKind::OpCompleted { instance, op_idx, epoch } => {
                // span inputs (op + dry-run cost) read before the handler
                // consumes the in-flight cursor — same discipline as
                // `OpStarted`
                let pre = self.instances[instance].inflight.as_ref().and_then(|fl| {
                    (fl.epoch == epoch).then(|| {
                        (fl.plan.ops.get(op_idx).copied(), fl.costs.get(op_idx).copied())
                    })
                });
                let ctx = StepCtx { cfg: &self.cfg, cost: &self.cost, now: self.now };
                let outcome = self.instances[instance].on_op_completed(
                    &ctx,
                    &mut self.cluster,
                    op_idx,
                    epoch,
                );
                match outcome {
                    OpOutcome::Applied { desc, cost, .. } => {
                        self.scale.op_time_s += cost.time_s;
                        if let Some((Some(op), Some(dry))) = pre {
                            self.tracer.op(
                                self.now,
                                instance,
                                op_idx,
                                op,
                                dry.time_s,
                                cost.time_s,
                                OpSpanPhase::Applied,
                            );
                        }
                        self.audit_push(
                            AuditKind::ModuleOp,
                            Some(instance),
                            None,
                            format!("completed {desc}"),
                        );
                        self.scale.events.push(OpEvent {
                            t: self.now,
                            instance,
                            op_idx,
                            phase: OpPhase::Completed,
                            desc,
                        });
                    }
                    OpOutcome::Aborted { desc } => {
                        self.scale.plans_aborted += 1;
                        if let Some((Some(op), Some(dry))) = pre {
                            self.tracer.op(
                                self.now,
                                instance,
                                op_idx,
                                op,
                                dry.time_s,
                                0.0,
                                OpSpanPhase::Aborted,
                            );
                        }
                        self.tracer.mark(
                            self.now,
                            instance as i64,
                            MarkKind::Rollback,
                            op_idx as f64,
                        );
                        self.audit_push(
                            AuditKind::ModuleOp,
                            Some(instance),
                            None,
                            format!("aborted {desc}"),
                        );
                        self.scale.events.push(OpEvent {
                            t: self.now,
                            instance,
                            op_idx,
                            phase: OpPhase::Aborted,
                            desc,
                        });
                    }
                    OpOutcome::Started { .. } | OpOutcome::Stale => {}
                }
            }
            EventKind::StepComplete { instance, token } => {
                let inst = &mut self.instances[instance];
                // Stale tokens: an OOM rebuild cleared the in-flight
                // step after this completion was scheduled.
                if inst.step_token == token && inst.busy_until.is_some() {
                    inst.busy_until = None;
                    // completion spans read off the monitor diff — no
                    // signature change on the completion path, and the
                    // snapshot is free when telemetry is off
                    let before = if self.tracer.enabled() {
                        self.instances[instance].monitor.completions().len()
                    } else {
                        0
                    };
                    self.instances[instance].finish_completions(self.now, &mut self.cluster);
                    if self.tracer.enabled() {
                        for k in before..self.instances[instance].monitor.completions().len() {
                            let c = self.instances[instance].monitor.completions()[k];
                            self.tracer.completion(
                                self.now,
                                c.request_id,
                                instance as i64,
                                c.e2e_latency(),
                            );
                        }
                    }
                }
            }
            EventKind::Wake { instance } => {
                let inst = &mut self.instances[instance];
                if matches!(inst.scheduled_wake, Some(w) if w <= self.now + 1e-9) {
                    inst.scheduled_wake = None;
                }
            }
        }
        self.peak_mem = self.peak_mem.max(self.cluster.total_used_bytes());

        // Coordinator follow-ups: re-route requests shed by OOM
        // handling during this event, then retry parked requests —
        // both before the readiness sweep so newly delivered work can
        // start at this timestamp.
        self.collect_shed();
        self.drain_parked();

        // Readiness sweep: every idle instance with queued work gets a
        // chance to start, in ascending id order (deterministic). Idle
        // instances *without* work are skipped cheaply; instances with
        // queued work are deliberately re-polled on every event — that
        // keeps the lockstep loop's retry cadence for OOM-stalled and
        // timeout-waiting instances (their wake events are only the
        // no-other-traffic fallback).
        for i in 0..self.instances.len() {
            if self.instances[i].busy_until.is_none() && self.instances[i].has_work() {
                self.try_start(i, q);
            }
        }
        // The sweep can shed too (OOM on step start) — collect before
        // leaving the timestamp so the requests are not stranded.
        self.collect_shed();
        // Drain trace events recorded deep inside instances this event
        // (OOM episodes, governor decisions) into the tracer. Gated:
        // telemetry-off runs never touch the (always-empty) outboxes.
        if self.tracer.enabled() {
            for i in 0..self.instances.len() {
                if self.instances[i].trace_outbox.is_empty() {
                    continue;
                }
                let evs = std::mem::take(&mut self.instances[i].trace_outbox);
                for tev in evs {
                    self.tracer.forward(tev);
                }
            }
        }
        // Reconcile device-seconds billing with any placement moves
        // this event (or its sweep) made.
        self.sync_billing();
    }

    /// Outstanding requests fleet-wide as the timeline samples them:
    /// router-parked plus every instance's pending + running + in-flight
    /// routes (the same per-instance definition routing uses).
    fn timeline_outstanding(&self) -> u64 {
        (self.router.pending.len()
            + (0..self.instances.len()).map(|i| self.outstanding(i)).sum::<usize>())
            as u64
    }

    /// Cumulative busy device-seconds across the cluster (the timeline's
    /// utilization numerator; windows report the per-window delta).
    fn total_busy_seconds(&self) -> f64 {
        (0..self.cluster.n()).map(|d| self.cluster.device(d).busy_seconds()).sum()
    }

    /// Run the trace to completion (plus drain); returns the report.
    ///
    /// `cfg.shards == 1` (the default) runs today's single-queue loop;
    /// `cfg.shards ≥ 2` runs the epoch-barrier sharded kernel. The two
    /// produce byte-identical metrics JSON (asserted per scenario in
    /// `rust/tests/shard_parity.rs`).
    pub fn run(self, trace: &Trace, duration_s: f64) -> SimReport {
        if self.cfg.shards <= 1 {
            self.run_sequential(trace, duration_s)
        } else {
            self.run_sharded(trace, duration_s)
        }
    }

    /// Dispatch one event, optionally under the self-profiler: the slot
    /// is read before the call, wall time and the allocation counter are
    /// sampled around it. Wall-clock flows only into the profiler —
    /// never into simulation state — so profiled runs stay
    /// byte-identical on the golden surface.
    #[inline]
    fn dispatch_profiled(
        &mut self,
        ev: Event,
        trace: &Trace,
        next_req: &mut usize,
        q: &mut dyn EventSink,
        profiler: &mut Option<crate::telemetry::profiler::KernelProfiler>,
    ) {
        match profiler {
            Some(p) => {
                let slot = ev.kind.slot();
                let a0 = p.probe_now();
                let t0 = std::time::Instant::now();
                self.dispatch(ev, trace, next_req, q);
                let wall = t0.elapsed().as_nanos() as u64;
                let a1 = p.probe_now();
                p.record(slot, wall, a1.saturating_sub(a0));
            }
            None => self.dispatch(ev, trace, next_req, q),
        }
    }

    /// The self-profiler for this run, if the telemetry config asks for
    /// one (with its allocation probe installed).
    fn make_profiler(&self) -> Option<crate::telemetry::profiler::KernelProfiler> {
        self.tracer
            .profile_enabled()
            .then(|| crate::telemetry::profiler::KernelProfiler::new(self.tracer.alloc_probe()))
    }

    /// The sequential kernel: one deterministic queue, one pop loop.
    fn run_sequential(mut self, trace: &Trace, duration_s: f64) -> SimReport {
        let drain_deadline = duration_s + 300.0;
        let mut q = EventQueue::new();
        let mut next_req = 0usize;
        self.seed(trace, drain_deadline, &mut q);
        let mut profiler = self.make_profiler();
        loop {
            if next_req >= trace.requests.len() && self.all_idle() {
                break;
            }
            let Some(ev) = q.pop() else { break };
            if ev.time > drain_deadline {
                break;
            }
            self.dispatch_profiled(ev, trace, &mut next_req, &mut q, &mut profiler);
        }
        self.finish(profiler)
    }

    /// The sharded kernel: instance-local events live in per-shard
    /// queues (`instance % shards`); coordinator events (`Arrival`,
    /// `ForecastTick`, `ControllerTick`) are the barriers. At each epoch
    /// boundary the shards drain their due window in parallel
    /// (`std::thread::scope` inside [`ShardedEventQueue::drain_epoch`]);
    /// the coordinator then applies the merged stream — shard windows
    /// interleaved with barrier events by the same time → kind-priority
    /// → instance-id → FIFO tie-break a single queue uses, so every
    /// cross-shard effect (routing, shed re-routes, fleet plans, ledger
    /// advances) lands in exactly the sequential kernel's order.
    fn run_sharded(mut self, trace: &Trace, duration_s: f64) -> SimReport {
        let drain_deadline = duration_s + 300.0;
        let mut q = ShardedEventQueue::new(self.cfg.shards);
        let mut next_req = 0usize;
        self.seed(trace, drain_deadline, &mut q);
        let mut profiler = self.make_profiler();
        loop {
            if next_req >= trace.requests.len() && self.all_idle() {
                break;
            }
            q.drain_epoch();
            let Some(ev) = q.pop_merged() else { break };
            if ev.time > drain_deadline {
                break;
            }
            self.dispatch_profiled(ev, trace, &mut next_req, &mut q, &mut profiler);
        }
        self.finish(profiler)
    }

    /// Close the books and build the report (shared by both kernels).
    fn finish(
        mut self,
        profiler: Option<crate::telemetry::profiler::KernelProfiler>,
    ) -> SimReport {
        let wall = self.now.max(1e-9);
        self.ledger.advance(self.now);
        // consume the tracer first (its end-of-run samples read the
        // instances the report construction below moves out of)
        let (trace_buf, timeline) = {
            let outstanding = self.timeline_outstanding();
            let busy = self.total_busy_seconds();
            let dev_s = self.ledger.device_seconds();
            let n_inst = self.instances.len();
            self.tracer.into_output(
                self.now,
                outstanding,
                dev_s,
                busy,
                self.cluster.n(),
                n_inst,
            )
        };
        // aggregate governor stats before `monitors` consumes the instances
        let mempress = if self.cfg.mempress.is_some() {
            let mut agg = MempressReport::default();
            for inst in &self.instances {
                if let Some(g) = &inst.governor {
                    agg.absorb(&g.stats);
                }
                agg.quantized_layers += inst.quantized_layers.len() as u64;
            }
            Some(agg)
        } else {
            None
        };
        // requests still parked at the deadline are the conservation
        // remainder the chaos tests account for (completed + shed +
        // unrouted == trace length)
        let audit = self.audit.take().map(|log| AuditBlock {
            log,
            unrouted_at_end: self.router.pending.len(),
        });
        // per-class outcome summary — assembled only under a class-aware
        // routing policy, so classless documents carry no `slo` key
        let slo = if self.router.cfg.policy.class_aware() {
            use crate::workload::SloClass;
            let mut premium = (0usize, 0usize);
            let mut be = (0usize, 0usize);
            for inst in &self.instances {
                let m = &inst.monitor;
                for c in m.completions() {
                    let within = c.e2e_latency() <= m.slo_latency_s;
                    let bucket = if c.class == SloClass::LatencySensitive {
                        &mut premium
                    } else {
                        &mut be
                    };
                    bucket.0 += 1;
                    bucket.1 += usize::from(within);
                }
            }
            let attain =
                |(n, ok): (usize, usize)| if n == 0 { 1.0 } else { ok as f64 / n as f64 };
            Some(SloBlock {
                premium_completed: premium.0,
                premium_slo_attainment: attain(premium),
                be_completed: be.0,
                be_slo_attainment: attain(be),
                preemptions: self.instances.iter().map(|i| i.preemptions).sum(),
                premium_routes: self.router.class_routes
                    [Router::class_idx(SloClass::LatencySensitive)],
                be_routes: self.router.class_routes[Router::class_idx(SloClass::BestEffort)],
            })
        } else {
            None
        };
        SimReport {
            duration_s: wall,
            events_processed: self.events_processed,
            steps_started: self.steps_started,
            device_seconds: self.ledger.device_seconds(),
            routes: self.router.routes,
            reroutes: self.router.reroutes,
            fleet_events: self.fleet_events,
            device_util: (0..self.cluster.n())
                .map(|d| {
                    (
                        d,
                        self.cluster.device(d).utilization(wall),
                        self.cluster.device(d).mem_frac(),
                    )
                })
                .collect(),
            device_peak_bytes: (0..self.cluster.n())
                .map(|d| self.cluster.device(d).peak_used_bytes())
                .collect(),
            total_oom_events: self.cluster.total_oom_events()
                + self.instances.iter().map(|i| i.monitor.total_oom()).sum::<u64>(),
            scale_ups: self.scale.scale_ups,
            scale_downs: self.scale.scale_downs,
            oom_victims: self.instances.iter().map(|i| i.oom_victims.len()).sum(),
            scale_op_time_s: self.scale.op_time_s,
            peak_mem_bytes: self.peak_mem,
            kv_stats: self.instances.iter().map(|i| i.kv_peak).collect(),
            placements: self.instances.iter().map(|i| i.placement.clone()).collect(),
            batch_sizes: self.instances.iter().map(|i| i.batch_size).collect(),
            plans_aborted: self.scale.plans_aborted,
            op_events: self.scale.events,
            forecast: self.predictive.map(|p| p.report()),
            mempress,
            audit,
            slo,
            timeline,
            trace: trace_buf,
            profile: profiler.map(|p| p.finish()),
            monitors: self.instances.into_iter().map(|i| i.monitor).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::workload::{Arrival, LengthDist, Trace};

    fn run_single(policy: SimPolicy, rps: f64, dur: f64) -> SimReport {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
        let trace = Trace::generate(
            Arrival::Poisson { rps },
            LengthDist::alpaca(),
            dur,
            42,
        );
        sim.run(&trace, dur)
    }

    #[test]
    fn governed_run_reports_mempress_and_never_sheds_more() {
        let dur = 30.0;
        let trace =
            Trace::generate(Arrival::Poisson { rps: 6.0 }, LengthDist::alpaca(), dur, 7);
        let mk = |cfg: SimConfig| {
            let cluster = Cluster::paper_testbed();
            let placement = Placement::single_device(cfg.model.n_layers, 0);
            Simulation::new(cfg, cluster, vec![(placement, baselines::hft(16))])
                .run(&trace, dur)
        };
        let off = mk(SimConfig::paper_13b());
        let mut governed = SimConfig::paper_13b();
        governed.mempress = Some(crate::mempress::MempressConfig::default());
        let on = mk(governed);
        assert!(off.mempress.is_none(), "unset config must add no report block");
        assert!(on.mempress.is_some(), "governed run reports the governor");
        assert!(
            on.oom_victims <= off.oom_victims,
            "the ladder must never shed more than the raw policy"
        );
    }

    #[test]
    fn low_load_completes_everything() {
        let r = run_single(baselines::vllm_like(16), 3.0, 20.0);
        assert!(r.total_completed() >= 40, "completed {}", r.total_completed());
        assert!(r.merged_latency().mean() < 20.0);
    }

    #[test]
    fn hft_static_batching_slower_than_continuous() {
        let h = run_single(baselines::hft(16), 8.0, 30.0);
        let v = run_single(baselines::vllm_like(16), 8.0, 30.0);
        let hl = h.merged_latency().mean();
        let vl = v.merged_latency().mean();
        assert!(vl < hl, "vllm {vl} !< hft {hl}");
    }

    #[test]
    fn cocoserve_autoscaler_replicates_under_load() {
        let r = run_single(baselines::cocoserve(16), 20.0, 30.0);
        assert!(r.scale_ups > 0, "no scale-ups happened");
        // some layer gained a replica
        let maxdeg = (0..r.placements[0].n_layers)
            .map(|l| r.placements[0].degree(l))
            .max()
            .unwrap();
        assert!(maxdeg > 1);
        // the replicas arrived through in-flight op events, not a pause
        assert!(!r.op_events.is_empty(), "no op events logged");
        assert!(r
            .op_events
            .iter()
            .any(|e| e.phase == OpPhase::Completed && e.desc.starts_with("replicate")));
    }

    #[test]
    fn cocoserve_outperforms_vllm_under_load() {
        let c = run_single(baselines::cocoserve(16), 20.0, 30.0);
        let v = run_single(baselines::vllm_like(16), 20.0, 30.0);
        let cl = c.merged_latency().mean();
        let vl = v.merged_latency().mean();
        assert!(cl < vl, "coco {cl} !< vllm {vl}");
        assert!(c.total_throughput_tps() >= v.total_throughput_tps() * 0.95);
    }

    #[test]
    fn throughput_increases_with_rps_until_saturation() {
        let lo = run_single(baselines::vllm_like(16), 3.0, 20.0);
        let hi = run_single(baselines::vllm_like(16), 12.0, 20.0);
        assert!(hi.total_throughput_tps() > lo.total_throughput_tps());
    }

    #[test]
    fn device_utilization_reported() {
        let r = run_single(baselines::vllm_like(16), 10.0, 20.0);
        let (_, util0, mem0) = r.device_util[0];
        assert!(util0 > 0.0 && util0 <= 1.0);
        assert!(mem0 > 0.0, "model weights resident");
        assert!(r.device_peak_bytes[0] > 0.0);
    }

    #[test]
    fn multi_instance_routes_by_load() {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::paper_testbed();
        let p0 = Placement::single_device(cfg.model.n_layers, 0);
        let p1 = Placement::single_device(cfg.model.n_layers, 1);
        let sim = Simulation::new(
            cfg,
            cluster,
            vec![
                (p0, baselines::vllm_like(16)),
                (p1, baselines::vllm_like(16)),
            ],
        );
        let trace = Trace::generate(
            Arrival::Poisson { rps: 10.0 },
            LengthDist::alpaca(),
            20.0,
            7,
        );
        let r = sim.run(&trace, 20.0);
        let c0 = r.monitors[0].completions().len();
        let c1 = r.monitors[1].completions().len();
        assert!(c0 > 0 && c1 > 0, "both instances serve: {c0}/{c1}");
        let ratio = c0 as f64 / c1 as f64;
        assert!((0.5..2.0).contains(&ratio), "balanced routing: {ratio}");
    }

    #[test]
    fn migration_relieves_memory_cliff() {
        // Fig. 3 mechanism: a layer migrated off the hot device frees
        // memory for KV, avoiding HFT-style OOM churn.
        let cfg = SimConfig::paper_13b();
        let mut cluster = Cluster::paper_testbed();
        // squeeze device 0 so KV pressure appears quickly
        cluster
            .device_mut(0)
            .alloc("other-tenant", 12.0 * crate::cluster::GIB)
            .unwrap();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let sim = Simulation::new(
            cfg,
            cluster,
            vec![(placement, baselines::cocoserve(24))],
        );
        let trace = Trace::generate(
            Arrival::Poisson { rps: 30.0 },
            LengthDist::alpaca(),
            20.0,
            11,
        );
        let r = sim.run(&trace, 20.0);
        // the autoscaler acted and the run stayed mostly OOM-free
        assert!(r.scale_ups + r.scale_downs > 0);
    }

    #[test]
    fn eight_instances_advance_independently() {
        // Fleet-scale smoke test for the event kernel: 8 instances over 8
        // devices, every one serves, and the run drains to completion.
        let cfg = SimConfig::paper_13b();
        let cluster =
            Cluster::homogeneous(8, crate::cluster::DeviceSpec::a100_40gb());
        let placements: Vec<_> = (0..8)
            .map(|i| {
                (
                    Placement::single_device(cfg.model.n_layers, i),
                    baselines::vllm_like(16),
                )
            })
            .collect();
        let sim = Simulation::new(cfg, cluster, placements);
        let trace = Trace::generate(
            Arrival::Poisson { rps: 40.0 },
            LengthDist::alpaca(),
            15.0,
            23,
        );
        let n_req = trace.len();
        let r = sim.run(&trace, 15.0);
        assert_eq!(r.monitors.len(), 8);
        assert!(r.total_completed() >= n_req * 9 / 10, "drained {} of {n_req}",
                r.total_completed());
        let serving = r.monitors.iter().filter(|m| !m.completions().is_empty()).count();
        assert!(serving >= 7, "only {serving}/8 instances served");
    }

    #[test]
    fn same_seed_runs_are_identical() {
        let a = run_single(baselines::cocoserve(16), 15.0, 20.0);
        let b = run_single(baselines::cocoserve(16), 15.0, 20.0);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::baselines;
    use crate::workload::{Arrival, LengthDist, Trace};

    #[test]
    #[ignore]
    fn debug_report() {
        for (name, pol) in [
            ("vllm", baselines::vllm_like(16)),
            ("coco", baselines::cocoserve(16)),
        ] {
            let cfg = SimConfig::paper_13b();
            let cluster = Cluster::paper_testbed();
            let placement = Placement::single_device(cfg.model.n_layers, 0);
            let sim = Simulation::new(cfg, cluster, vec![(placement, pol)]);
            let trace = Trace::generate(Arrival::Poisson { rps: 20.0 }, LengthDist::alpaca(), 30.0, 42);
            let n_req = trace.len();
            let r = sim.run(&trace, 30.0);
            let mut lat = r.merged_latency();
            eprintln!("{name}: req={n_req} done={} mean={:.2} p95={:.2} dur={:.1} tps={:.0} ups={} downs={} aborts={} opev={} oom={} batch={:?} trans={} degmax={}",
                r.total_completed(), lat.mean(), lat.p95(), r.duration_s,
                r.total_throughput_tps(), r.scale_ups, r.scale_downs, r.plans_aborted,
                r.op_events.len(), r.total_oom_events,
                r.batch_sizes, r.placements[0].transition_count(),
                (0..r.placements[0].n_layers).map(|l| r.placements[0].degree(l)).max().unwrap());
        }
    }
}
