//! Fig. 6 — layer replication count & parallelism degree vs performance.
//!
//! Paper setup: LLaMA-13B on 4×A100.
//! * 6a/6b: dop fixed at 2, replicated-layer count ∈ {0,15,20,25,30};
//!   throughput grows nonlinearly with replication (4.3× at 30 layers,
//!   50 RPS); latency stays sub-5s for deep replication vs the baseline's
//!   blow-up.
//! * 6c/6d: 20 layers replicated, dop ∈ {1,2,3,4}; near-linear scaling
//!   below 30 RPS, diminishing returns at high load.

use cocoserve::cluster::Cluster;
use cocoserve::placement::Placement;
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::sim::{OomBehavior, SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{replicated_placement_13b as replicated_placement, Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: [f64; 5] = [10.0, 20.0, 30.0, 40.0, 50.0];

fn policy() -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::continuous(16),
        paged_kv: true,
        autoscale: false, // replication is applied statically per arm
        oom: OomBehavior::Preempt,
    }
}

fn run(p: &Placement, rps: f64) -> (f64, f64) {
    let cfg = SimConfig::paper_13b();
    let sim = Simulation::new(cfg, Cluster::paper_testbed(), vec![(p.clone(), policy())]);
    let trace = Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), 20.0, 6);
    let r = sim.run(&trace, 20.0);
    (r.total_throughput_tps(), r.merged_latency().mean())
}

fn main() {
    let mut rep = Report::new("fig6_replication");

    // ---- 6a/6b: replication-count sweep at dop 2 ------------------------
    println!("Fig. 6a/6b — throughput & latency vs replicated layers (dop=2)\n");
    let mut ta = Table::new(&["rps", "rep#0", "rep#15", "rep#20", "rep#25", "rep#30"]);
    let mut tb = Table::new(&["rps", "rep#0", "rep#15", "rep#20", "rep#25", "rep#30"]);
    let counts = [0usize, 15, 20, 25, 30];
    let placements: Vec<Placement> =
        counts.iter().map(|&n| replicated_placement(n, 2)).collect();
    let mut thr_at_50 = vec![];
    for &rps in &RPS {
        let mut thr_row = vec![format!("{rps:.0}")];
        let mut lat_row = vec![format!("{rps:.0}")];
        for (i, p) in placements.iter().enumerate() {
            let (thr, lat) = run(p, rps);
            thr_row.push(format!("{thr:.0}"));
            lat_row.push(format!("{lat:.2}"));
            if rps == 50.0 {
                thr_at_50.push(thr);
            }
            rep.set(
                &format!("rep{}_rps{}", counts[i], rps as u64),
                json::arr([json::num(thr), json::num(lat)]),
            );
        }
        ta.row(&thr_row);
        tb.row(&lat_row);
    }
    println!("throughput (tok/s):");
    ta.print();
    println!("\nmean latency (s):");
    tb.print();
    println!(
        "\nat 50 RPS: rep#30 = {:.2}× baseline throughput (paper: 4.3×); \
         rep#20 = {:.2}× (paper: 1.9×)",
        thr_at_50[4] / thr_at_50[0],
        thr_at_50[2] / thr_at_50[0]
    );

    // ---- 6c/6d: dop sweep at 20 replicated layers ------------------------
    println!("\nFig. 6c/6d — throughput & latency vs parallelism degree (rep=20)\n");
    let mut tc = Table::new(&["rps", "dop1", "dop2", "dop3", "dop4"]);
    let mut td = Table::new(&["rps", "dop1", "dop2", "dop3", "dop4"]);
    let dops = [1usize, 2, 3, 4];
    let dop_placements: Vec<Placement> =
        dops.iter().map(|&d| replicated_placement(20, d)).collect();
    for &rps in &RPS {
        let mut thr_row = vec![format!("{rps:.0}")];
        let mut lat_row = vec![format!("{rps:.0}")];
        for (i, p) in dop_placements.iter().enumerate() {
            let (thr, lat) = run(p, rps);
            thr_row.push(format!("{thr:.0}"));
            lat_row.push(format!("{lat:.2}"));
            rep.set(
                &format!("dop{}_rps{}", dops[i], rps as u64),
                json::arr([json::num(thr), json::num(lat)]),
            );
        }
        tc.row(&thr_row);
        td.row(&lat_row);
    }
    println!("throughput (tok/s):");
    tc.print();
    println!("\nmean latency (s):");
    td.print();

    println!("\nreport: {}", rep.write().unwrap().display());
}
