//! The scenario library: named traffic shapes for multi-instance experiments.
//!
//! The paper evaluates under steady Poisson load only (§6.1); the systems it
//! is compared against are stressed by *dynamic* traffic — MorphServe swaps
//! under bursty traces, FlexPipe refactors inflight under fragmented,
//! fluctuating load. These constructors package the shapes the fig10/fig11
//! benches sweep so every scaling experiment runs the same five scenarios:
//!
//! * **steady**  — constant-rate Poisson (the paper's baseline shape),
//! * **diurnal** — sinusoidal day/night cycle (slow swing the scale-up
//!   loop should harvest and the scale-down loop should survive),
//! * **burst**   — a 3× spike window mid-run (flash crowd),
//! * **ramp**    — monotone growth from 20% to 180% of the target rate
//!   (capacity walk-up),
//! * **two-tenant** — interactive chat (short prompts, short outputs)
//!   mixed with batch summarization (long prompts, long outputs) at the
//!   same aggregate rate — the fragmented length mix that stresses
//!   continuous batching and KV accounting.
//!
//! All constructors are deterministic in `(rps, duration_s, seed)`.

use super::{Arrival, LengthDist, Trace};

impl LengthDist {
    /// Interactive-chat tenant: short prompts, short replies.
    pub fn chat() -> LengthDist {
        LengthDist {
            prompt_mu: 2.7, // median ≈ 15 tokens
            prompt_sigma: 0.6,
            max_prompt: 256,
            mean_output: 32.0,
            max_new_tokens: 128,
        }
    }

    /// Batch-summarization tenant: long documents, long outputs.
    pub fn summarize() -> LengthDist {
        LengthDist {
            prompt_mu: 4.6, // median ≈ 100 tokens, heavy tail
            prompt_sigma: 0.6,
            max_prompt: 512,
            mean_output: 160.0,
            max_new_tokens: 256,
        }
    }
}

impl Trace {
    /// Steady Poisson arrivals at `rps` with Alpaca-like lengths.
    pub fn steady(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), duration_s, seed)
    }

    /// Diurnal sine around `mean_rps` (amplitude 0.7, one full cycle over
    /// the run, so the trace exercises both crest and trough).
    pub fn diurnal(mean_rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Diurnal { mean: mean_rps, amplitude: 0.7, period_s: duration_s },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Burst spike: base load at `rps` with a 3× window over the middle
    /// fifth of the run.
    pub fn burst(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Burst {
                base: rps,
                burst: 3.0 * rps,
                start_s: 0.4 * duration_s,
                end_s: 0.6 * duration_s,
            },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Ramp from 20% to 180% of `rps` over the run (mean ≈ `rps`).
    pub fn ramp(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Ramp { from: 0.2 * rps, to: 1.8 * rps },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Two-tenant mix at an aggregate `rps`: 70% interactive chat, 30%
    /// batch summarization, each with its own length distribution. Seeds
    /// are derived per-tenant so the mix is deterministic.
    pub fn two_tenant(rps: f64, duration_s: f64, seed: u64) -> Trace {
        let chat = Trace::generate(
            Arrival::Poisson { rps: 0.7 * rps },
            LengthDist::chat(),
            duration_s,
            seed ^ 0xC047,
        );
        let batch = Trace::generate(
            Arrival::Poisson { rps: 0.3 * rps },
            LengthDist::summarize(),
            duration_s,
            seed ^ 0xBA7C,
        );
        Trace::merge(vec![chat, batch])
    }

    /// The full scenario sweep at a common target rate — what the
    /// fig10/fig11 benches iterate.
    pub fn scenario_sweep(rps: f64, duration_s: f64, seed: u64) -> Vec<(&'static str, Trace)> {
        vec![
            ("steady", Trace::steady(rps, duration_s, seed)),
            ("diurnal", Trace::diurnal(rps, duration_s, seed)),
            ("burst", Trace::burst(rps, duration_s, seed)),
            ("ramp", Trace::ramp(rps, duration_s, seed)),
            ("two-tenant", Trace::two_tenant(rps, duration_s, seed)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_scenarios_deterministically() {
        let a = Trace::scenario_sweep(15.0, 30.0, 9);
        let b = Trace::scenario_sweep(15.0, 30.0, 9);
        assert_eq!(a.len(), 5);
        for ((name_a, ta), (name_b, tb)) in a.iter().zip(&b) {
            assert_eq!(name_a, name_b);
            assert_eq!(ta.requests, tb.requests, "{name_a} not deterministic");
            assert!(!ta.is_empty(), "{name_a} generated no requests");
        }
        let names: Vec<_> = a.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, ["steady", "diurnal", "burst", "ramp", "two-tenant"]);
    }

    #[test]
    fn two_tenant_mixes_length_regimes() {
        let t = Trace::two_tenant(20.0, 60.0, 3);
        let long_prompts = t.requests.iter().filter(|r| r.prompt_tokens > 64).count();
        let short_prompts = t.requests.iter().filter(|r| r.prompt_tokens <= 32).count();
        assert!(long_prompts > t.len() / 10, "batch tenant missing: {long_prompts}");
        assert!(short_prompts > t.len() / 3, "chat tenant missing: {short_prompts}");
        // aggregate rate ≈ requested
        let rps = t.mean_rps(60.0);
        assert!((rps - 20.0).abs() < 3.0, "rps {rps}");
    }

    #[test]
    fn burst_triples_mid_window_rate() {
        let t = Trace::burst(10.0, 50.0, 4);
        let during = t.requests.iter()
            .filter(|r| (20.0..30.0).contains(&r.arrival_s))
            .count() as f64 / 10.0;
        let outside = t.requests.iter()
            .filter(|r| !(20.0..30.0).contains(&r.arrival_s))
            .count() as f64 / 40.0;
        assert!(during > 2.0 * outside, "burst {during} vs base {outside}");
    }

    #[test]
    fn ramp_mean_near_target() {
        let t = Trace::ramp(20.0, 60.0, 5);
        let rps = t.mean_rps(60.0);
        assert!((rps - 20.0).abs() < 4.0, "rps {rps}");
    }
}
