//! `artifacts/manifest.json` — the contract between the Python compile path
//! and the Rust runtime. Written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Unique artifact key: `config__module__b{B}[_s{S}]`.
    pub name: String,
    /// Path relative to the artifacts root.
    pub path: String,
    /// Module kind string (decoder_layer, attn, ffn, embed, lm_head, …).
    pub module: String,
    /// "prefill" | "decode".
    pub phase: String,
    /// Model config this artifact was lowered for.
    pub config: String,
    /// Batch bucket the shapes were fixed at.
    pub batch: usize,
    /// Sequence bucket (0 for decode artifacts).
    pub seq: usize,
    /// Argument shapes (for validation).
    pub arg_shapes: Vec<Vec<usize>>,
    /// Names of the tuple outputs, in order.
    pub outputs: Vec<String>,
}

/// A weight tensor dump.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    /// Path relative to the artifacts root.
    pub path: String,
    /// Tensor dimensions.
    pub shape: Vec<usize>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Batch-size buckets artifacts were compiled at (ascending).
    pub batch_buckets: Vec<usize>,
    /// Sequence-length buckets (ascending).
    pub seq_buckets: Vec<usize>,
    /// KV-cache capacity artifacts were compiled for.
    pub max_seq_len: usize,
    /// Model configs by name.
    pub configs: BTreeMap<String, ModelConfig>,
    /// config name → tensor name → weight dump.
    pub weights: BTreeMap<String, BTreeMap<String, WeightEntry>>,
    artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load and parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        Manifest::from_json(&j)
    }

    /// Parse an already-loaded manifest document (format 1, hlo-text).
    pub fn from_json(j: &Json) -> Result<Manifest> {
        anyhow::ensure!(
            j.req("format").as_u64() == Some(1),
            "unsupported manifest format"
        );
        anyhow::ensure!(
            j.req("interchange").as_str() == Some("hlo-text"),
            "runtime only loads hlo-text artifacts"
        );
        let buckets = |key: &str| -> Vec<usize> {
            j.req(key)
                .as_arr()
                .expect(key)
                .iter()
                .map(|v| v.as_usize().expect(key))
                .collect()
        };
        let mut configs = BTreeMap::new();
        for (name, cj) in j.req("configs").as_obj().context("configs")? {
            configs.insert(name.clone(), ModelConfig::from_json(cj));
        }
        let mut weights = BTreeMap::new();
        for (cfg, wj) in j.req("weights").as_obj().context("weights")? {
            let mut m = BTreeMap::new();
            for (name, e) in wj.as_obj().context("weight entry")? {
                m.insert(
                    name.clone(),
                    WeightEntry {
                        path: e.req("path").as_str().context("path")?.to_string(),
                        shape: e
                            .req("shape")
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .map(|v| v.as_usize().unwrap())
                            .collect(),
                    },
                );
            }
            weights.insert(cfg.clone(), m);
        }
        let mut artifacts = BTreeMap::new();
        for e in j.req("artifacts").as_arr().context("artifacts")? {
            let a = ArtifactEntry {
                name: e.req("name").as_str().context("name")?.to_string(),
                path: e.req("path").as_str().context("path")?.to_string(),
                module: e.req("module").as_str().context("module")?.to_string(),
                phase: e.req("phase").as_str().context("phase")?.to_string(),
                config: e.req("config").as_str().context("config")?.to_string(),
                batch: e.req("batch").as_usize().context("batch")?,
                seq: e.req("seq").as_usize().context("seq")?,
                arg_shapes: e
                    .req("args")
                    .as_arr()
                    .context("args")?
                    .iter()
                    .map(|a| {
                        a.req("shape")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|v| v.as_usize().unwrap())
                            .collect()
                    })
                    .collect(),
                outputs: e
                    .req("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(|v| v.as_str().unwrap().to_string())
                    .collect(),
            };
            artifacts.insert(a.name.clone(), a);
        }
        Ok(Manifest {
            batch_buckets: buckets("batch_buckets"),
            seq_buckets: buckets("seq_buckets"),
            max_seq_len: j.req("max_seq_len").as_usize().context("max_seq_len")?,
            configs,
            weights,
            artifacts,
        })
    }

    /// Look up an artifact by its full name.
    pub fn artifact(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.get(name)
    }

    /// All artifacts, in name order.
    pub fn artifacts(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.artifacts.values()
    }

    /// Smallest bucket ≥ n (None if n exceeds the largest bucket).
    pub fn batch_bucket(&self, n: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().find(|&b| b >= n)
    }

    /// Smallest sequence bucket ≥ n (None past the largest bucket).
    pub fn seq_bucket(&self, n: usize) -> Option<usize> {
        self.seq_buckets.iter().copied().find(|&s| s >= n)
    }

    /// Artifact name for (config, module, phase) at a bucket shape.
    pub fn artifact_name(
        &self,
        config: &str,
        module_fn: &str,
        batch: usize,
        seq: Option<usize>,
    ) -> String {
        match seq {
            Some(s) => format!("{config}__{module_fn}__b{batch}_s{s}"),
            None => format!("{config}__{module_fn}__b{batch}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    fn built() -> Option<Manifest> {
        let p = default_artifacts_dir().join("manifest.json");
        p.exists().then(|| Manifest::load(&p).expect("manifest parses"))
    }

    #[test]
    fn bucket_selection_rounds_up() {
        let Some(m) = built() else { return };
        assert_eq!(m.batch_bucket(1), Some(1));
        assert_eq!(m.batch_bucket(3), Some(4));
        assert_eq!(m.batch_bucket(8), Some(8));
        assert_eq!(m.batch_bucket(9), None);
        assert_eq!(m.seq_bucket(17), Some(32));
    }

    #[test]
    fn tiny_config_and_artifacts_present() {
        let Some(m) = built() else { return };
        let cfg = &m.configs["tiny-llama"];
        assert_eq!(cfg.d_model, 64);
        let name = m.artifact_name("tiny-llama", "layer_prefill", 2, Some(16));
        let a = m.artifact(&name).expect("layer_prefill b2 s16");
        assert_eq!(a.batch, 2);
        assert_eq!(a.arg_shapes[0], vec![2, 16, 64]);
        // decode artifact (no seq suffix)
        let d = m.artifact(&m.artifact_name("tiny-llama", "layer_decode", 4, None));
        assert!(d.is_some());
    }

    #[test]
    fn paper_configs_ride_along() {
        let Some(m) = built() else { return };
        assert_eq!(m.configs["llama2-13b"].n_layers, 40);
        assert_eq!(m.configs["llama2-70b"].d_model, 8192);
    }

    #[test]
    fn weight_entries_have_files() {
        let Some(m) = built() else { return };
        let w = &m.weights["tiny-llama"];
        assert!(w.contains_key("emb"));
        assert!(w.contains_key("layer0.wq"));
        for e in w.values() {
            assert!(default_artifacts_dir().join(&e.path).exists());
        }
    }
}
