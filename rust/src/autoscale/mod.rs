//! The dynamic auto-scaling mechanism (§4) — CoCoServe's core
//! contribution, structured as **pure planners** feeding the plan
//! executor.
//!
//! * [`speedup`] — the modified-Amdahl model, Eqs. 1–4,
//! * [`scale_up`] — Algorithm 1: greedy continuity-sorted layer
//!   replication, returning a [`crate::plan::ScalePlan`],
//! * [`scale_down`] — Algorithm 2: migrate → evict → reduce, graduated,
//!   returning a plan plus the phase-3 batch decision,
//! * [`controller`] — the §5 threshold controller closing the loop with
//!   the monitor, emitting [`controller::PlannedDecision`]s.
//!
//! Ownership rule: planners never take `&mut Cluster`. All mutation flows
//! through [`crate::ops::PlanExecutor`] / [`crate::ops::PlanExecution`].

pub mod controller;
pub mod scale_down;
pub mod scale_up;
pub mod speedup;

pub use controller::{
    Controller, ControllerConfig, ControllerInputs, Decision, PlanCtx, PlannedDecision,
};
pub use scale_down::{
    memory_violation, scale_down, Pressure, ScaleDownConfig, ScaleDownPlan,
    MEM_VIOLATION_FRAC,
};
pub use scale_up::{scale_up, ScaleUpConfig, ScaleUpPlan};
