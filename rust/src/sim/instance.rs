//! Per-instance serving state machine.
//!
//! One [`Instance`] owns everything a single simulated model deployment
//! needs to serve: its [`Placement`], [`Scheduler`], KV-cache allocator,
//! monitor, and OOM/penalty bookkeeping. The event kernel
//! ([`crate::sim::Simulation`]) only decides *when* an instance runs; every
//! *what* — starting prefill/decode steps, admitting KV, handling OOM per
//! policy, applying scaling-plan ops as their events fire — happens here,
//! against the shared [`Cluster`] ledgers. That separation is what lets
//! instances advance at their own step cadence (heterogeneous layer
//! counts, different batch sizes) instead of a global tick.
//!
//! ### In-flight plan execution
//!
//! Scaling is not instantaneous: an admitted [`ScalePlan`] becomes a
//! sequence of `OpStarted`/`OpCompleted` events whose durations come from
//! the plan's dry-run costing. Replication overlaps serving entirely (the
//! source replica keeps serving; only the §6.5 communication-setup barrier
//! pauses the instance when the plan lands). Migration blocks *only the
//! moved module* — modeled as the instance not starting new steps while a
//! migrate op is in flight (every step traverses the moved module, so it
//! is on the critical path), while steps already in flight finish
//! untouched. A mid-plan failure rolls every applied op back.

use crate::autoscale::{scale_down, Pressure, ScaleDownConfig};
use crate::cluster::Cluster;
use crate::kvcache::{ContiguousKvCache, KvCache, KvStats, PagedKvCache};
use crate::mempress::{MempressGovernor, PressureCause, PressureView, Relief};
use crate::model::cost::{CostModel, INT8_BYTES, SWAP_QUALITY_PENALTY_PER_STEP};
use crate::monitor::{Completion, Monitor};
use crate::ops::{ModuleOps, OpCost, PlanExecution, PlanExecutor, REPLICA_COMM_SETUP_S};
use crate::placement::{Placement, PlacementProfile};
use crate::plan::{ModuleOp, PlanCost, ScalePlan};
use crate::scheduler::{Scheduler, Step};

use super::metrics::{OpEvent, OpPhase, ScaleStats};
use super::{OomBehavior, SimConfig, SimPolicy, DECODE_BUSY_FRACTION, SYNC_PAUSE_S};

/// Read-only per-event context the kernel hands to instance methods.
pub(crate) struct StepCtx<'a> {
    pub cfg: &'a SimConfig,
    pub cost: &'a CostModel,
    pub now: f64,
}

/// What a step-start attempt did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum StepStart {
    /// Nothing runnable (empty, or a static batch still filling).
    Idle,
    /// A step is in flight until `until`; completion carries `token`.
    Busy { until: f64, token: u64 },
    /// A KV admission OOM was handled per policy; the kernel should retry
    /// after a backoff instead of spinning at the same timestamp.
    OomStall,
    /// A scaling op blocks the serving path (in-flight migration or the
    /// post-replication sync barrier); retry at `until`.
    Blocked { until: f64 },
}

/// Fleet lifecycle state of an instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Lifecycle {
    /// Serving and accepting routed traffic (once past its cold start).
    Active,
    /// No longer offered new work by the router; finishes what it holds.
    Draining,
    /// Drained and released: every ledger allocation freed, devices no
    /// longer billed for this instance.
    Retired,
}

/// A request shed by OOM handling, handed back to the coordinator for
/// re-routing (fleet mode only — local requeue is the default).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Shed {
    pub id: u64,
    /// Original arrival time (end-to-end latency keeps accruing across
    /// the re-route).
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Accumulated OOM-reload penalty the request carries with it.
    pub penalty: f64,
    /// SLO class the request entered the system with — preserved across
    /// every re-route so class-aware policies keep honoring it.
    pub class: crate::workload::SloClass,
    /// Why the request was shed (telemetry: distinguishes OOM sheds from
    /// SLO preemptions and failure-domain evacuations in the trace).
    pub cause: crate::telemetry::ShedCause,
}

/// A plan being executed op-by-op by the event kernel.
pub(crate) struct InflightPlan {
    pub plan: ScalePlan,
    /// Undo log + launch-amortization cursor for the applied prefix.
    pub exec: PlanExecution,
    /// Admission-time per-op costs — the scheduled event durations.
    pub costs: Vec<OpCost>,
    /// Guards against events of superseded plans.
    pub epoch: u64,
    /// Next op expected to complete.
    pub next_op: usize,
    /// Replication plans pay the §6.5 comm-setup barrier at completion.
    pub had_replication: bool,
}

/// What repairing an instance after a device failure did (the kernel
/// turns this into audit records).
#[derive(Debug)]
pub(crate) enum FailRecovery {
    /// The instance held nothing on the dead device and no in-flight op
    /// targeted it — untouched.
    Untouched,
    /// Placement repaired on surviving devices; the instance keeps serving.
    Recovered {
        /// An in-flight plan touching the device was rolled back first.
        plan_aborted: bool,
        /// Layers whose replica on the dead device was dropped (the module
        /// survives elsewhere — no bytes moved).
        replicas_dropped: Vec<usize>,
        /// Layers whose dead primary was replaced by promoting a surviving
        /// replica in place (no bytes moved — the replica is a full copy).
        promoted: Vec<(usize, usize)>,
        /// Emergency migrations: `(description, dst_device, bytes)` of each
        /// sole-copy module re-fetched onto a surviving device
        /// (copy-then-verify-then-free; the free side is vacuous — the
        /// source died with the device).
        migrated: Vec<(String, usize, f64)>,
        /// In-flight requests shed back to the router for re-routing.
        shed: usize,
    },
    /// No surviving device had room for a sole-copy module: the instance
    /// was force-released (every tag freed, requests shed).
    Lost {
        /// An in-flight plan was rolled back before release.
        plan_aborted: bool,
        /// Requests flushed to the shed outbox for re-routing.
        shed: usize,
    },
}

/// What applying one in-flight op event did (for the kernel's log).
#[derive(Debug)]
pub(crate) enum OpOutcome {
    /// Event belongs to a superseded/aborted plan — ignored.
    Stale,
    /// Op transfer began.
    Started { desc: String },
    /// Op effects applied; `finished` = whole plan landed.
    Applied { desc: String, cost: OpCost, finished: bool },
    /// Op failed against the live ledgers; the plan was rolled back.
    Aborted { desc: String },
}

/// One simulated model instance.
pub(crate) struct Instance {
    pub id: usize,
    pub placement: Placement,
    /// Compiled step-cost profile of `placement` — the zero-allocation
    /// roofline kernel. Invalidated (recompiled, epoch bumped) only when
    /// the placement mutates: a plan op landing (`OpCompleted`), a
    /// mid-flight rollback, or an emergency scale-down. Steady-state
    /// steps never recompile.
    pub profile: PlacementProfile,
    /// Monotone placement revision — the profile cache key.
    pub placement_rev: u64,
    /// Ledger tag of this instance's mirrored KV reservation (cached —
    /// `sync_kv` runs on every step).
    kv_tag: String,
    pub scheduler: Scheduler,
    pub kv: Box<dyn KvCache>,
    pub policy: SimPolicy,
    /// Current max batch (phase-3 scale-down shrinks it).
    pub batch_size: usize,
    /// Wall time when the in-flight step completes (None = idle).
    pub busy_until: Option<f64>,
    /// Monotone step counter; stale `StepComplete` events are detected by
    /// comparing against the token they carry.
    pub step_token: u64,
    /// Serving-path block horizon: new steps cannot start before this
    /// (in-flight migrations, post-replication sync barrier, emergency
    /// corrective pauses).
    pub op_block_until: f64,
    /// The scaling plan currently executing in flight, if any.
    pub inflight: Option<InflightPlan>,
    /// Monotone plan counter; events carry the epoch they were scheduled
    /// under so an aborted plan's remaining events die quietly.
    pub plan_epoch: u64,
    /// Steps since the last OOM (drives batch-size recovery after backoff).
    pub clean_steps: u64,
    pub monitor: Monitor,
    /// Peak KV accounting observed (Fig. 9 reads peaks, not end-state).
    pub kv_peak: KvStats,
    /// Earliest wake-up already scheduled for this instance (dedup).
    pub scheduled_wake: Option<f64>,
    /// Requests routed here but not yet delivered (the `Routed` event is
    /// still in flight). Shard-local load state: keeping it on the
    /// instance rather than in a coordinator-side vector means the
    /// sharded kernel's router reads it without cross-shard traffic.
    pub outstanding_routes: u32,
    /// Fleet lifecycle state (always `Active` outside fleet mode).
    pub lifecycle: Lifecycle,
    /// Earliest time the router may offer this instance traffic (spin-up
    /// cold start; 0.0 for instances deployed before the run).
    pub active_after: f64,
    /// Hand OOM-shed requests back to the coordinator instead of
    /// requeueing them locally (set by the kernel in fleet mode).
    pub reroute_shed: bool,
    /// Requests shed since the kernel last collected them.
    pub shed_outbox: Vec<Shed>,
    /// Telemetry enabled for this run (cached from `SimConfig` at
    /// deploy). Gates every `trace_outbox` push so telemetry-off runs
    /// allocate nothing and stay byte-identical.
    pub trace_enabled: bool,
    /// Trace events recorded on paths deep inside the instance (OOM
    /// episodes, governor decisions) since the kernel last drained them
    /// — the telemetry twin of `shed_outbox`.
    pub trace_outbox: Vec<crate::telemetry::TraceEvent>,
    /// Shape of the step most recently started — `(batch, is_decode)` —
    /// so the kernel can record the step span without threading the
    /// tracer through `start_step`. Set unconditionally (two word
    /// stores; telemetry-off runs just never read it).
    pub last_step_shape: (usize, bool),
    /// Allow a waiting latency-sensitive request to preempt an
    /// all-best-effort running batch at the next token boundary (set by
    /// the kernel only under a class-aware routing policy; always false
    /// otherwise, so classless runs never take the preemption path).
    pub preempt_premium: bool,
    /// Best-effort batches preempted for a latency-sensitive arrival.
    pub preemptions: u64,
    /// Request metadata by id (arrival, prompt, output, SLO class) for
    /// completions. Class rides in the last slot so positional `.1`
    /// prompt lookups predating SLO classes stay valid.
    pub requests: std::collections::BTreeMap<u64, (f64, usize, usize, crate::workload::SloClass)>,
    /// Per-request accumulated penalty (OOM reloads).
    pub penalties: std::collections::BTreeMap<u64, f64>,
    /// Unique requests ever caught in an OOM (Fig. 11a numerator).
    pub oom_victims: std::collections::BTreeSet<u64>,
    /// Layers currently serving int8 weights (landed `SwapPrecision`
    /// ops). Always empty without a governor — the decode roofline takes
    /// the mixed-precision path only when non-empty, so ungoverned runs
    /// stay bit-identical to the pre-governor kernel.
    pub quantized_layers: std::collections::BTreeSet<usize>,
    /// Memory-pressure governor (`Some` iff `SimConfig::mempress` is set).
    pub governor: Option<MempressGovernor>,
    /// The run's full weight precision, cached from `SimConfig` for swap
    /// bookkeeping on paths without a `StepCtx` (rollback unwinding).
    dtype_bytes: usize,
}

impl Instance {
    /// Build an instance and deploy its weights onto the cluster ledgers.
    pub fn deploy(
        id: usize,
        placement: Placement,
        policy: SimPolicy,
        cfg: &SimConfig,
        cost: &CostModel,
        cluster: &mut Cluster,
    ) -> Instance {
        let ops = ModuleOps::new(cost, cfg.dtype_bytes, &format!("inst{id}"));
        ops.deploy_instance(cluster, &placement)
            .expect("instance deployment OOM");
        let bytes_per_token =
            cost.kv_cache_bytes(1, 1, cfg.dtype_bytes) * cfg.model.n_layers as f64;
        // A governed instance pre-grants a finite KV pool (the reservation
        // a real engine makes at startup), sized from the post-deploy free
        // bytes of its layer-0 device; the governor resizes it elastically
        // under pressure. Ungoverned instances keep the unbounded pools
        // (and the reserved-bytes ledger mirror) of the pre-governor
        // kernel, so every existing golden stays byte-identical.
        let pool = match &cfg.mempress {
            Some(mp) => {
                let d0 = placement.primary_device(0);
                cluster.device(d0).free_bytes() * mp.initial_pool_frac
            }
            None => f64::INFINITY,
        };
        let kv: Box<dyn KvCache> = if policy.paged_kv {
            Box::new(PagedKvCache::new(pool, bytes_per_token, 16))
        } else {
            Box::new(ContiguousKvCache::new(pool, bytes_per_token, cfg.max_seq_len))
        };
        let profile = PlacementProfile::compile(&placement, cluster, 0);
        Instance {
            id,
            placement,
            profile,
            placement_rev: 0,
            kv_tag: format!("inst{id}/kv"),
            scheduler: Scheduler::new(policy.scheduler),
            kv,
            policy,
            batch_size: policy.scheduler.max_batch,
            busy_until: None,
            step_token: 0,
            op_block_until: 0.0,
            inflight: None,
            plan_epoch: 0,
            clean_steps: 0,
            monitor: Monitor::new(cfg.slo_latency_s),
            kv_peak: Default::default(),
            scheduled_wake: None,
            outstanding_routes: 0,
            lifecycle: Lifecycle::Active,
            active_after: 0.0,
            reroute_shed: false,
            shed_outbox: Vec::new(),
            trace_enabled: cfg.telemetry.is_some(),
            trace_outbox: Vec::new(),
            last_step_shape: (0, false),
            preempt_premium: false,
            preemptions: 0,
            requests: Default::default(),
            penalties: Default::default(),
            oom_victims: Default::default(),
            quantized_layers: Default::default(),
            governor: cfg.mempress.map(MempressGovernor::new),
            dtype_bytes: cfg.dtype_bytes,
        }
    }

    pub fn pending_ids(&self) -> Vec<u64> {
        self.scheduler.pending_ids()
    }

    /// Has runnable or waiting work (used by the kernel's readiness sweep).
    pub fn has_work(&self) -> bool {
        !self.scheduler.is_idle()
    }

    /// May the router offer this instance new traffic at `now`? Active,
    /// past its spin-up cold start, not draining.
    pub fn accepting(&self, now: f64) -> bool {
        self.lifecycle == Lifecycle::Active && now + 1e-12 >= self.active_after
    }

    /// Deliver a routed request: register its metadata (original arrival —
    /// end-to-end latency spans re-routes) plus any penalty it carries,
    /// and submit it to the scheduler.
    pub fn deliver(&mut self, req: crate::workload::Request, penalty: f64) {
        self.requests
            .insert(req.id, (req.arrival_s, req.prompt_tokens, req.output_tokens, req.class));
        if penalty > 0.0 {
            *self.penalties.entry(req.id).or_insert(0.0) += penalty;
        }
        self.scheduler.submit(req);
    }

    /// Live latency-sensitive requests (pending + running) — the premium
    /// numerator of the fleet telemetry window under class-aware
    /// policies. (Routed-but-undelivered requests are not counted; their
    /// class is still in flight with the `Routed` event.)
    pub fn premium_live(&self) -> usize {
        self.scheduler
            .running_view()
            .iter()
            .map(|(id, _, _)| *id)
            .chain(self.pending_ids())
            .filter(|id| {
                self.requests.get(id).map(|r| r.3)
                    == Some(crate::workload::SloClass::LatencySensitive)
            })
            .count()
    }

    /// Fully drained? (Nothing queued, running, or scaling in flight.)
    pub fn drained(&self) -> bool {
        self.scheduler.is_idle() && self.busy_until.is_none() && self.inflight.is_none()
    }

    /// Release the instance: free every ledger allocation it holds (module
    /// weights, replicas, migrated modules, the KV mirror) and mark it
    /// retired. The caller stops billing its devices from here on.
    pub fn release(&mut self, cluster: &mut Cluster) {
        debug_assert!(self.drained(), "release before drain completes");
        self.free_all_tags(cluster);
        self.lifecycle = Lifecycle::Retired;
    }

    /// Free every `inst{id}/`-prefixed ledger tag on every device.
    fn free_all_tags(&self, cluster: &mut Cluster) {
        let prefix = format!("inst{}/", self.id);
        for d in 0..cluster.n() {
            let dev = cluster.device_mut(d);
            let tags: Vec<String> = dev
                .allocations()
                .filter(|(t, _)| t.starts_with(&prefix))
                .map(|(t, _)| t.to_string())
                .collect();
            for t in tags {
                let _ = dev.free(&t);
            }
        }
    }

    /// Requests still owned by this instance: pending in the scheduler or
    /// in the running batch. (The `requests` metadata map also retains
    /// completed ids — those must never be shed again.)
    fn live_ids(&self) -> Vec<u64> {
        let mut ids: std::collections::BTreeSet<u64> = self
            .scheduler
            .running_view()
            .iter()
            .map(|(id, _, _)| *id)
            .collect();
        ids.extend(self.pending_ids());
        ids.into_iter().collect()
    }

    /// Shed every live request to the outbox for coordinator re-routing
    /// (the no-request-lost failure path): drop their KV, carry their
    /// accumulated penalties, rebuild the scheduler empty, and invalidate
    /// any step in flight. Returns the number of requests shed.
    pub fn shed_live_requests(&mut self) -> usize {
        let ids = self.live_ids();
        for id in &ids {
            self.kv.remove_sequence(*id);
            if let Some((arr, p, o, class)) = self.requests.remove(id) {
                let penalty = self.penalties.remove(id).unwrap_or(0.0);
                self.shed_outbox.push(Shed {
                    id: *id,
                    arrival_s: arr,
                    prompt_tokens: p,
                    output_tokens: o,
                    penalty,
                    class,
                    cause: crate::telemetry::ShedCause::Failure,
                });
            }
        }
        self.scheduler = Scheduler::new(self.scheduler.cfg);
        self.busy_until = None;
        self.step_token += 1; // stale StepComplete events die quietly
        ids.len()
    }

    /// Release outside the drain-then-release protocol: an instance that
    /// failed (or was preempted while `Draining`) flushes every live
    /// request to the shed outbox, drops any in-flight plan (its tags are
    /// freed wholesale below — the caller rolls back first if it wants the
    /// op-event record), frees every `inst{id}/` ledger tag on every
    /// device, and retires. No request is lost and no tag leaks. Returns
    /// the number of requests shed.
    pub fn force_release(&mut self, cluster: &mut Cluster) -> usize {
        let shed = self.shed_live_requests();
        self.inflight = None;
        self.plan_epoch += 1; // kill any remaining plan events
        self.op_block_until = 0.0;
        self.free_all_tags(cluster);
        self.lifecycle = Lifecycle::Retired;
        shed
    }

    /// Repair this instance after `device` died (its ledger already
    /// cleared by [`crate::cluster::Device::fail`]). In order:
    ///
    /// 1. an in-flight plan that reads or writes the dead device rolls
    ///    back via the undo log (rollback never re-acquires memory — the
    ///    dead device's `restore_alloc` is a no-op);
    /// 2. replicas on the dead device are dropped from the placement
    ///    (the module survives elsewhere);
    /// 3. a dead primary with surviving replicas promotes one in place
    ///    (no bytes move — the replica is a full copy);
    /// 4. sole-copy modules (primary-resident layers, migrated sub-layer
    ///    modules, embed/head globals) are emergency-migrated onto the
    ///    surviving device with the most free bytes — copy-then-verify-
    ///    then-free with a vacuous free side; if no survivor has room the
    ///    whole instance is force-released ([`FailRecovery::Lost`]);
    /// 5. every live request is shed back to the router (its KV shards on
    ///    the dead device are gone) and the step-cost profile recompiles.
    pub fn recover_from_failure(
        &mut self,
        ctx: &StepCtx<'_>,
        cluster: &mut Cluster,
        device: usize,
        scale: &mut ScaleStats,
    ) -> FailRecovery {
        let holds = self.device_set().contains(&device)
            || self.placement.migrations().any(|(_, &d)| d == device);
        let plan_touches = self.inflight.as_ref().map_or(false, |fl| {
            fl.plan.ops.iter().any(|o| o.touches_device(device))
        });
        if !holds && !plan_touches {
            return FailRecovery::Untouched;
        }

        // 1. unwind any plan entangled with the dead device
        let plan_aborted = self.inflight.is_some();
        self.abort_inflight(ctx.now, cluster, scale);

        if !holds {
            // the plan was the only entanglement — rollback repaired it
            return FailRecovery::Recovered {
                plan_aborted,
                replicas_dropped: Vec::new(),
                promoted: Vec::new(),
                migrated: Vec::new(),
                shed: 0,
            };
        }

        let ops = self.module_ops(ctx);

        // 2. drop dead replicas (module survives on its primary)
        let mut replicas_dropped = Vec::new();
        for l in 0..self.placement.n_layers {
            if self.placement.remove_replica(l, device) {
                replicas_dropped.push(l);
            }
        }

        // 3./4. repair layers whose primary died
        let mut promoted = Vec::new();
        let mut migrated = Vec::new();
        for l in self.placement.primaries_on(device) {
            let survivors = self.placement.layer_devices(l);
            if let Some(&r) = survivors.iter().find(|&&d| d != device) {
                // promote the first surviving replica (creation order —
                // deterministic); its ledger copy is already in place
                self.placement.remove_replica(l, r);
                self.placement.migrate_layer(l, r);
                promoted.push((l, r));
            } else {
                // sole copy died: re-fetch onto the roomiest survivor
                let m = crate::model::ModuleId::layer(
                    crate::model::ModuleKind::DecoderLayer,
                    l,
                );
                let bytes = ops.module_bytes(crate::model::ModuleKind::DecoderLayer);
                match Self::emergency_alloc(cluster, bytes, &ops, &m) {
                    Some(dst) => {
                        self.placement.migrate_layer(l, dst);
                        // the re-fetched copy is full precision
                        self.quantized_layers.remove(&l);
                        migrated.push((format!("L{l}"), dst, bytes));
                    }
                    None => {
                        let shed = self.force_release(cluster);
                        return FailRecovery::Lost { plan_aborted, shed };
                    }
                }
            }
        }

        // 4b. migrated sub-layer modules stranded on the dead device
        let stranded: Vec<crate::model::ModuleId> = self
            .placement
            .migrations()
            .filter(|&(_, &d)| d == device)
            .map(|(m, _)| *m)
            .collect();
        for m in stranded {
            let bytes = ops.module_bytes(m.kind);
            match Self::emergency_alloc(cluster, bytes, &ops, &m) {
                Some(dst) => {
                    self.placement.migrate_module(m, dst);
                    migrated.push((format!("{m}"), dst, bytes));
                }
                None => {
                    let shed = self.force_release(cluster);
                    return FailRecovery::Lost { plan_aborted, shed };
                }
            }
        }

        // 4c. embed/head globals: if their bytes died with the device
        // (no live device holds a copy), re-fetch them at the repaired
        // layer-0 home
        for kind in [crate::model::ModuleKind::Embed, crate::model::ModuleKind::LmHead] {
            let m = crate::model::ModuleId::global(kind);
            if self.placement.module_override(m) == Some(device) {
                // the override pointed at the corpse — drop it so the
                // module homes with the (repaired, live) layer-0 primary
                self.placement.unmigrate_module(m);
            }
            let alive = (0..cluster.n())
                .any(|d| d != device && cluster.device(d).has_alloc(&ops.tag(&m, d)));
            if alive {
                continue;
            }
            let home = self.placement.module_device(m);
            debug_assert_ne!(home, device, "layer-0 primary repaired above");
            let bytes = ops.module_bytes(kind);
            if cluster.device_mut(home).alloc(&ops.tag(&m, home), bytes).is_ok() {
                migrated.push((format!("{m}"), home, bytes));
            } else {
                match Self::emergency_alloc(cluster, bytes, &ops, &m) {
                    Some(dst) => {
                        self.placement.migrate_module(m, dst);
                        migrated.push((format!("{m}"), dst, bytes));
                    }
                    None => {
                        let shed = self.force_release(cluster);
                        return FailRecovery::Lost { plan_aborted, shed };
                    }
                }
            }
        }

        // 5. requests lose their dead-device KV shards — shed for re-route
        let shed = self.shed_live_requests();
        self.recompile_profile(cluster);
        let _ = self.sync_kv(cluster);
        FailRecovery::Recovered { plan_aborted, replicas_dropped, promoted, migrated, shed }
    }

    /// Allocate `bytes` for module `m` on the surviving device with the
    /// most free bytes (ascending-id tie-break — deterministic). Returns
    /// the chosen device, or `None` when no survivor has room.
    fn emergency_alloc(
        cluster: &mut Cluster,
        bytes: f64,
        ops: &ModuleOps<'_>,
        m: &crate::model::ModuleId,
    ) -> Option<usize> {
        let mut order: Vec<usize> = cluster.live_devices();
        order.sort_by(|&a, &b| {
            cluster
                .device(b)
                .free_bytes()
                .partial_cmp(&cluster.device(a).free_bytes())
                .unwrap()
                .then(a.cmp(&b))
        });
        for d in order {
            let tag = ops.tag(m, d);
            if cluster.device_mut(d).alloc(&tag, bytes).is_ok() {
                return Some(d);
            }
        }
        None
    }

    /// All devices hosting any copy of any of this instance's layers.
    pub fn device_set(&self) -> std::collections::BTreeSet<usize> {
        self.profile.device_set.iter().copied().collect()
    }

    fn module_ops<'a>(&self, ctx: &StepCtx<'a>) -> ModuleOps<'a> {
        ModuleOps::new(ctx.cost, ctx.cfg.dtype_bytes, &format!("inst{}", self.id))
    }

    /// Recompile the step-cost profile after a placement mutation. The
    /// only call sites are the plan-epoch transitions: an op landing, a
    /// rollback, an emergency scale-down, and deploy itself.
    fn recompile_profile(&mut self, cluster: &Cluster) {
        self.placement_rev += 1;
        self.profile =
            PlacementProfile::compile(&self.placement, cluster, self.placement_rev);
    }

    // ---- step latency (the roofline substitute for real execution) -------
    //
    // Both step costs run on the compiled profile: allocation-free linear
    // scans over precompiled per-layer segments, bit-identical to the
    // uncompiled per-layer walk (see `placement::profile`).

    /// Per-layer prefill time across replicas: batch split (Fig. 4), max
    /// over replicas, plus scatter/gather per dataflow transition.
    pub fn prefill_step_time(&self, ctx: &StepCtx<'_>, batch: usize, seq: usize) -> f64 {
        debug_assert_eq!(self.profile.epoch, self.placement_rev, "stale profile");
        self.profile
            .prefill_step_time(ctx.cost, ctx.cfg.dtype_bytes, batch, seq)
    }

    /// Decode-iteration time: roofline max(compute, HBM bytes) per layer.
    pub fn decode_step_time(&self, ctx: &StepCtx<'_>, batch: usize, mean_ctx: usize) -> f64 {
        debug_assert_eq!(self.profile.epoch, self.placement_rev, "stale profile");
        self.profile
            .decode_step_time(ctx.cost, ctx.cfg.dtype_bytes, batch, mean_ctx)
    }

    /// Spread this step's busy time across the instance's device set.
    fn charge_busy(&self, cluster: &mut Cluster, seconds: f64) {
        let devices = &self.profile.device_set;
        let n = devices.len().max(1) as f64;
        for &d in devices {
            cluster.device_mut(d).add_busy(seconds / n);
        }
    }

    // ---- KV accounting ----------------------------------------------------

    /// Mirror the instance's KV reservation into device ledgers; on ledger
    /// OOM the caller must invoke [`Instance::handle_oom`]. Runs on every
    /// step, so it walks the profile's precompiled KV residency groups —
    /// no per-call Vec/BTreeMap/String. The per-device total is built by
    /// repeated addition of the per-layer share (count identical addends),
    /// matching the uncompiled per-layer accumulation bit-for-bit.
    ///
    /// KNOWN QUIRK (pre-existing, deliberately preserved): only devices in
    /// the *current* KV residency groups are resized. A device whose last
    /// KV layer migrates away keeps its final `inst{N}/kv` ledger size
    /// until (if ever) a layer returns — the mirror is never shrunk to
    /// zero there. The pre-profile implementation (per-layer walk into a
    /// fresh per-device map) had exactly the same behaviour, and the
    /// golden-replay byte-identity contract of this refactor forbids
    /// changing it here; a future change that is allowed to move the
    /// goldens should resize departed devices to zero.
    pub fn sync_kv(&mut self, cluster: &mut Cluster) -> Result<(), ()> {
        let stats = self.kv.stats();
        if stats.reserved_bytes > self.kv_peak.reserved_bytes {
            self.kv_peak = stats;
        }
        // Governed instances mirror the pre-granted pool capacity (the
        // real deployment reservation the governor resizes); ungoverned
        // instances mirror live reservations exactly as before, keeping
        // the golden metrics byte-identical.
        let mirrored = if self.governor.is_some() {
            self.kv.pool_bytes()
        } else {
            stats.reserved_bytes
        };
        let per_layer = mirrored / self.placement.n_layers as f64;
        for &(d, count) in &self.profile.kv_groups {
            let mut bytes = 0.0;
            for _ in 0..count {
                bytes += per_layer;
            }
            if cluster.device_mut(d).resize(&self.kv_tag, bytes).is_err() {
                self.monitor.record_oom();
                return Err(());
            }
        }
        Ok(())
    }

    /// Apply the policy's OOM behaviour (§2.3 / Fig. 3 / Algorithm 2).
    /// Governed instances walk the memory-pressure escalation ladder
    /// first; only an `Escalate` decision falls through to the shed below.
    pub fn handle_oom(
        &mut self,
        ctx: &StepCtx<'_>,
        cluster: &mut Cluster,
        scale: &mut ScaleStats,
        cause: PressureCause,
    ) {
        if self.trace_enabled {
            self.trace_outbox.push(crate::telemetry::TraceEvent::Mark {
                t: ctx.now,
                instance: self.id as i64,
                kind: crate::telemetry::MarkKind::OomEpisode,
                value: match cause {
                    PressureCause::PoolExhausted { deficit } => deficit,
                    PressureCause::LedgerMirror => 0.0,
                },
            });
        }
        if self.governor.is_some() && self.mempress_relieve(ctx.now, cluster, cause) {
            return;
        }
        match self.policy.oom {
            OomBehavior::FailBatch => {
                // Drop the running batch's KV; requests retry after the
                // model-reload penalty (§2.3: 8–25 s).
                let ids: Vec<u64> = self
                    .scheduler
                    .running_view()
                    .iter()
                    .map(|(id, _, _)| *id)
                    .collect();
                let penalty = ctx.cfg.oom_penalty_s;
                for id in &ids {
                    self.kv.remove_sequence(*id);
                    if self.reroute_shed {
                        // Fleet mode: hand the failed batch back to the
                        // coordinator; the request (and its accumulated
                        // penalty) leaves this instance entirely.
                        if let Some((arr, p, o, class)) = self.requests.remove(id) {
                            let carried = self.penalties.remove(id).unwrap_or(0.0) + penalty;
                            self.shed_outbox.push(Shed {
                                id: *id,
                                arrival_s: arr,
                                prompt_tokens: p,
                                output_tokens: o,
                                penalty: carried,
                                class,
                                cause: crate::telemetry::ShedCause::Oom,
                            });
                        }
                        continue;
                    }
                    *self.penalties.entry(*id).or_insert(0.0) += penalty;
                    // requeue as fresh arrival (retry)
                    if let Some(&(_, p, o, class)) = self.requests.get(id) {
                        self.scheduler.submit(crate::workload::Request {
                            id: *id,
                            arrival_s: ctx.now,
                            prompt_tokens: p,
                            output_tokens: o,
                            class,
                        });
                    }
                }
                // The scheduler has no cancel API: rebuild it, moving every
                // tracked id (previously pending + the resubmitted batch)
                // into the fresh pending queue.
                let cfg = self.scheduler.cfg;
                let mut fresh = Scheduler::new(cfg);
                for id in self.pending_ids() {
                    if let Some(&(_, p, o, class)) = self.requests.get(&id) {
                        fresh.submit(crate::workload::Request {
                            id,
                            arrival_s: ctx.now,
                            prompt_tokens: p,
                            output_tokens: o,
                            class,
                        });
                    }
                }
                self.scheduler = fresh;
                self.busy_until = None;
                // After a reload, the static engine restarts with a halved
                // batch (§2.3); every request in the failed batch counts
                // toward the Fig. 11a OOM occurrence rate.
                for id in &ids {
                    self.oom_victims.insert(*id);
                }
                self.batch_size = (self.batch_size / 2).max(1);
                self.clean_steps = 0;
                let _ = self.sync_kv(cluster);
            }
            OomBehavior::Preempt => {
                // Drop the newest running sequence's cache and requeue it.
                // If it is the only running sequence, re-queuing would spin
                // (nothing can ever fit) — fail it instead, with the reload
                // penalty, so the system keeps making progress.
                let view = self.scheduler.running_view();
                let victim = view.last().map(|(id, _, _)| *id);
                let only_one = view.len() <= 1;
                if let Some(id) = victim {
                    self.oom_victims.insert(id);
                    self.kv.remove_sequence(id);
                    self.scheduler.preempt(id);
                    if let Some(&(_, p, o, class)) = self.requests.get(&id) {
                        if only_one {
                            *self.penalties.entry(id).or_insert(0.0) +=
                                ctx.cfg.oom_penalty_s;
                        }
                        self.scheduler.submit(crate::workload::Request {
                            id,
                            arrival_s: ctx.now,
                            prompt_tokens: p,
                            output_tokens: if only_one { 1 } else { o },
                            class,
                        });
                    }
                }
                let _ = self.sync_kv(cluster);
            }
            OomBehavior::ScaleDown => {
                self.emergency_scale_down(ctx, cluster, Pressure::Memory, scale);
                let _ = self.sync_kv(cluster);
            }
        }
    }

    // ---- memory-pressure governing (the rungs above the policy shed) ------

    /// Snapshot the governor's decision inputs for one pressure episode.
    fn pressure_view(&self, cluster: &Cluster) -> PressureView {
        let headroom = self
            .profile
            .kv_groups
            .iter()
            .map(|&(d, _)| cluster.device(d).free_bytes())
            .fold(f64::INFINITY, f64::min);
        // Cold-layer proxy: deepest unreplicated, unswapped layers whose
        // primary sits on the hottest device — deterministic, and swapping
        // them frees bytes exactly where the pressure is. Replicated
        // layers are hot by definition (the autoscaler just replicated
        // them) and precision is tracked per layer, not per copy.
        let hot = self.hottest_primary_device(cluster);
        let swap_candidates: Vec<usize> = (0..self.placement.n_layers)
            .rev()
            .filter(|&l| {
                self.profile.primary_devices[l] == hot
                    && self.placement.degree(l) == 1
                    && !self.quantized_layers.contains(&l)
            })
            .collect();
        let gov = self.governor.as_ref().expect("governed instance");
        PressureView {
            pool_bytes: self.kv.pool_bytes(),
            reserved_bytes: self.kv.stats().reserved_bytes,
            headroom_bytes: if headroom.is_finite() { headroom } else { 0.0 },
            swap_candidates,
            swapped: self.quantized_layers.len(),
            relief_inflight: self.inflight.is_some() || gov.swap_parked(),
        }
    }

    /// Walk the governor's escalation ladder for one OOM episode. Returns
    /// true when the episode is handled — relief enacted, or pending in
    /// flight — and the caller must skip the policy shed.
    fn mempress_relieve(
        &mut self,
        now: f64,
        cluster: &mut Cluster,
        cause: PressureCause,
    ) -> bool {
        let view = self.pressure_view(cluster);
        let relief =
            self.governor.as_mut().expect("governed instance").decide(cause, &view);
        let pressure = match cause {
            PressureCause::PoolExhausted { deficit } => deficit,
            PressureCause::LedgerMirror => 0.0,
        };
        let (handled, action, value) = match relief {
            Relief::GrowPool { grant } => {
                let target = self.kv.pool_bytes() + grant;
                let _ = self.kv.resize(target); // growing always succeeds
                let _ = self.sync_kv(cluster); // mirror the larger grant
                (true, crate::telemetry::DecisionAction::GrowPool, grant)
            }
            Relief::ShrinkPool { to } => {
                // cannot fail: `to` is the snapshot's live reservation and
                // nothing allocated since (same call stack)
                let _ = self.kv.resize(to);
                let _ = self.sync_kv(cluster); // release waste to the ledger
                (true, crate::telemetry::DecisionAction::ShrinkPool, to)
            }
            Relief::RequestSwaps { layers } => {
                // park the plan for the kernel to admit as in-flight
                // `OpStarted`/`OpCompleted` events — handle_oom has no
                // event-queue access, and swaps take real transfer time
                let n = layers.len();
                let mut plan = ScalePlan::new();
                for l in layers {
                    plan.push(ModuleOp::SwapPrecision {
                        layer: l,
                        device: self.profile.primary_devices[l],
                        from: self.dtype_bytes,
                        to: INT8_BYTES,
                    });
                }
                self.governor.as_mut().expect("governed instance").park_swap(plan);
                (true, crate::telemetry::DecisionAction::RequestSwaps, n as f64)
            }
            Relief::Wait => (true, crate::telemetry::DecisionAction::Wait, 0.0),
            Relief::Escalate => (false, crate::telemetry::DecisionAction::Escalate, 0.0),
        };
        if self.trace_enabled {
            self.trace_outbox.push(crate::telemetry::TraceEvent::Decision {
                t: now,
                actor: crate::telemetry::DecisionActor::Mempress,
                action,
                instance: self.id as i64,
                pressure,
                deficit: 0.0,
                chosen_cost: value,
                rejected_cost: -1.0,
            });
            if handled {
                self.trace_outbox.push(crate::telemetry::TraceEvent::Mark {
                    t: now,
                    instance: self.id as i64,
                    kind: crate::telemetry::MarkKind::MempressRelief,
                    value,
                });
            }
        }
        handled
    }

    /// A rollback undid the applied prefix of a plan: restore the
    /// quantized-layer set to each swap op's `from` precision (the exact
    /// inverse of the forward update in [`Instance::on_op_completed`]).
    fn unwind_swaps(&mut self, plan: &ScalePlan, applied: usize) {
        for op in &plan.ops[..applied] {
            if let ModuleOp::SwapPrecision { layer, from, .. } = *op {
                if from < self.dtype_bytes {
                    self.quantized_layers.insert(layer);
                } else {
                    self.quantized_layers.remove(&layer);
                }
            }
        }
    }

    // ---- in-flight plan execution -----------------------------------------

    /// Accept a controller-planned [`ScalePlan`] for in-flight execution.
    /// Returns the plan epoch and each op's `(start, end)` times for the
    /// kernel to schedule as `OpStarted`/`OpCompleted` events. `batch_after`
    /// (the phase-3 scale-down decision) applies immediately — it is a
    /// scheduler config change, not a transfer.
    pub fn admit_plan(
        &mut self,
        now: f64,
        plan: ScalePlan,
        cost: PlanCost,
        batch_after: Option<usize>,
    ) -> (u64, Vec<(f64, f64)>) {
        debug_assert_eq!(plan.len(), cost.per_op.len());
        if let Some(b) = batch_after {
            self.batch_size = b;
        }
        self.plan_epoch += 1;
        let epoch = self.plan_epoch;
        let mut spans = Vec::with_capacity(cost.per_op.len());
        let mut t = now;
        for c in &cost.per_op {
            spans.push((t, t + c.time_s));
            t += c.time_s;
        }
        let had_replication = plan.ops.iter().any(|o| o.is_replication());
        self.inflight = Some(InflightPlan {
            plan,
            exec: PlanExecution::new(),
            costs: cost.per_op,
            epoch,
            next_op: 0,
            had_replication,
        });
        (epoch, spans)
    }

    /// Roll back and discard the in-flight plan, if any (emergency
    /// corrections supersede background scaling).
    pub fn abort_inflight(
        &mut self,
        now: f64,
        cluster: &mut Cluster,
        scale: &mut ScaleStats,
    ) {
        if let Some(fl) = self.inflight.take() {
            let desc = fl
                .plan
                .ops
                .get(fl.next_op)
                .map(|o| o.describe())
                .unwrap_or_default();
            // rollback restores ledger precision; mirror that in the
            // quantized-layer set before the placement unwinds
            self.unwind_swaps(&fl.plan, fl.next_op);
            fl.exec.rollback(cluster, &mut self.placement);
            self.plan_epoch += 1; // kill the plan's remaining events
            self.recompile_profile(cluster); // rollback moved the placement
            scale.plans_aborted += 1;
            scale.events.push(OpEvent {
                t: now,
                instance: self.id,
                op_idx: fl.next_op,
                phase: OpPhase::Aborted,
                desc,
            });
        }
    }

    /// An `OpStarted` event fired: begin blocking the serving path if the
    /// op takes a serving module offline (migration).
    pub fn on_op_started(&mut self, now: f64, op_idx: usize, epoch: u64) -> OpOutcome {
        let Some(fl) = self.inflight.as_ref() else { return OpOutcome::Stale };
        if fl.epoch != epoch || op_idx >= fl.plan.len() {
            return OpOutcome::Stale;
        }
        let op = fl.plan.ops[op_idx];
        let duration = fl.costs[op_idx].time_s;
        if op.blocks_serving() {
            self.op_block_until = self.op_block_until.max(now + duration);
        }
        OpOutcome::Started { desc: op.describe() }
    }

    /// An `OpCompleted` event fired: apply the op's ledger + placement
    /// effects now (the transfer is done). On failure against the live
    /// ledgers — serving may have grown into the planned space — the whole
    /// plan rolls back, leaving allocations and placement as before it
    /// started.
    pub fn on_op_completed(
        &mut self,
        ctx: &StepCtx<'_>,
        cluster: &mut Cluster,
        op_idx: usize,
        epoch: u64,
    ) -> OpOutcome {
        let Some(mut fl) = self.inflight.take() else { return OpOutcome::Stale };
        if fl.epoch != epoch || fl.next_op != op_idx {
            self.inflight = Some(fl);
            return OpOutcome::Stale;
        }
        let ops = self.module_ops(ctx);
        let op = fl.plan.ops[op_idx];
        match fl.exec.apply_next(&ops, cluster, &mut self.placement, &op) {
            Ok(cost) => {
                if let ModuleOp::SwapPrecision { layer, to, .. } = op {
                    // track which layers now serve quantized (drives the
                    // mixed-precision decode roofline + quality penalty)
                    if to < self.dtype_bytes {
                        self.quantized_layers.insert(layer);
                    } else {
                        self.quantized_layers.remove(&layer);
                    }
                    if let Some(g) = &mut self.governor {
                        g.stats.swaps_applied += 1;
                        g.stats.swap_freed_bytes += (-cost.dst_bytes).max(0.0);
                    }
                }
                fl.next_op += 1;
                let finished = fl.next_op == fl.plan.len();
                if finished {
                    // commit: release migrated/evicted source copies now
                    // that the whole plan landed (copy-then-free)
                    let _ = fl.exec.commit(cluster);
                    if fl.had_replication {
                        // §6.5: inter-replica communication setup — the
                        // only serving-path pause replication causes.
                        self.op_block_until = self
                            .op_block_until
                            .max(ctx.now + SYNC_PAUSE_S + REPLICA_COMM_SETUP_S);
                    }
                } else {
                    self.inflight = Some(fl);
                }
                // the op moved the placement — invalidate the step-cost
                // cache (the only steady-state invalidation point)
                self.recompile_profile(cluster);
                OpOutcome::Applied { desc: op.describe(), cost, finished }
            }
            Err(_) => {
                self.unwind_swaps(&fl.plan, fl.next_op);
                fl.exec.rollback(cluster, &mut self.placement);
                self.plan_epoch += 1;
                self.recompile_profile(cluster);
                OpOutcome::Aborted { desc: op.describe() }
            }
        }
    }

    // ---- emergency corrective scaling -------------------------------------

    /// Synchronous Algorithm 2 round, used on the OOM path where relief
    /// cannot wait for in-flight execution: plan (pure), then execute
    /// atomically through the [`PlanExecutor`]. The serving path pays the
    /// transfer as a corrective pause (Table 2: 0.25–0.8 s), capped at 1 s.
    pub fn emergency_scale_down(
        &mut self,
        ctx: &StepCtx<'_>,
        cluster: &mut Cluster,
        pressure: Pressure,
        scale: &mut ScaleStats,
    ) {
        // an emergency supersedes background scaling — unwind it first so
        // the corrective plan sees consistent state
        self.abort_inflight(ctx.now, cluster, scale);
        let hot = self.hottest_primary_device(cluster);
        let kv_per_layer =
            self.kv.stats().reserved_bytes / self.placement.n_layers as f64;
        let ops = self.module_ops(ctx);
        let out = scale_down(
            &ops,
            cluster,
            &self.placement,
            hot,
            pressure,
            self.batch_size,
            &ScaleDownConfig::default(),
            |_l| kv_per_layer,
            crate::autoscale::memory_violation(hot, ctx.cfg.slo_latency_s),
        );
        if out.actions.is_empty() {
            return;
        }
        scale.scale_downs += 1;
        self.batch_size = out.batch_size;
        if out.plan.is_empty() {
            return; // phase-3-only relief: nothing to execute
        }
        match PlanExecutor::new(&ops).execute(cluster, &mut self.placement, &out.plan) {
            Ok(cost) => {
                self.recompile_profile(cluster); // corrective ops landed
                scale.op_time_s += cost.total.time_s;
                self.op_block_until =
                    self.op_block_until.max(ctx.now + cost.total.time_s.min(1.0));
                for (k, op) in out.plan.ops.iter().enumerate() {
                    scale.events.push(OpEvent {
                        t: ctx.now,
                        instance: self.id,
                        op_idx: k,
                        phase: OpPhase::Completed,
                        desc: op.describe(),
                    });
                }
            }
            Err(_) => {
                // Planned against this exact state, so execution cannot
                // fail in practice; if it ever does the executor has
                // already rolled back — only the batch reduction stands.
                scale.plans_aborted += 1;
            }
        }
    }

    /// The most memory-loaded device hosting this instance's primaries.
    /// Walks the profile's precompiled per-layer primary list — same
    /// sequence (and therefore the same tie-breaking) as walking the
    /// placement, without the per-call lookups.
    pub fn hottest_primary_device(&self, cluster: &Cluster) -> usize {
        self.profile
            .primary_devices
            .iter()
            .copied()
            .max_by(|&a, &b| {
                cluster
                    .device(a)
                    .mem_frac()
                    .partial_cmp(&cluster.device(b).mem_frac())
                    .unwrap()
            })
            .unwrap_or(0)
    }

    // ---- the state machine ------------------------------------------------

    /// Try to start the next step. `contention` is the overlap-weighted
    /// neighbour slowdown the kernel computed from the fleet's busy sets.
    pub fn start_step(
        &mut self,
        ctx: &StepCtx<'_>,
        cluster: &mut Cluster,
        contention: f64,
        scale: &mut ScaleStats,
    ) -> StepStart {
        // A migration in flight (or the post-replication sync barrier)
        // holds the serving path: every step traverses the moved module.
        if ctx.now + 1e-9 < self.op_block_until {
            return StepStart::Blocked { until: self.op_block_until };
        }
        // Batch capacity = (possibly scaled-down) base batch × the mean
        // layer degree: replica sets add data-parallel lanes (Fig. 4).
        // Recovery: a reloaded static engine creeps back toward its
        // configured batch (operators restart with the original config;
        // the OOM cycle then recurs under sustained load — the Fig. 11a
        // occurrence-rate mechanism). clean_steps counts start polls, not
        // executed steps — the recovery cadence the lockstep loop had.
        self.clean_steps += 1;
        if self.clean_steps % 40 == 0 && self.batch_size < self.policy.scheduler.max_batch
        {
            self.batch_size = (self.batch_size * 2).min(self.policy.scheduler.max_batch);
        }
        let cap = ((self.batch_size as f64) * self.profile.mean_degree) as usize;
        let mut cfg = self.scheduler.cfg;
        cfg.max_batch = cap;
        self.scheduler.cfg = cfg;

        // Mid-step preemption (class-aware fleet mode only): a waiting
        // latency-sensitive request about to be admitted may claim the
        // slots of an all-best-effort running batch at this token boundary
        // (start_step only runs between steps, so no step is cut short).
        // Gated on `preempt_premium`, which stays false in every classless
        // configuration — those runs never take this path.
        if self.preempt_premium {
            self.preempt_best_effort_batch(cap);
        }

        match self.scheduler.next_step(ctx.now) {
            Step::Idle => StepStart::Idle,
            Step::Prefill { request_ids } => {
                // admit KV for the new sequences
                let mut cause = None;
                let mut deficit = 0.0;
                for id in &request_ids {
                    // idempotent: a previous partially-OOMed prefill may
                    // have admitted this sequence's cache already
                    if self.kv.tokens_of(*id).is_some() {
                        continue;
                    }
                    let prompt = self.requests.get(id).map(|r| r.1).unwrap_or(8);
                    if let Err(d) = self.kv.add_sequence(*id, prompt) {
                        deficit += d;
                        cause = Some(PressureCause::PoolExhausted { deficit });
                    }
                }
                if cause.is_none() && self.sync_kv(cluster).is_err() {
                    cause = Some(PressureCause::LedgerMirror);
                }
                if let Some(c) = cause {
                    self.handle_oom(ctx, cluster, scale, c);
                    return StepStart::OomStall;
                }
                let batch = request_ids.len();
                let max_seq = request_ids
                    .iter()
                    .filter_map(|id| self.requests.get(id).map(|r| r.1))
                    .max()
                    .unwrap_or(8);
                let mut dt = self.prefill_step_time(ctx, batch, max_seq);
                dt *= contention;
                self.charge_busy(cluster, dt); // prefill is compute-bound: full busy
                self.scheduler.on_prefilled(&request_ids);
                self.last_step_shape = (batch, false);
                self.begin_busy(ctx.now + dt)
            }
            Step::Decode { request_ids } => {
                // grow KV by one token per sequence
                let mut cause = None;
                let mut deficit = 0.0;
                for id in &request_ids {
                    if self.kv.tokens_of(*id).is_some() {
                        if let Err(d) = self.kv.append_token(*id) {
                            deficit += d;
                            cause = Some(PressureCause::PoolExhausted { deficit });
                        }
                    }
                }
                if cause.is_none() && self.sync_kv(cluster).is_err() {
                    cause = Some(PressureCause::LedgerMirror);
                }
                if let Some(c) = cause {
                    self.handle_oom(ctx, cluster, scale, c);
                    return StepStart::OomStall;
                }
                let batch = request_ids.len();
                let mean_ctx = {
                    let ctxs: Vec<usize> = request_ids
                        .iter()
                        .filter_map(|id| self.kv.tokens_of(*id))
                        .collect();
                    (ctxs.iter().sum::<usize>() / ctxs.len().max(1)).max(1)
                };
                let mut dt = if self.quantized_layers.is_empty() {
                    self.decode_step_time(ctx, batch, mean_ctx)
                } else {
                    // Quantized layers read int8 weights — faster roofline
                    // bytes term — but each step accrues a quality penalty
                    // the governor surfaces in the metrics JSON. Reached
                    // only under an active governor (swaps are its rung 2),
                    // so the ungoverned path stays bit-identical.
                    let t = self.profile.decode_step_time_mixed(
                        ctx.cost,
                        ctx.cfg.dtype_bytes,
                        batch,
                        mean_ctx,
                        &self.quantized_layers,
                        INT8_BYTES,
                    );
                    if let Some(g) = &mut self.governor {
                        g.stats.quality_penalty += self.quantized_layers.len()
                            as f64
                            * SWAP_QUALITY_PENALTY_PER_STEP;
                    }
                    t
                };
                dt *= contention;
                // Decode is HBM-bandwidth-bound: the SMs are only partially
                // occupied during the step (what NVML-style compute
                // utilization reports — the Fig. 2 signal).
                self.charge_busy(cluster, dt * DECODE_BUSY_FRACTION);
                self.scheduler.on_decoded(&request_ids);
                self.last_step_shape = (batch, true);
                self.begin_busy(ctx.now + dt)
            }
        }
    }

    /// Shed the running batch so a waiting latency-sensitive request can
    /// take its place at the next token boundary. Fires only when (a) a
    /// premium request sits within the next `cap` admissions — so the
    /// freed slots actually go to it, never a churn loop — and (b) every
    /// running sequence is best-effort (premium work is never preempted).
    /// The batch leaves via the shed outbox with its accumulated penalty
    /// and original arrival intact, exactly like an OOM shed, so the
    /// coordinator's `collect_shed` conservation machinery re-routes it.
    fn preempt_best_effort_batch(&mut self, cap: usize) {
        use crate::workload::SloClass;
        let premium_next = self
            .scheduler
            .pending_ids()
            .iter()
            .take(cap.max(1))
            .any(|id| self.requests.get(id).map(|r| r.3) == Some(SloClass::LatencySensitive));
        if !premium_next {
            return;
        }
        let view = self.scheduler.running_view();
        if view.is_empty()
            || view
                .iter()
                .any(|(id, _, _)| self.requests.get(id).map(|r| r.3) != Some(SloClass::BestEffort))
        {
            return;
        }
        for (id, _, _) in view {
            self.kv.remove_sequence(id);
            self.scheduler.preempt(id);
            if let Some((arr, p, o, class)) = self.requests.remove(&id) {
                let penalty = self.penalties.remove(&id).unwrap_or(0.0);
                self.shed_outbox.push(Shed {
                    id,
                    arrival_s: arr,
                    prompt_tokens: p,
                    output_tokens: o,
                    penalty,
                    class,
                    cause: crate::telemetry::ShedCause::SloPreempt,
                });
            }
        }
        self.preemptions += 1;
    }

    fn begin_busy(&mut self, until: f64) -> StepStart {
        // a step started, so the instance is making forward progress —
        // reset the governor's stall counter (bounds Relief::Wait)
        if let Some(g) = &mut self.governor {
            g.note_progress();
        }
        self.step_token += 1;
        self.busy_until = Some(until);
        StepStart::Busy { until, token: self.step_token }
    }

    /// Record completions for sequences the scheduler reaped.
    pub fn finish_completions(&mut self, now: f64, cluster: &mut Cluster) {
        let tracked: std::collections::BTreeSet<u64> = self
            .scheduler
            .running_view()
            .iter()
            .map(|(id, _, _)| *id)
            .chain(self.pending_ids())
            .collect();
        let finished: Vec<u64> = self
            .requests
            .keys()
            .copied()
            .filter(|id| !tracked.contains(id) && self.kv.tokens_of(*id).is_some())
            .collect();
        for id in finished {
            self.kv.remove_sequence(id);
            let (arrival, prompt, output, class) = self.requests[&id];
            let penalty = self.penalties.get(&id).copied().unwrap_or(0.0);
            self.monitor.record(Completion {
                request_id: id,
                arrival_s: arrival,
                finish_s: now + penalty,
                prompt_tokens: prompt,
                output_tokens: output,
                class,
            });
        }
        let _ = self.sync_kv(cluster);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::{scale_up, ScaleUpConfig};
    use crate::baselines;
    use crate::cluster::GIB;

    fn setup(policy: SimPolicy) -> (SimConfig, CostModel, Cluster, Instance) {
        let cfg = SimConfig::paper_13b();
        let cost = cfg.cost_model();
        let mut cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let inst = Instance::deploy(0, placement, policy, &cfg, &cost, &mut cluster);
        (cfg, cost, cluster, inst)
    }

    fn submit(inst: &mut Instance, id: u64, at: f64, prompt: usize, out: usize) {
        submit_classed(inst, id, at, prompt, out, crate::workload::SloClass::default());
    }

    fn submit_classed(
        inst: &mut Instance,
        id: u64,
        at: f64,
        prompt: usize,
        out: usize,
        class: crate::workload::SloClass,
    ) {
        inst.requests.insert(id, (at, prompt, out, class));
        inst.scheduler.submit(crate::workload::Request {
            id,
            arrival_s: at,
            prompt_tokens: prompt,
            output_tokens: out,
            class,
        });
    }

    /// Plan a scale-up round against the live state (test helper mirroring
    /// the controller path).
    fn plan_up(
        cfg: &SimConfig,
        cost: &CostModel,
        cluster: &Cluster,
        inst: &Instance,
        max_ops: usize,
    ) -> crate::autoscale::ScaleUpPlan {
        let ops = ModuleOps::new(cost, cfg.dtype_bytes, "inst0");
        let up = ScaleUpConfig {
            min_vacancy: crate::sim::SCALE_UP_MIN_VACANCY,
            max_ops_per_round: max_ops,
            ..Default::default()
        };
        scale_up(&ops, cluster, &inst.placement, &up)
    }

    #[test]
    fn deploy_allocates_weights() {
        let (_, _, cluster, inst) = setup(baselines::vllm_like(8));
        assert!(cluster.device(0).used_bytes() > 20.0 * GIB);
        assert!(!inst.has_work());
        assert_eq!(inst.device_set().into_iter().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn prefill_then_decode_advances_state() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::vllm_like(8));
        let mut scale = ScaleStats::default();
        submit(&mut inst, 0, 0.0, 32, 4);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let s1 = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        let StepStart::Busy { until: t1, token: k1 } = s1 else {
            panic!("expected busy, got {s1:?}")
        };
        assert!(t1 > 0.0);
        assert_eq!(inst.kv.tokens_of(0), Some(32));
        inst.busy_until = None;
        inst.finish_completions(t1, &mut cluster);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: t1 };
        let s2 = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        let StepStart::Busy { until: t2, token: k2 } = s2 else {
            panic!("expected busy, got {s2:?}")
        };
        assert!(t2 > t1);
        assert_eq!(k2, k1 + 1);
        assert_eq!(inst.kv.tokens_of(0), Some(33));
    }

    #[test]
    fn sequences_complete_and_release_kv() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::vllm_like(8));
        let mut scale = ScaleStats::default();
        submit(&mut inst, 0, 0.0, 16, 1); // finishes at prefill
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let StepStart::Busy { until, .. } =
            inst.start_step(&ctx, &mut cluster, 1.0, &mut scale)
        else {
            panic!("expected busy")
        };
        inst.busy_until = None;
        inst.finish_completions(until, &mut cluster);
        assert_eq!(inst.monitor.completions().len(), 1);
        assert_eq!(inst.kv.tokens_of(0), None);
        assert_eq!(inst.kv.stats().sequences, 0);
        assert!(!inst.has_work());
    }

    #[test]
    fn failbatch_oom_halves_batch_and_requeues() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::hft(16));
        let mut scale = ScaleStats::default();
        // Fill the device so the KV ledger mirror cannot grow.
        let free = cluster.device(0).free_bytes();
        cluster.device_mut(0).alloc("hog", free - 1.0).unwrap();
        for i in 0..16 {
            submit(&mut inst, i, 0.0, 64, 4);
        }
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 1.0 };
        let s = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert_eq!(s, StepStart::OomStall);
        assert_eq!(inst.batch_size, 8, "batch halves after reload");
        assert_eq!(inst.scheduler.running_len(), 0, "scheduler rebuilt");
        assert_eq!(inst.scheduler.pending_len(), 16, "no request lost");
        assert_eq!(inst.oom_victims.len(), 16);
        assert!(inst.monitor.total_oom() > 0);
    }

    #[test]
    fn shed_records_preserve_class_and_accumulated_penalty() {
        // The regression contract for every shed path (FailBatch reroute,
        // DeviceFailed flush, premium preemption — all build the same
        // `Shed` record): the request's SLO class and accumulated penalty
        // must survive into the outbox, or the re-routed request would
        // silently lose its priority and its OOM-reload debt.
        use crate::workload::SloClass;
        let (_, _, _, mut inst) = setup(baselines::vllm_like(8));
        submit_classed(&mut inst, 7, 1.5, 32, 4, SloClass::LatencySensitive);
        inst.penalties.insert(7, 0.75);
        assert_eq!(inst.shed_live_requests(), 1);
        let shed = &inst.shed_outbox[0];
        assert_eq!(shed.id, 7);
        assert_eq!(shed.arrival_s, 1.5, "original arrival preserved");
        assert_eq!(shed.class, SloClass::LatencySensitive, "class preserved");
        assert_eq!(shed.penalty, 0.75, "accumulated penalty preserved");
    }

    #[test]
    fn premium_arrival_preempts_best_effort_batch_at_token_boundary() {
        use crate::workload::SloClass;
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::hft(4));
        inst.preempt_premium = true;
        let mut scale = ScaleStats::default();
        submit_classed(&mut inst, 0, 0.0, 16, 8, SloClass::BestEffort);
        submit_classed(&mut inst, 1, 0.0, 16, 8, SloClass::BestEffort);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let StepStart::Busy { until, .. } =
            inst.start_step(&ctx, &mut cluster, 1.0, &mut scale)
        else {
            panic!("expected the best-effort batch to start")
        };
        inst.busy_until = None;
        inst.finish_completions(until, &mut cluster);
        // a latency-sensitive request lands while the best-effort batch
        // is mid-decode; carry a pre-existing penalty on one victim
        inst.penalties.insert(0, 0.25);
        submit_classed(&mut inst, 2, until, 16, 2, SloClass::LatencySensitive);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: until };
        let s = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert!(matches!(s, StepStart::Busy { .. }), "premium must start: {s:?}");
        assert_eq!(inst.preemptions, 1, "one batch preemption recorded");
        let shed: Vec<_> = inst.shed_outbox.iter().map(|s| s.id).collect();
        assert_eq!(shed, vec![0, 1], "the whole best-effort batch is shed");
        for s in &inst.shed_outbox {
            assert_eq!(s.class, SloClass::BestEffort);
            assert_eq!(s.arrival_s, 0.0, "original arrival survives preemption");
        }
        assert_eq!(inst.shed_outbox[0].penalty, 0.25, "penalty survives preemption");
        // the premium request owns the machine now
        let running: Vec<u64> =
            inst.scheduler.running_view().iter().map(|(id, _, _)| *id).collect();
        assert_eq!(running, vec![2]);
    }

    #[test]
    fn classless_instances_never_preempt() {
        // preempt_premium stays false outside class-aware policies: the
        // identical arrival pattern runs the best-effort batch to
        // completion with an empty shed outbox — the byte-identity
        // guarantee for classless goldens at the instance level.
        use crate::workload::SloClass;
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::hft(4));
        let mut scale = ScaleStats::default();
        submit_classed(&mut inst, 0, 0.0, 16, 8, SloClass::BestEffort);
        submit_classed(&mut inst, 1, 0.0, 16, 8, SloClass::BestEffort);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let StepStart::Busy { until, .. } =
            inst.start_step(&ctx, &mut cluster, 1.0, &mut scale)
        else {
            panic!("expected busy")
        };
        inst.busy_until = None;
        inst.finish_completions(until, &mut cluster);
        submit_classed(&mut inst, 2, until, 16, 2, SloClass::LatencySensitive);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: until };
        let s = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert!(matches!(s, StepStart::Busy { .. }));
        assert_eq!(inst.preemptions, 0);
        assert!(inst.shed_outbox.is_empty(), "no preemption without the flag");
        let running: Vec<u64> =
            inst.scheduler.running_view().iter().map(|(id, _, _)| *id).collect();
        assert_eq!(running, vec![0, 1], "the best-effort batch keeps the machine");
    }

    /// Deploy with a governor and a deliberately starved initial pool.
    fn governed_setup(
        initial_pool_frac: f64,
    ) -> (SimConfig, CostModel, Cluster, Instance) {
        let mut cfg = SimConfig::paper_13b();
        cfg.mempress = Some(crate::mempress::MempressConfig {
            initial_pool_frac,
            ..Default::default()
        });
        let cost = cfg.cost_model();
        let mut cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(cfg.model.n_layers, 0);
        let inst = Instance::deploy(
            0,
            placement,
            baselines::cocoserve(16),
            &cfg,
            &cost,
            &mut cluster,
        );
        (cfg, cost, cluster, inst)
    }

    #[test]
    fn governed_oom_grows_pool_instead_of_shedding() {
        // Pool rounds down to zero blocks, so the very first prefill hits
        // admission pressure — but device headroom is plentiful, so rung 1
        // (grow) must absorb it without any request being shed.
        let (cfg, cost, mut cluster, mut inst) = governed_setup(1e-6);
        let mut scale = ScaleStats::default();
        submit(&mut inst, 1, 0.0, 128, 4);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let first = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert_eq!(first, StepStart::OomStall, "episode stalls one poll");
        let g = inst.governor.as_ref().unwrap();
        assert_eq!(g.stats.kv_grows, 1, "rung 1 granted a larger pool");
        assert_eq!(g.stats.escalations, 0);
        assert!(inst.oom_victims.is_empty(), "nothing was shed");
        assert!(inst.kv.pool_bytes() > 0.0, "the grant is live");
        // same request, same instant: the grown pool now admits it
        let second = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert!(matches!(second, StepStart::Busy { .. }), "prefill started");
        assert!(inst.oom_victims.is_empty());
    }

    #[test]
    fn governed_oom_swaps_layers_when_headroom_is_gone() {
        // Starve both the pool AND the device: rung 1 cannot grant, so the
        // governor must park a SwapPrecision plan (rung 2). Executing it
        // through the real op events quantizes the coldest layers, frees
        // their ledger bytes, and the grow that was impossible before now
        // succeeds — the full ladder, no shed at any point.
        let (cfg, cost, mut cluster, mut inst) = governed_setup(1e-6);
        let mut scale = ScaleStats::default();
        let free = cluster.device(0).free_bytes();
        cluster.device_mut(0).alloc("hog", free - 0.01 * GIB).unwrap();
        submit(&mut inst, 1, 0.0, 128, 4);
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let s = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert_eq!(s, StepStart::OomStall);
        assert!(inst.oom_victims.is_empty(), "governor handled the episode");
        {
            let g = inst.governor.as_ref().unwrap();
            assert_eq!(g.stats.kv_grows, 0, "10 MiB headroom cannot cover it");
            assert_eq!(g.stats.swap_requests, 1, "rung 2 requested swaps");
            assert!(g.swap_parked(), "plan waits for the kernel to admit it");
        }

        // kernel's role, replayed by hand: dry-run then admit as op events
        let plan = inst.governor.as_mut().unwrap().take_swap_request().unwrap();
        assert_eq!(plan.len(), 4, "one batch of the coldest layers");
        let ops = ModuleOps::new(&cost, cfg.dtype_bytes, "inst0");
        let plan_cost = plan.dry_run(&ops, &cluster, &inst.placement).unwrap();
        let free_before = cluster.device(0).free_bytes();
        let (epoch, spans) = inst.admit_plan(0.0, plan, plan_cost, None);
        for (k, &(t0, t1)) in spans.iter().enumerate() {
            inst.on_op_started(t0, k, epoch);
            let ctx = StepCtx { cfg: &cfg, cost: &cost, now: t1 };
            inst.on_op_completed(&ctx, &mut cluster, k, epoch);
        }
        let expect: std::collections::BTreeSet<usize> =
            [36, 37, 38, 39].into_iter().collect();
        assert_eq!(inst.quantized_layers, expect, "deepest four layers swapped");
        assert!(
            cluster.device(0).free_bytes() > free_before + GIB,
            "int8 rewrite freed over half the four layers' weight bytes"
        );
        let g = inst.governor.as_ref().unwrap();
        assert_eq!(g.stats.swaps_applied, 4);
        assert!(g.stats.swap_freed_bytes > GIB);

        // freed weight bytes became KV headroom: the retry grows and admits
        let retry = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert_eq!(retry, StepStart::OomStall, "grow consumes one more poll");
        assert_eq!(inst.governor.as_ref().unwrap().stats.kv_grows, 1);
        let served = inst.start_step(&ctx, &mut cluster, 1.0, &mut scale);
        assert!(matches!(served, StepStart::Busy { .. }));
        assert!(inst.oom_victims.is_empty(), "the whole ladder shed nothing");
    }

    #[test]
    fn contention_inflates_step_time() {
        let (cfg, cost, _cluster, inst) = setup(baselines::vllm_like(8));
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        let base = inst.prefill_step_time(&ctx, 8, 128);
        assert!(base > 0.0);
        // factor applied by start_step multiplies dt — verified indirectly
        // through the decode roofline being monotone in batch/context
        let d1 = inst.decode_step_time(&ctx, 1, 64);
        let d2 = inst.decode_step_time(&ctx, 16, 256);
        assert!(d2 > d1);
    }

    #[test]
    fn profile_invalidates_exactly_at_plan_epochs() {
        // The step-cost cache recompiles when (and only when) an op event
        // moves the placement: each applied op bumps the revision, and the
        // cached times always equal a fresh compile of the live placement.
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::cocoserve(16));
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        assert_eq!(inst.placement_rev, 0);
        let before = inst.decode_step_time(&ctx, 16, 128);

        let up = plan_up(&cfg, &cost, &cluster, &inst, 2);
        let (epoch, spans) = inst.admit_plan(0.0, up.plan, up.cost, None);
        assert_eq!(inst.placement_rev, 0, "admitting alone must not invalidate");

        for (k, &(t0, t1)) in spans.iter().enumerate() {
            inst.on_op_started(t0, k, epoch);
            let ctx = StepCtx { cfg: &cfg, cost: &cost, now: t1 };
            inst.on_op_completed(&ctx, &mut cluster, k, epoch);
            assert_eq!(inst.placement_rev, k as u64 + 1, "one recompile per op");
            let fresh = crate::placement::PlacementProfile::compile(
                &inst.placement,
                &cluster,
                inst.placement_rev,
            );
            assert_eq!(
                inst.decode_step_time(&ctx, 16, 128).to_bits(),
                fresh.decode_step_time(&cost, cfg.dtype_bytes, 16, 128).to_bits(),
                "cached profile must equal a fresh compile"
            );
        }
        let after = inst.decode_step_time(&ctx, 16, 128);
        assert_ne!(before.to_bits(), after.to_bits(), "replicas changed the cost");
    }

    #[test]
    fn inflight_plan_applies_op_by_op_then_pays_barrier() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::cocoserve(16));
        let up = plan_up(&cfg, &cost, &cluster, &inst, 3);
        assert_eq!(up.planned.len(), 3);
        let (epoch, spans) = inst.admit_plan(0.0, up.plan, up.cost, None);
        assert_eq!(spans.len(), 3);
        assert!(spans[0].1 > spans[0].0, "ops take time");
        for (k, &(t0, t1)) in spans.iter().enumerate() {
            let s = inst.on_op_started(t0, k, epoch);
            assert!(matches!(s, OpOutcome::Started { .. }));
            // replication never blocks serving mid-transfer
            assert!(inst.op_block_until <= t0 + 1e-12, "replication blocked serving");
            let ctx = StepCtx { cfg: &cfg, cost: &cost, now: t1 };
            let done = inst.on_op_completed(&ctx, &mut cluster, k, epoch);
            let OpOutcome::Applied { finished, .. } = done else {
                panic!("expected applied, got {done:?}")
            };
            assert_eq!(finished, k == 2);
        }
        assert!(inst.inflight.is_none());
        // the §6.5 comm-setup barrier lands after the last op
        let end = spans[2].1;
        assert!(
            (inst.op_block_until - (end + SYNC_PAUSE_S + REPLICA_COMM_SETUP_S)).abs()
                < 1e-9
        );
        let max_deg = (0..inst.placement.n_layers)
            .map(|l| inst.placement.degree(l))
            .max()
            .unwrap();
        assert!(max_deg > 1, "replicas landed");
        inst.placement.validate(cluster.n()).unwrap();
    }

    #[test]
    fn mid_flight_failure_rolls_the_whole_plan_back() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::cocoserve(16));
        let up = plan_up(&cfg, &cost, &cluster, &inst, 2);
        let (epoch, spans) = inst.admit_plan(0.0, up.plan, up.cost, None);
        let allocs_before: Vec<Vec<(String, u64)>> = (0..cluster.n())
            .map(|d| {
                cluster
                    .device(d)
                    .allocations()
                    .map(|(t, b)| (t.to_string(), b.to_bits()))
                    .collect()
            })
            .collect();
        let pl_before = format!("{:?}", inst.placement);
        // op 0 applies…
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: spans[0].1 };
        assert!(matches!(
            inst.on_op_completed(&ctx, &mut cluster, 0, epoch),
            OpOutcome::Applied { finished: false, .. }
        ));
        // …then serving eats the destination's memory before op 1 lands
        let dst = up.planned[1].1;
        let free = cluster.device(dst).free_bytes();
        cluster.device_mut(dst).alloc("kv-burst", free - 1.0).unwrap();
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: spans[1].1 };
        assert!(matches!(
            inst.on_op_completed(&ctx, &mut cluster, 1, epoch),
            OpOutcome::Aborted { .. }
        ));
        cluster.device_mut(dst).free("kv-burst").unwrap();
        // pre-plan state restored exactly (modulo the burst we injected)
        let allocs_after: Vec<Vec<(String, u64)>> = (0..cluster.n())
            .map(|d| {
                cluster
                    .device(d)
                    .allocations()
                    .map(|(t, b)| (t.to_string(), b.to_bits()))
                    .collect()
            })
            .collect();
        assert_eq!(allocs_before, allocs_after);
        assert_eq!(pl_before, format!("{:?}", inst.placement));
        assert!(inst.inflight.is_none());
        // the dead plan's remaining events are ignored
        assert!(matches!(
            inst.on_op_started(spans[1].1, 1, epoch),
            OpOutcome::Stale
        ));
    }

    #[test]
    fn blocked_step_waits_for_op_block() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::vllm_like(8));
        let mut scale = ScaleStats::default();
        submit(&mut inst, 0, 0.0, 32, 4);
        inst.op_block_until = 5.0;
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 1.0 };
        assert_eq!(
            inst.start_step(&ctx, &mut cluster, 1.0, &mut scale),
            StepStart::Blocked { until: 5.0 }
        );
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 5.0 };
        assert!(matches!(
            inst.start_step(&ctx, &mut cluster, 1.0, &mut scale),
            StepStart::Busy { .. }
        ));
    }

    #[test]
    fn emergency_scale_down_acts_atomically() {
        let (cfg, cost, mut cluster, mut inst) = setup(baselines::cocoserve(16));
        let mut scale = ScaleStats::default();
        // push device 0 above the violation line
        let free = cluster.device(0).free_bytes();
        cluster
            .device_mut(0)
            .alloc("pressure", free - 0.5 * GIB)
            .unwrap();
        let ctx = StepCtx { cfg: &cfg, cost: &cost, now: 0.0 };
        inst.emergency_scale_down(&ctx, &mut cluster, Pressure::Memory, &mut scale);
        assert_eq!(scale.scale_downs, 1);
        // with nothing evictable the graduated response ends in phase 3:
        // the batch walks down to the floor (performance traded for memory)
        assert_eq!(inst.batch_size, 1, "phase-3 batch reduction reached the floor");
        inst.placement.validate(cluster.n()).unwrap();
    }
}
