//! Table 2 — replication and migration cost vs layer count (§6.5).
//!
//! Paper measurements (13B layers, 4×A100):
//!
//! | layers | repl time | repl mem  | migr time | migr mem  |
//! |   1    | 0.2987 s  | 1107 MB   | 0.2492 s  | 1107 MB   |
//! |  10    | 0.3581 s  | 6579 MB   | 0.3181 s  | 6579 MB   |
//! |  20    | 0.3826 s  | 12659 MB  | 0.3426 s  | 12659 MB  |
//! |  30    | 0.4947 s  | 18739 MB  | 0.3947 s  | 18739 MB  |
//! |  40    | 0.8938 s  | 24819 MB  | 0.8138 s  | 24819 MB  |
//!
//! Plus: inter-replica communication setup 39.1 ms. Properties asserted:
//! memory exactly linear (499 + 608·n MiB), sub-second ops, time grows
//! ~3× for 40× layers, migration cheaper than replication. We report the
//! analytic model and *executed* plans against the cluster ledger — and
//! assert the plan/execute contract on every row: `ScalePlan::dry_run`
//! equals the executed `PlanCost` bit for bit.

use cocoserve::cluster::Cluster;
use cocoserve::model::cost::{CostModel, MIB};
use cocoserve::model::ModelConfig;
use cocoserve::ops::{ModuleOps, PlanExecutor, REPLICA_COMM_SETUP_S};
use cocoserve::placement::Placement;
use cocoserve::plan::ScalePlan;
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;

const LAYERS: [usize; 5] = [1, 10, 20, 30, 40];
const PAPER: [(f64, f64, f64); 5] = [
    (0.2987, 0.2492, 1107.0),
    (0.3581, 0.3181, 6579.0),
    (0.3826, 0.3426, 12659.0),
    (0.4947, 0.3947, 18739.0),
    (0.8938, 0.8138, 24819.0),
];

fn main() {
    println!("Table 2 — replication & migration cost vs layer count (13B)\n");
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let cluster = Cluster::paper_testbed();
    let bw = cluster.link_bw(0, 1);

    let mut t = Table::new(&["layers", "repl time", "paper", "migr time", "paper",
                             "memory MB", "paper"]);
    let mut rep = Report::new("table2_scaling_cost");
    for (i, &n) in LAYERS.iter().enumerate() {
        // destination fill grows with the op itself (the paper's target
        // device holds the replicas) — feed the model the resulting frac.
        let frac = (499.0 + 608.0 * n as f64) * MIB / cluster.device(1).spec.mem_bytes;
        let (tr, mem) = ops.table2_cost(n, bw, frac, false);
        let (tm, _) = ops.table2_cost(n, bw, frac, true);
        let (p_tr, p_tm, p_mem) = PAPER[i];
        t.row(&[
            format!("{n}"),
            format!("{tr:.4}s"),
            format!("{p_tr:.4}s"),
            format!("{tm:.4}s"),
            format!("{p_tm:.4}s"),
            format!("{:.0}", mem / MIB),
            format!("{p_mem:.0}"),
        ]);
        rep.set(
            &format!("layers{n}"),
            json::arr([tr, tm, mem / MIB].into_iter().map(json::num)),
        );
        assert!((mem / MIB - p_mem).abs() < 60.0, "memory must be linear-exact");
        assert!(tr < 2.0 && tm < tr, "sub-second; migration cheaper");
    }
    t.print();

    // executed (not just modeled) batch plans against the ledger, with
    // the dry-run parity contract checked on every row
    println!("\nexecuted plans (ledger-backed, dry-run == executed asserted):");
    let mut t2 = Table::new(&["layers", "executed repl", "executed migr",
                              "dst resident MB"]);
    let executor = PlanExecutor::new(&ops);
    for &n in &LAYERS {
        let layers: Vec<usize> = (0..n).collect();

        let mut cl = Cluster::paper_testbed();
        let mut pl = Placement::single_device(40, 0);
        ops.deploy_instance(&mut cl, &pl).unwrap();
        let repl = ScalePlan::replicate_batch(&layers, 1);
        let dry = repl.dry_run(&ops, &cl, &pl).unwrap();
        let c = executor.execute(&mut cl, &mut pl, &repl).unwrap();
        assert_eq!(dry, c, "replication n={n}: dry-run must equal executed");

        let mut cl2 = Cluster::paper_testbed();
        let mut pl2 = Placement::single_device(40, 0);
        ops.deploy_instance(&mut cl2, &pl2).unwrap();
        let migr = ScalePlan::migrate_batch(&layers, 1);
        let dry2 = migr.dry_run(&ops, &cl2, &pl2).unwrap();
        let c2 = executor.execute(&mut cl2, &mut pl2, &migr).unwrap();
        assert_eq!(dry2, c2, "migration n={n}: dry-run must equal executed");

        t2.row(&[
            format!("{n}"),
            format!("{:.4}s", c.total.time_s),
            format!("{:.4}s", c2.total.time_s),
            format!("{:.0}", cl.device(1).used_bytes() / MIB),
        ]);
    }
    t2.print();
    println!("dry-run == executed PlanCost held on all {} rows", LAYERS.len());

    println!(
        "\ninter-replica communication setup: {:.1} ms (paper: 39.1 ms)",
        REPLICA_COMM_SETUP_S * 1e3
    );
    let r40 = PAPER[4].0 / PAPER[0].0;
    println!(
        "time scaling 1→40 layers: paper {:.2}×, model {:.2}× — sub-linear \
         in layer count both ways (launch cost amortizes)",
        r40,
        {
            let f1 = (499.0 + 608.0) * MIB / cluster.device(1).spec.mem_bytes;
            let f40 = (499.0 + 608.0 * 40.0) * MIB / cluster.device(1).spec.mem_bytes;
            ops.table2_cost(40, bw, f40, false).0 / ops.table2_cost(1, bw, f1, false).0
        }
    );
    println!("report: {}", rep.write().unwrap().display());
}
