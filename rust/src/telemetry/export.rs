//! Chrome trace-event JSON export — the Perfetto/`chrome://tracing`
//! rendering of a recorded [`TraceBuffer`].
//!
//! ### Track layout
//!
//! * **pid 0 — `fleet`**: tid 0 `requests` (async request-lifecycle
//!   spans, one per request id), tid 1 `decisions` (controller/governor
//!   decision instants with their inputs and the dry-run price of the
//!   losing alternative), tid 2 `marks` (fleet-wide instants: device
//!   failures, spin-ups, drains, releases).
//! * **pid i+1 — `instance i`**: tid 0 `steps` (complete `X` spans, one
//!   per prefill/decode step), tid 1 `ops` (module-op spans: an `X` span
//!   of the dry-run duration at start, plus an applied/aborted instant
//!   carrying dry vs actual cost), tid 2 `marks` (per-instance instants:
//!   OOM episodes, mempress relief, rollbacks).
//!
//! ### Determinism
//!
//! Timestamps are simulation seconds scaled to integer-valued
//! microseconds (`ts = t × 1e6`); durations are clamped to `≥ 0` so a
//! zero-length span can never serialize as a negative duration Perfetto
//! would reject. The JSON builder sorts object keys, and events are
//! emitted in buffer order (which is simulation order) — so the export
//! is byte-identical across runs and shard counts whenever the record
//! stream is.

use super::{OpSpanPhase, ReqPhase, TraceBuffer, TraceEvent};
use crate::util::json::{self, Json};

/// Microseconds per simulated second (trace-event `ts`/`dur` unit).
const US: f64 = 1e6;

fn meta(pid: i64, tid: i64, what: &str, name: &str) -> Json {
    json::obj(vec![
        ("args", json::obj(vec![("name", json::s(name))])),
        ("name", json::s(what)),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
    ])
}

/// pid of an instance lane (`-1` = the fleet process, pid 0).
fn pid_of(instance: i64) -> f64 {
    if instance < 0 {
        0.0
    } else {
        (instance + 1) as f64
    }
}

fn event_json(ev: &TraceEvent) -> Json {
    match *ev {
        TraceEvent::Req { t, id, instance, phase } => {
            let ph = match phase {
                ReqPhase::Arrival => "b",
                ReqPhase::Completed => "e",
                _ => "n",
            };
            json::obj(vec![
                (
                    "args",
                    json::obj(vec![
                        ("instance", json::num(instance as f64)),
                        ("phase", json::s(phase.name())),
                    ]),
                ),
                ("cat", json::s("req")),
                ("id", json::num(id as f64)),
                ("name", json::s("request")),
                ("ph", json::s(ph)),
                ("pid", json::num(0.0)),
                ("tid", json::num(0.0)),
                ("ts", json::num(t * US)),
            ])
        }
        TraceEvent::Step { t, dur_s, instance, batch, decode } => json::obj(vec![
            ("args", json::obj(vec![("batch", json::num(batch as f64))])),
            ("cat", json::s("step")),
            ("dur", json::num((dur_s * US).max(0.0))),
            ("name", json::s(if decode { "decode" } else { "prefill" })),
            ("ph", json::s("X")),
            ("pid", json::num((instance + 1) as f64)),
            ("tid", json::num(0.0)),
            ("ts", json::num(t * US)),
        ]),
        TraceEvent::Op { t, instance, op_idx, op, dry_s, actual_s, phase } => {
            let name = op.describe();
            match phase {
                OpSpanPhase::Started => json::obj(vec![
                    (
                        "args",
                        json::obj(vec![
                            ("dry_s", json::num(dry_s)),
                            ("op_idx", json::num(op_idx as f64)),
                        ]),
                    ),
                    ("cat", json::s("op")),
                    ("dur", json::num((dry_s * US).max(0.0))),
                    ("name", json::s(&name)),
                    ("ph", json::s("X")),
                    ("pid", json::num((instance + 1) as f64)),
                    ("tid", json::num(1.0)),
                    ("ts", json::num(t * US)),
                ]),
                OpSpanPhase::Applied | OpSpanPhase::Aborted => json::obj(vec![
                    (
                        "args",
                        json::obj(vec![
                            ("actual_s", json::num(actual_s)),
                            ("dry_s", json::num(dry_s)),
                            ("op_idx", json::num(op_idx as f64)),
                            ("outcome", json::s(phase.name())),
                        ]),
                    ),
                    ("cat", json::s("op")),
                    ("name", json::s(&name)),
                    ("ph", json::s("i")),
                    ("pid", json::num((instance + 1) as f64)),
                    ("s", json::s("t")),
                    ("tid", json::num(1.0)),
                    ("ts", json::num(t * US)),
                ]),
            }
        }
        TraceEvent::Mark { t, instance, kind, value } => json::obj(vec![
            ("args", json::obj(vec![("value", json::num(value))])),
            ("cat", json::s("mark")),
            ("name", json::s(kind.name())),
            ("ph", json::s("i")),
            ("pid", json::num(pid_of(instance))),
            ("s", json::s("p")),
            ("tid", json::num(2.0)),
            ("ts", json::num(t * US)),
        ]),
        TraceEvent::Decision {
            t,
            actor,
            action,
            instance,
            pressure,
            deficit,
            chosen_cost,
            rejected_cost,
        } => json::obj(vec![
            (
                "args",
                json::obj(vec![
                    ("actor", json::s(actor.name())),
                    ("chosen_cost", json::num(chosen_cost)),
                    ("deficit", json::num(deficit)),
                    ("instance", json::num(instance as f64)),
                    ("pressure", json::num(pressure)),
                    ("rejected_cost", json::num(rejected_cost)),
                ]),
            ),
            ("cat", json::s("decision")),
            ("name", json::s(action.name())),
            ("ph", json::s("i")),
            ("pid", json::num(0.0)),
            ("s", json::s("t")),
            ("tid", json::num(1.0)),
            ("ts", json::num(t * US)),
        ]),
    }
}

/// Render the buffer as a Chrome trace-event JSON object
/// (`{"traceEvents": [...]}` — load the file directly in
/// [ui.perfetto.dev](https://ui.perfetto.dev) or `chrome://tracing`).
/// Metadata naming events come first, then the recorded events in
/// simulation order. `droppedEvents` reports ring-sink overwrites.
pub fn chrome_trace(buf: &TraceBuffer) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(buf.events.len() + 4 * (buf.n_instances + 1));
    events.push(meta(0, 0, "process_name", "fleet"));
    events.push(meta(0, 0, "thread_name", "requests"));
    events.push(meta(0, 1, "thread_name", "decisions"));
    events.push(meta(0, 2, "thread_name", "marks"));
    for i in 0..buf.n_instances {
        let pid = i as i64 + 1;
        events.push(meta(pid, 0, "process_name", &format!("instance {i}")));
        events.push(meta(pid, 0, "thread_name", "steps"));
        events.push(meta(pid, 1, "thread_name", "ops"));
        events.push(meta(pid, 2, "thread_name", "marks"));
    }
    events.extend(buf.events.iter().map(event_json));
    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("droppedEvents", json::num(buf.dropped as f64)),
        ("traceEvents", json::arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ModuleOp;
    use crate::telemetry::{DecisionAction, DecisionActor, MarkKind};

    fn sample_buffer() -> TraceBuffer {
        TraceBuffer {
            events: vec![
                TraceEvent::Req { t: 0.5, id: 7, instance: -1, phase: ReqPhase::Arrival },
                TraceEvent::Req { t: 0.5, id: 7, instance: 2, phase: ReqPhase::Routed },
                TraceEvent::Step { t: 0.6, dur_s: 0.05, instance: 2, batch: 4, decode: false },
                TraceEvent::Op {
                    t: 0.7,
                    instance: 2,
                    op_idx: 0,
                    op: ModuleOp::Replicate { layer: 3, dst: 1 },
                    dry_s: 0.2,
                    actual_s: 0.0,
                    phase: OpSpanPhase::Started,
                },
                TraceEvent::Mark { t: 0.8, instance: -1, kind: MarkKind::DeviceFailed, value: 1.0 },
                TraceEvent::Decision {
                    t: 0.9,
                    actor: DecisionActor::Fleet,
                    action: DecisionAction::ScaleOutReplicate,
                    instance: 2,
                    pressure: 9.5,
                    deficit: 0.0,
                    chosen_cost: 0.2,
                    rejected_cost: 1.5,
                },
                TraceEvent::Req { t: 1.1, id: 7, instance: 2, phase: ReqPhase::Completed },
            ],
            dropped: 0,
            n_instances: 3,
        }
    }

    #[test]
    fn export_parses_and_has_expected_tracks() {
        let j = chrome_trace(&sample_buffer());
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("exporter must emit valid JSON");
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 fleet metadata + 4×3 instance metadata + 7 records
        assert_eq!(evs.len(), 4 + 12 + 7);
        // every event carries ph/pid/tid
        for e in evs {
            assert!(e.get("ph").is_some());
            assert!(e.get("pid").is_some());
            assert!(e.get("tid").is_some());
        }
        // async request span: one "b", one "e", same id
        let phs: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").unwrap().as_str()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "b").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "e").count(), 1);
        // step span lands on pid 3 (instance 2) with µs timestamps
        let step = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("prefill"))
            .unwrap();
        assert_eq!(step.get("pid").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(step.get("ts").unwrap().as_f64().unwrap(), 0.6 * 1e6);
        assert_eq!(step.get("dur").unwrap().as_f64().unwrap(), 0.05 * 1e6);
        // decision instant carries the rejected alternative's price
        let dec = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("scale_out_replicate"))
            .unwrap();
        let args = dec.get("args").unwrap();
        assert_eq!(args.get("rejected_cost").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(args.get("actor").unwrap().as_str().unwrap(), "fleet");
    }

    #[test]
    fn zero_and_negative_durations_clamp_to_zero() {
        let buf = TraceBuffer {
            events: vec![TraceEvent::Step {
                t: 1.0,
                dur_s: -1e-9, // rounding artifact — must not export negative
                instance: 0,
                batch: 1,
                decode: true,
            }],
            dropped: 0,
            n_instances: 1,
        };
        let j = chrome_trace(&buf);
        let parsed = Json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let step = evs.iter().find(|e| e.get("dur").is_some()).unwrap();
        assert_eq!(step.get("dur").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn dropped_count_is_reported() {
        let buf = TraceBuffer { events: vec![], dropped: 42, n_instances: 0 };
        let j = chrome_trace(&buf);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("droppedEvents").unwrap().as_u64().unwrap(), 42);
    }
}
