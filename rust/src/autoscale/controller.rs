//! The Auto-Scaling Controller (§5): threshold decisions + cooldown.
//!
//! Periodically evaluates monitor feedback and picks an action:
//! scale-up when the cluster-wide resource vacancy exceeds `T_up`,
//! scale-down when the SLO violation rate exceeds `T_down` (or any OOM
//! occurred). A cooldown suppresses decision flapping while a previous
//! operation's cost is still being amortized.

use super::scale_down::Pressure;

/// Snapshot of the signals the controller consumes each tick (produced by
/// `monitor::Monitor::controller_view`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerInputs {
    /// Mean vacancy rate across eligible devices (1 − mem_frac).
    pub vacancy_rate: f64,
    /// Fraction of recent requests violating the SLO.
    pub slo_violation_rate: f64,
    /// OOM events since the last tick.
    pub oom_events: u64,
    /// Most loaded device + its pressure kind (scale-down target).
    pub hottest_device: usize,
    /// Compute utilization of the hottest device.
    pub hottest_compute_util: f64,
    /// Memory fraction of the hottest device.
    pub hottest_mem_frac: f64,
}

/// Controller decision for one tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    None,
    ScaleUp,
    ScaleDown { device: usize, pressure: Pressure },
}

/// Threshold configuration (T_up / T_down of §5).
#[derive(Debug, Clone, Copy)]
pub struct ControllerConfig {
    /// Scale up when vacancy exceeds this (idle resources to harvest).
    pub t_up: f64,
    /// Scale down when SLO violation rate exceeds this.
    pub t_down: f64,
    /// Ticks to wait after an action before acting again.
    pub cooldown_ticks: u32,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig { t_up: 0.30, t_down: 0.05, cooldown_ticks: 2 }
    }
}

/// Stateful threshold controller.
#[derive(Debug, Clone)]
pub struct Controller {
    pub cfg: ControllerConfig,
    cooldown: u32,
    decisions: u64,
}

impl Controller {
    pub fn new(cfg: ControllerConfig) -> Controller {
        Controller { cfg, cooldown: 0, decisions: 0 }
    }

    pub fn decisions_made(&self) -> u64 {
        self.decisions
    }

    /// Evaluate one control tick.
    ///
    /// Priority: OOM/SLO pressure outranks idle-resource harvesting —
    /// scale-down is checked first (§4.2 runs "when workload intensifies
    /// beyond capacity"), and an OOM bypasses the cooldown entirely.
    pub fn tick(&mut self, inp: &ControllerInputs) -> Decision {
        let emergency = inp.oom_events > 0;
        if self.cooldown > 0 && !emergency {
            self.cooldown -= 1;
            return Decision::None;
        }

        if emergency || inp.slo_violation_rate > self.cfg.t_down {
            // Memory pressure if the hot device is memory-dominated;
            // compute pressure otherwise (§3.3 module selection).
            let pressure = if emergency
                || inp.hottest_mem_frac >= inp.hottest_compute_util
            {
                Pressure::Memory
            } else {
                Pressure::Compute
            };
            self.arm();
            return Decision::ScaleDown { device: inp.hottest_device, pressure };
        }

        if inp.vacancy_rate > self.cfg.t_up && inp.slo_violation_rate == 0.0 {
            self.arm();
            return Decision::ScaleUp;
        }

        Decision::None
    }

    fn arm(&mut self) {
        self.cooldown = self.cfg.cooldown_ticks;
        self.decisions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle() -> ControllerInputs {
        ControllerInputs {
            vacancy_rate: 0.6,
            slo_violation_rate: 0.0,
            oom_events: 0,
            hottest_device: 0,
            hottest_compute_util: 0.2,
            hottest_mem_frac: 0.4,
        }
    }

    fn overloaded() -> ControllerInputs {
        ControllerInputs {
            vacancy_rate: 0.05,
            slo_violation_rate: 0.4,
            oom_events: 0,
            hottest_device: 2,
            hottest_compute_util: 0.99,
            hottest_mem_frac: 0.7,
        }
    }

    #[test]
    fn idle_cluster_scales_up() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.tick(&idle()), Decision::ScaleUp);
    }

    #[test]
    fn slo_violation_scales_down_with_compute_pressure() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(
            c.tick(&overloaded()),
            Decision::ScaleDown { device: 2, pressure: Pressure::Compute }
        );
    }

    #[test]
    fn memory_dominated_device_gets_memory_pressure() {
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = overloaded();
        inp.hottest_mem_frac = 0.99;
        inp.hottest_compute_util = 0.5;
        assert!(matches!(
            c.tick(&inp),
            Decision::ScaleDown { pressure: Pressure::Memory, .. }
        ));
    }

    #[test]
    fn cooldown_suppresses_consecutive_actions() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.tick(&idle()), Decision::ScaleUp);
        assert_eq!(c.tick(&idle()), Decision::None);
        assert_eq!(c.tick(&idle()), Decision::None);
        assert_eq!(c.tick(&idle()), Decision::ScaleUp); // cooldown over
        assert_eq!(c.decisions_made(), 2);
    }

    #[test]
    fn oom_bypasses_cooldown() {
        let mut c = Controller::new(ControllerConfig::default());
        assert_eq!(c.tick(&idle()), Decision::ScaleUp); // arms cooldown
        let mut inp = overloaded();
        inp.oom_events = 3;
        assert!(matches!(c.tick(&inp), Decision::ScaleDown { .. }));
    }

    #[test]
    fn scale_down_outranks_scale_up() {
        // Vacant cluster *and* SLO violations: stability wins.
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = idle();
        inp.slo_violation_rate = 0.2;
        assert!(matches!(c.tick(&inp), Decision::ScaleDown { .. }));
    }

    #[test]
    fn no_action_in_the_healthy_band() {
        let mut c = Controller::new(ControllerConfig::default());
        let mut inp = idle();
        inp.vacancy_rate = 0.2; // below T_up, above trouble
        assert_eq!(c.tick(&inp), Decision::None);
        assert_eq!(c.decisions_made(), 0);
    }
}
