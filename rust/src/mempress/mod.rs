//! Memory-pressure governor — elastic KV resizing and quantized layer
//! swapping *before* any request is shed.
//!
//! The paper treats the KV cache as a first-class migratable module (§3.3),
//! but the OOM path of PRs 1–6 still had only two answers: shed the batch or
//! emergency scale-down. MorphServe (arXiv 2506.02006) shows a third: resize
//! KV pools and swap decoder layers to quantized variants at runtime,
//! freeing HBM without dropping requests; FlexPipe (arXiv 2510.11938) shows
//! such reconfiguration can happen in flight without stalling serving.
//!
//! ### The escalation ladder
//!
//! The governor sits between the scheduler's admission/OOM signals and the
//! plan executor. A governed instance pre-grants its KV pool (the vLLM
//! deployment reality: the pool is reserved up front, whether or not tokens
//! fill it) and the governor arbitrates every pressure episode through a
//! tiered ladder — each rung strictly cheaper than the next:
//!
//! 1. **Elastic pool resize.** Pool exhausted at admission → grow it within
//!    device headroom ([`crate::kvcache::KvCache::resize`], bounded via the
//!    ledger's free bytes). Device ledger pressure → shrink the pool's
//!    pre-granted *waste* (capacity − reserved) back to what live sequences
//!    actually hold.
//! 2. **Quantized layer swapping.** No headroom left → request
//!    [`crate::plan::ModuleOp::SwapPrecision`] on the coldest resident
//!    layers (int8 halves a layer's bytes), executed by the event kernel as
//!    in-flight `OpStarted`/`OpCompleted` events through the full
//!    validate→dry-run→apply→rollback machinery. While relief is in flight
//!    the governor holds admission (a bounded stall), instead of shedding.
//! 3. **Shed.** Relief exhausted (every swappable layer already int8, the
//!    stall budget spent) → escalate to the instance's configured
//!    [`crate::sim::OomBehavior`] (fail-batch / preempt).
//! 4. **Emergency scale-down** stays the policy's last rung, unchanged.
//!
//! ### Determinism
//!
//! The governor is a pure state machine over a [`PressureView`] snapshot:
//! identical traces produce identical decisions, so governed runs golden-
//! replay like everything else. With [`MempressConfig`] unset the governor
//! is never constructed, KV pools stay unbounded, and every byte of the
//! ungoverned kernel's output is untouched (the same `Option<_>` discipline
//! as the PR 5 `forecast` block).

use crate::plan::ScalePlan;

/// Tuning knobs of the memory-pressure governor. Attach to
/// [`crate::sim::SimConfig::mempress`] to enable governing; `None` keeps
/// the kernel byte-identical to the ungoverned one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MempressConfig {
    /// Initial KV pool, as a fraction of the pool device's free bytes
    /// after the instance's weights landed (the pre-granted reservation a
    /// real engine makes at startup).
    pub initial_pool_frac: f64,
    /// Fraction of the tightest KV device's free bytes one grow episode
    /// may claim — the device-headroom bound on elastic growth.
    pub grow_frac: f64,
    /// Bytes granted beyond the immediate admission deficit when growing,
    /// so back-to-back admissions don't each pay a pressure episode.
    pub grow_chunk_bytes: f64,
    /// Most decoder layers the governor may hold at int8 per instance —
    /// the quality-budget ceiling of rung 2.
    pub max_swapped_layers: usize,
    /// Layers quantized per swap request (one in-flight plan).
    pub swap_batch_layers: usize,
    /// Consecutive stalled episodes tolerated while relief is pending
    /// before escalating to the shed rung.
    pub max_stalls: u32,
}

impl Default for MempressConfig {
    fn default() -> MempressConfig {
        MempressConfig {
            initial_pool_frac: 0.5,
            grow_frac: 0.5,
            grow_chunk_bytes: 1024.0 * 1024.0 * 1024.0, // 1 GiB
            max_swapped_layers: 8,
            swap_batch_layers: 4,
            max_stalls: 6,
        }
    }
}

/// Why an instance is under memory pressure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PressureCause {
    /// KV admission failed: the pool lacks `deficit` bytes for the
    /// sequences being admitted.
    PoolExhausted {
        /// Bytes short at admission (summed over the failing sequences).
        deficit: f64,
    },
    /// The device ledger refused the instance's KV mirror (or another
    /// allocation): pressure comes from the device side, not the pool.
    LedgerMirror,
}

/// What the governor decided for one pressure episode.
#[derive(Debug, Clone, PartialEq)]
pub enum Relief {
    /// Grow the KV pool by `grant` bytes (rung 1, admission side).
    GrowPool {
        /// Bytes to add to the pool.
        grant: f64,
    },
    /// Shrink the KV pool to `to` bytes, releasing pre-granted waste back
    /// to the device (rung 1, device side).
    ShrinkPool {
        /// New pool size in bytes (never below live reservations).
        to: f64,
    },
    /// Quantize these layers to int8 via in-flight `SwapPrecision` ops
    /// (rung 2). The kernel admits the plan; admission stalls meanwhile.
    RequestSwaps {
        /// Layer indices to swap, coldest first.
        layers: Vec<usize>,
    },
    /// Relief is already in flight — hold admission one more episode.
    Wait,
    /// Ladder exhausted: fall through to the policy shed (rung 3).
    Escalate,
}

/// Everything the governor needs to know about one pressure episode,
/// snapshotted by the instance. Keeping the decision a pure function of
/// this view is what makes governed runs deterministic and the ladder
/// unit-testable without a simulator.
#[derive(Debug, Clone)]
pub struct PressureView {
    /// Current KV pool capacity in bytes.
    pub pool_bytes: f64,
    /// Bytes of the pool live sequences actually reserve.
    pub reserved_bytes: f64,
    /// Free bytes of the tightest device hosting this instance's KV.
    pub headroom_bytes: f64,
    /// Cold, unquantized, swappable layers (coldest first) on the
    /// pressured device.
    pub swap_candidates: Vec<usize>,
    /// Layers already held at int8.
    pub swapped: usize,
    /// A scaling plan (swap or otherwise) is already executing in flight,
    /// or a swap request awaits kernel pickup.
    pub relief_inflight: bool,
}

/// Counters accumulated by one instance's governor, surfaced through
/// [`MempressReport`] in the metrics JSON.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MempressStats {
    /// Pressure episodes handled (each OOM signal the governor saw).
    pub episodes: u64,
    /// Rung-1 pool grows granted.
    pub kv_grows: u64,
    /// Rung-1 pool shrinks (waste reclaimed to the device).
    pub kv_shrinks: u64,
    /// Total bytes granted to pools by grows.
    pub pool_granted_bytes: f64,
    /// Total pre-granted waste bytes reclaimed by shrinks.
    pub pool_reclaimed_bytes: f64,
    /// Rung-2 swap plans requested.
    pub swap_requests: u64,
    /// `SwapPrecision` ops that landed (in-flight `OpCompleted`).
    pub swaps_applied: u64,
    /// Device bytes freed by landed swaps.
    pub swap_freed_bytes: f64,
    /// Episodes resolved (or stalled) without reaching the shed rung.
    pub sheds_averted: u64,
    /// Episodes that fell through to the policy shed.
    pub escalations: u64,
    /// Accumulated quality-loss units: quantized layers × decode steps ×
    /// [`crate::model::cost::SWAP_QUALITY_PENALTY_PER_STEP`].
    pub quality_penalty: f64,
}

/// Fleet-aggregated governor counters, embedded in the metrics JSON as the
/// `mempress` block (present only when governing is configured).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MempressReport {
    /// Pressure episodes across all instances.
    pub episodes: u64,
    /// Rung-1 pool grows.
    pub kv_grows: u64,
    /// Rung-1 pool shrinks.
    pub kv_shrinks: u64,
    /// Bytes granted to pools.
    pub pool_granted_bytes: f64,
    /// Waste bytes reclaimed from pools.
    pub pool_reclaimed_bytes: f64,
    /// Swap plans requested.
    pub swap_requests: u64,
    /// Swap ops landed.
    pub swaps_applied: u64,
    /// Device bytes freed by swaps.
    pub swap_freed_bytes: f64,
    /// Episodes kept off the shed rung.
    pub sheds_averted: u64,
    /// Episodes escalated to shedding.
    pub escalations: u64,
    /// Accumulated quality-loss units.
    pub quality_penalty: f64,
    /// Layers still at int8 when the run ended.
    pub quantized_layers: u64,
}

impl MempressReport {
    /// Fold one instance's counters into the fleet aggregate.
    pub fn absorb(&mut self, s: &MempressStats) {
        self.episodes += s.episodes;
        self.kv_grows += s.kv_grows;
        self.kv_shrinks += s.kv_shrinks;
        self.pool_granted_bytes += s.pool_granted_bytes;
        self.pool_reclaimed_bytes += s.pool_reclaimed_bytes;
        self.swap_requests += s.swap_requests;
        self.swaps_applied += s.swaps_applied;
        self.swap_freed_bytes += s.swap_freed_bytes;
        self.sheds_averted += s.sheds_averted;
        self.escalations += s.escalations;
        self.quality_penalty += s.quality_penalty;
    }
}

/// Per-instance memory-pressure governor: the ladder state machine plus
/// its counters. Owned by a simulated instance when
/// [`crate::sim::SimConfig::mempress`] is set; never constructed otherwise.
#[derive(Debug)]
pub struct MempressGovernor {
    /// The knobs this governor runs under.
    pub cfg: MempressConfig,
    /// Counters for the metrics JSON.
    pub stats: MempressStats,
    /// Consecutive stalled episodes since the last successful step or
    /// immediate relief — the rung-3 escalation clock.
    stalls: u32,
    /// A swap plan awaiting kernel pickup (admitted as in-flight events).
    pending_swap: Option<ScalePlan>,
}

impl MempressGovernor {
    /// A fresh governor under `cfg`.
    pub fn new(cfg: MempressConfig) -> MempressGovernor {
        MempressGovernor { cfg, stats: MempressStats::default(), stalls: 0, pending_swap: None }
    }

    /// The instance started a step (pressure relieved): reset the stall
    /// escalation clock.
    pub fn note_progress(&mut self) {
        self.stalls = 0;
    }

    /// Park a swap plan for the kernel to admit in flight.
    pub fn park_swap(&mut self, plan: ScalePlan) {
        self.pending_swap = Some(plan);
    }

    /// Take the parked swap plan, if any (kernel pickup point).
    pub fn take_swap_request(&mut self) -> Option<ScalePlan> {
        self.pending_swap.take()
    }

    /// Is a swap request parked and not yet picked up?
    pub fn swap_parked(&self) -> bool {
        self.pending_swap.is_some()
    }

    /// Walk the escalation ladder for one pressure episode. Pure in
    /// `view`; mutates only this governor's counters and stall clock.
    pub fn decide(&mut self, cause: PressureCause, view: &PressureView) -> Relief {
        self.stats.episodes += 1;
        self.stalls += 1;
        // ---- rung 1: elastic pool resize ---------------------------------
        match cause {
            PressureCause::PoolExhausted { deficit } => {
                let grant = (deficit + self.cfg.grow_chunk_bytes)
                    .min(view.headroom_bytes * self.cfg.grow_frac);
                if deficit > 0.0 && grant >= deficit {
                    self.stalls = 0; // relief is immediate
                    self.stats.kv_grows += 1;
                    self.stats.pool_granted_bytes += grant;
                    self.stats.sheds_averted += 1;
                    return Relief::GrowPool { grant };
                }
            }
            PressureCause::LedgerMirror => {
                if view.pool_bytes > view.reserved_bytes {
                    self.stalls = 0;
                    self.stats.kv_shrinks += 1;
                    self.stats.pool_reclaimed_bytes += view.pool_bytes - view.reserved_bytes;
                    self.stats.sheds_averted += 1;
                    return Relief::ShrinkPool { to: view.reserved_bytes };
                }
            }
        }
        // ---- rung 2: quantize cold layers to free device bytes -----------
        if !view.relief_inflight && view.swapped < self.cfg.max_swapped_layers {
            let budget = self.cfg.max_swapped_layers - view.swapped;
            let take = view.swap_candidates.len().min(self.cfg.swap_batch_layers).min(budget);
            if take > 0 {
                self.stats.swap_requests += 1;
                self.stats.sheds_averted += 1;
                return Relief::RequestSwaps {
                    layers: view.swap_candidates[..take].to_vec(),
                };
            }
        }
        // relief already moving — hold admission within the stall budget
        if view.relief_inflight && self.stalls <= self.cfg.max_stalls {
            self.stats.sheds_averted += 1;
            return Relief::Wait;
        }
        // ---- rung 3: out of cheaper answers — shed per policy ------------
        self.stats.escalations += 1;
        Relief::Escalate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn view() -> PressureView {
        PressureView {
            pool_bytes: 4.0 * GIB,
            reserved_bytes: 3.0 * GIB,
            headroom_bytes: 8.0 * GIB,
            swap_candidates: vec![39, 38, 37, 36, 35],
            swapped: 0,
            relief_inflight: false,
        }
    }

    #[test]
    fn admission_pressure_grows_within_headroom() {
        let mut g = MempressGovernor::new(MempressConfig::default());
        let r = g.decide(PressureCause::PoolExhausted { deficit: 0.5 * GIB }, &view());
        let Relief::GrowPool { grant } = r else { panic!("expected grow, got {r:?}") };
        assert!(grant >= 0.5 * GIB, "grant covers the deficit");
        assert!(grant <= 8.0 * GIB * 0.5, "grant bounded by headroom");
        assert_eq!(g.stats.kv_grows, 1);
        assert_eq!(g.stats.sheds_averted, 1);
        assert_eq!(g.stats.escalations, 0);
    }

    #[test]
    fn device_pressure_reclaims_pool_waste_first() {
        let mut g = MempressGovernor::new(MempressConfig::default());
        let r = g.decide(PressureCause::LedgerMirror, &view());
        assert_eq!(r, Relief::ShrinkPool { to: 3.0 * GIB });
        assert_eq!(g.stats.kv_shrinks, 1);
        assert!((g.stats.pool_reclaimed_bytes - GIB).abs() < 1.0);
    }

    #[test]
    fn exhausted_headroom_escalates_to_swaps_then_waits() {
        let mut g = MempressGovernor::new(MempressConfig::default());
        let mut v = view();
        v.headroom_bytes = 0.0; // no room to grow
        let r = g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v);
        let Relief::RequestSwaps { layers } = r else { panic!("expected swaps, got {r:?}") };
        assert_eq!(layers, vec![39, 38, 37, 36], "coldest-first, batch-limited");
        // with the plan in flight the governor holds the line…
        v.relief_inflight = true;
        assert_eq!(g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v), Relief::Wait);
        assert_eq!(g.stats.escalations, 0, "no shedding yet");
    }

    #[test]
    fn stall_budget_bounds_waiting_then_sheds() {
        let cfg = MempressConfig { max_stalls: 2, ..Default::default() };
        let mut g = MempressGovernor::new(cfg);
        let mut v = view();
        v.headroom_bytes = 0.0;
        v.relief_inflight = true;
        assert_eq!(g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v), Relief::Wait);
        assert_eq!(g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v), Relief::Wait);
        // third consecutive stall exceeds the budget
        assert_eq!(
            g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v),
            Relief::Escalate
        );
        assert_eq!(g.stats.escalations, 1);
        // progress resets the clock
        g.note_progress();
        assert_eq!(g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v), Relief::Wait);
    }

    #[test]
    fn swap_budget_is_a_hard_quality_ceiling() {
        let cfg = MempressConfig { max_swapped_layers: 4, ..Default::default() };
        let mut g = MempressGovernor::new(cfg);
        let mut v = view();
        v.headroom_bytes = 0.0;
        v.swapped = 4; // budget spent
        assert_eq!(
            g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v),
            Relief::Escalate,
            "no swaps beyond the quality budget"
        );
        // partial budget: the batch is clamped to what remains
        v.swapped = 3;
        let r = g.decide(PressureCause::PoolExhausted { deficit: GIB }, &v);
        assert_eq!(r, Relief::RequestSwaps { layers: vec![39] });
    }

    #[test]
    fn park_take_roundtrip() {
        let mut g = MempressGovernor::new(MempressConfig::default());
        assert!(!g.swap_parked());
        assert!(g.take_swap_request().is_none());
        g.park_swap(ScalePlan::new());
        assert!(g.swap_parked());
        assert!(g.take_swap_request().is_some());
        assert!(!g.swap_parked());
    }
}
