//! Step-latency measurements over the real artifacts. Artifact-gated like
//! `integration.rs`: skips cleanly when `make artifacts` has not run (the
//! PJRT closure and AOT artifacts are absent on CI and offline builds).

use cocoserve::engine::TinyEngine;
use cocoserve::runtime::{artifacts_available, default_artifacts_dir};
use std::time::Instant;

#[test]
fn measure_steps() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let eng = TinyEngine::open(&default_artifacts_dir(), "tiny-llama").unwrap();
    let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![i as i32 + 1; 12]).collect();
    let mut seqs: Vec<_> = prompts.iter().enumerate().map(|(i,p)| eng.new_sequence(i as u64, p)).collect();
    let t0 = Instant::now();
    { let mut r: Vec<&mut _> = seqs.iter_mut().collect(); eng.prefill(&mut r).unwrap(); }
    eprintln!("prefill b8 s16 (first, incl compile): {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..5 { let mut r: Vec<&mut _> = seqs.iter_mut().collect(); eng.decode(&mut r).unwrap(); }
    eprintln!("decode b8 x5 (first incl compile): {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..20 { let mut r: Vec<&mut _> = seqs.iter_mut().collect(); eng.decode(&mut r).unwrap(); }
    eprintln!("decode b8 x20 warm: {:?} ({:?}/step)", t0.elapsed(), t0.elapsed()/20);
    let mut one = eng.new_sequence(99, &[1,2,3]);
    { let mut r: Vec<&mut _> = vec![&mut one]; eng.prefill(&mut r).unwrap(); }
    let t0 = Instant::now();
    for _ in 0..20 { let mut r: Vec<&mut _> = vec![&mut one]; eng.decode(&mut r).unwrap(); }
    eprintln!("decode b1 x20 warm: {:?} ({:?}/step)", t0.elapsed(), t0.elapsed()/20);
}
