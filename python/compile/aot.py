"""AOT export: lower every module function to HLO *text* + write manifest.

This is the only place Python touches the serving pipeline — it runs once at
build time (`make artifacts`); the Rust coordinator loads the results and
Python is never on the request path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 crate links) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly.

Outputs, under --out-dir (default ../artifacts):

  manifest.json                     — configs, buckets, artifact + weight index
  hlo/<name>.hlo.txt                — one per (module kind, shape bucket)
  weights/<cfg>/<tensor>.bin        — raw little-endian f32, row-major

Every artifact is lowered with return_tuple=True, so the Rust side always
unwraps a tuple (even for single outputs).
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model

# Shape buckets actually lowered. Kept deliberately modest: artifacts are
# shape-specialized, and the Rust scheduler pads to the next bucket.
BATCHES = configs.BATCH_BUCKETS
SEQS = configs.PREFILL_SEQ_BUCKETS
SMAX = configs.MAX_SEQ_LEN


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _weight_specs(cfg):
    return [
        _spec(s) for s in model.layer_weight_shapes(cfg).values()
    ]


class ArtifactSet:
    """Collects (name -> lowered fn) and writes hlo/ + manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.hlo_dir = os.path.join(out_dir, "hlo")
        os.makedirs(self.hlo_dir, exist_ok=True)
        self.entries = []

    def add(self, name: str, fn, arg_specs, *, module: str, phase: str,
            cfg, b: int, s: int, outputs: list):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        rel = os.path.join("hlo", f"{name}.hlo.txt")
        with open(os.path.join(self.out_dir, rel), "w") as f:
            f.write(text)
        self.entries.append({
            "name": name,
            "path": rel,
            "module": module,
            "phase": phase,
            "config": cfg.name,
            "batch": b,
            "seq": s,
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in arg_specs
            ],
            "outputs": outputs,
        })
        print(f"  {name}: {len(text)} chars ({time.time() - t0:.2f}s)")


def lower_config(art: ArtifactSet, cfg) -> None:
    d, h, ff, v = cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab_size
    hd = cfg.head_dim
    w = _weight_specs(cfg)
    n = cfg.name

    for b in BATCHES:
        # ---- decode-phase artifacts (seq axis fixed: 1 new token) --------
        hid1 = _spec((b, 1, d))
        kc = _spec((b, h, SMAX, hd))
        lens = _spec((b,), jnp.int32)
        art.add(f"{n}__layer_decode__b{b}",
                functools.partial(model.layer_decode, n_heads=h),
                [hid1, kc, kc, lens] + w,
                module="decoder_layer", phase="decode", cfg=cfg, b=b, s=1,
                outputs=["hidden", "k_new", "v_new"])
        art.add(f"{n}__attn_decode__b{b}",
                functools.partial(model.attn_decode, n_heads=h),
                [hid1, kc, kc, lens] + w[:5],
                module="attn", phase="decode", cfg=cfg, b=b, s=1,
                outputs=["hidden", "k_new", "v_new"])
        art.add(f"{n}__ffn_decode__b{b}", model.ffn,
                [hid1] + [w[5], w[6], w[7], w[8]],
                module="ffn", phase="decode", cfg=cfg, b=b, s=1,
                outputs=["hidden"])
        art.add(f"{n}__lm_head_decode__b{b}", model.lm_head_decode,
                [hid1, _spec((d,)), _spec((d, v))],
                module="lm_head", phase="decode", cfg=cfg, b=b, s=1,
                outputs=["next_token", "logits"])
        art.add(f"{n}__embed_decode__b{b}", model.embed,
                [_spec((b, 1), jnp.int32), _spec((v, d))],
                module="embed", phase="decode", cfg=cfg, b=b, s=1,
                outputs=["hidden"])

        # ---- prefill-phase artifacts, per sequence bucket ----------------
        for s in SEQS:
            hid = _spec((b, s, d))
            pos = _spec((b, s), jnp.int32)
            art.add(f"{n}__embed__b{b}_s{s}", model.embed,
                    [_spec((b, s), jnp.int32), _spec((v, d))],
                    module="embed", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["hidden"])
            art.add(f"{n}__layer_prefill__b{b}_s{s}",
                    functools.partial(model.layer_prefill, n_heads=h),
                    [hid, pos] + w,
                    module="decoder_layer", phase="prefill", cfg=cfg,
                    b=b, s=s, outputs=["hidden", "k", "v"])
            art.add(f"{n}__attn_prefill__b{b}_s{s}",
                    functools.partial(model.attn_prefill, n_heads=h),
                    [hid, pos] + w[:5],
                    module="attn", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["hidden", "k", "v"])
            art.add(f"{n}__ffn_prefill__b{b}_s{s}", model.ffn,
                    [hid, w[5], w[6], w[7], w[8]],
                    module="ffn", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["hidden"])
            art.add(f"{n}__qkv_proj__b{b}_s{s}",
                    functools.partial(model.qkv_proj, n_heads=h),
                    [hid, pos, w[0], w[1], w[2], w[3]],
                    module="qkv_proj", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["q", "k", "v"])
            art.add(f"{n}__attn_core__b{b}_s{s}", model.attn_core_prefill,
                    [_spec((b, h, s, hd))] * 3,
                    module="attn_core", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["attn_out"])
            art.add(f"{n}__o_proj__b{b}_s{s}", model.o_proj,
                    [hid, hid, _spec((d, d))],
                    module="o_proj", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["hidden"])
            art.add(f"{n}__lm_head_prefill__b{b}_s{s}", model.lm_head_prefill,
                    [hid, _spec((b,), jnp.int32), _spec((d,)),
                     _spec((d, v))],
                    module="lm_head", phase="prefill", cfg=cfg, b=b, s=s,
                    outputs=["next_token", "logits"])


def dump_weights(out_dir: str, cfg, seed: int = 0) -> dict:
    """Write synthetic weights as raw f32 .bin files; return the index."""
    wdir = os.path.join(out_dir, "weights", cfg.name)
    os.makedirs(wdir, exist_ok=True)
    weights = model.init_weights(cfg, seed)

    index = {}

    def put(name, arr):
        rel = os.path.join("weights", cfg.name, f"{name}.bin")
        np.asarray(arr, dtype=np.float32).tofile(os.path.join(out_dir, rel))
        index[name] = {"path": rel, "shape": list(arr.shape)}

    for i, lw in enumerate(weights["layers"]):
        for wname in model.LAYER_WEIGHT_NAMES:
            put(f"layer{i}.{wname}", lw[wname])
    put("emb", weights["emb"])
    put("w_out", weights["w_out"])
    put("rms_f", weights["rms_f"])
    return index


def dump_goldens(out_dir: str, cfg, seed: int = 0) -> dict:
    """Golden greedy generations from the pure-jnp reference model.

    The Rust engine must reproduce these token ids exactly — the
    end-to-end correctness contract across all three layers.
    """
    weights = model.init_weights(cfg, seed)
    prompts = [
        [1, 2, 3],
        [7, 11, 13, 17, 19],
        [42] * 8,
        list(range(30, 42)),
    ]
    n_new = 8
    outs = model.forward_greedy(cfg, weights, prompts, n_new)
    return {
        "config": cfg.name,
        "seed": seed,
        "n_new": n_new,
        "prompts": prompts,
        "expected": outs,
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    p.add_argument("--configs", nargs="*", default=["tiny-llama"],
                   help="which model configs to lower (default: tiny-llama)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    art = ArtifactSet(out_dir)

    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "batch_buckets": list(BATCHES),
        "seq_buckets": list(SEQS),
        "max_seq_len": SMAX,
        "configs": {},
        "weights": {},
        "artifacts": [],
    }
    t0 = time.time()
    for name in args.configs:
        cfg = configs.CONFIGS[name]
        print(f"lowering config {name} "
              f"(d={cfg.d_model}, heads={cfg.n_heads}, ff={cfg.d_ff})")
        manifest["configs"][name] = cfg.to_dict()
        lower_config(art, cfg)
        manifest["weights"][name] = dump_weights(out_dir, cfg, args.seed)
        with open(os.path.join(out_dir, f"goldens_{name}.json"), "w") as f:
            json.dump(dump_goldens(out_dir, cfg, args.seed), f, indent=1)
    # Paper-scale configs ride along for the Rust cost model / simulator.
    for name in ("llama2-13b", "llama2-70b"):
        manifest["configs"][name] = configs.CONFIGS[name].to_dict()

    manifest["artifacts"] = art.entries
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"{len(art.entries)} artifacts -> {out_dir} "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
