//! Fig. 14 (extension) — failure-domain chaos sweep: seeded device
//! deaths injected into every scenario shape, with the conservation
//! invariants asserted and the audit trail dumped for byte-diffing.
//!
//! The fleet is deliberately heterogeneous: two on-demand devices
//! (A100 + H100) that the chaos schedule never touches, and two spot
//! A100s that are exactly the preemption targets. Instance 0 seeds on
//! the safe A100, instance 1 on a spot device, and the elastic fleet
//! may spin up to all four. Each scenario then takes `CHAOS_FAILURES`
//! seeded deaths over the middle of the run.
//!
//! Asserted per scenario:
//! (a) **replay determinism** — two runs of the same seed produce
//!     byte-identical metrics JSON *including* the audit records;
//! (b) **request conservation** — completed + parked-at-deadline equals
//!     the trace length: failures shed and re-route, never lose;
//! (c) **audit completeness** — exactly one `device_failed` record per
//!     scheduled death;
//! and across the sweep: at least one death interrupted live work (some
//! recovery, shed, or forced-release record exists).
//!
//! ```bash
//! cargo bench --bench fig14_chaos                   # full sweep
//! FIG14_SMOKE=1 cargo bench --bench fig14_chaos     # CI smoke
//! CHAOS_SEED=7 GOLDEN_OUT=chaos.json cargo bench --bench fig14_chaos
//! ```
//!
//! `GOLDEN_OUT=<path>` writes the concatenated per-scenario metrics
//! JSON (audit trail included); CI runs the smoke twice with the same
//! `CHAOS_SEED` and byte-compares the two files.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, RoutePolicy, RouterConfig};
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{FailureSchedule, Trace};

struct BenchShape {
    rps: f64,
    duration_s: f64,
    seed: u64,
    failures: usize,
    smoke: bool,
}

impl BenchShape {
    fn from_env() -> BenchShape {
        let smoke = std::env::var("FIG14_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
            || std::env::args().any(|a| a == "--smoke");
        let seed = std::env::var("CHAOS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(140);
        let failures = std::env::var("CHAOS_FAILURES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2);
        if smoke {
            BenchShape { rps: 12.0, duration_s: 16.0, seed, failures, smoke }
        } else {
            BenchShape { rps: 14.0, duration_s: 40.0, seed, failures, smoke }
        }
    }
}

/// The mixed on-demand + spot fleet. Spot devices are the chaos targets.
fn chaos_cluster() -> Cluster {
    Cluster::mixed(vec![
        DeviceSpec::a100_40gb(),
        DeviceSpec::a100_40gb().spot(),
        DeviceSpec::h100_80gb(),
        DeviceSpec::a100_40gb().spot(),
    ])
}

fn run(trace: &Trace, shape: &BenchShape, schedule: &FailureSchedule) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let policy = baselines::cocoserve(32);
    // instance 0 on the safe A100, instance 1 on a spot device
    let placements = vec![
        (Placement::single_device(cfg.model.n_layers, 0), policy),
        (Placement::single_device(cfg.model.n_layers, 1), policy),
    ];
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 4, policy)),
        ..Default::default()
    };
    Simulation::with_fleet(cfg, chaos_cluster(), placements, setup)
        .with_failures(schedule.clone())
        .run(trace, shape.duration_s)
}

/// Count audit records of one kind.
fn kind_count(r: &SimReport, kind: &str) -> usize {
    r.audit
        .as_ref()
        .map_or(0, |a| a.log.records().iter().filter(|rec| rec.kind.name() == kind).count())
}

fn main() {
    let shape = BenchShape::from_env();
    let golden_out = std::env::var("GOLDEN_OUT").ok().filter(|p| !p.is_empty());
    let targets = chaos_cluster().preemptible_devices();
    println!(
        "Fig. 14 — chaos sweep: 13B elastic fleet on 2 on-demand + {} spot devices, \
         {} seeded deaths (seed {}), {:.0} rps, {:.0}s{}\n",
        targets.len(),
        shape.failures,
        shape.seed,
        shape.rps,
        shape.duration_s,
        if shape.smoke { " (SMOKE)" } else { "" }
    );

    let schedule =
        FailureSchedule::seeded(&targets, shape.duration_s, shape.failures, shape.seed);
    for f in &schedule.failures {
        println!("  scheduled death: device {} at t={:.2}s", f.device, f.t);
    }
    println!();

    let sweep = Trace::scenario_sweep(shape.rps, shape.duration_s, shape.seed);
    let mut table = Table::new(&[
        "scenario", "requests", "completed", "reroutes", "deaths", "migrations",
        "lost", "shed", "unrouted", "dev·s",
    ]);
    let mut rep = Report::new("fig14_chaos");
    let mut replay_ok = true;
    let mut recovery_activity = 0usize;
    let mut dump = String::new();

    for (name, trace) in &sweep {
        let r = run(trace, &shape, &schedule);
        // (a) replay determinism, audit trail included
        let again = run(trace, &shape, &schedule);
        let rj = r.to_json().to_string();
        let identical = rj == again.to_json().to_string();
        replay_ok &= identical;
        if !identical {
            eprintln!("WARNING: chaos scenario `{name}` not replay-deterministic");
        }

        let audit = r.audit.as_ref().expect("chaos runs carry an audit block");
        let unrouted = audit.unrouted_at_end;
        // (b) conservation: every arrival completed once or still parked
        assert_eq!(
            r.total_completed() + unrouted,
            trace.len(),
            "`{name}`: {} completed + {unrouted} unrouted != {} arrivals",
            r.total_completed(),
            trace.len()
        );
        // (c) one audit record per scheduled death
        let deaths = kind_count(&r, "device_failed");
        assert_eq!(deaths, schedule.len(), "`{name}`: audit missed a death");

        let migrations = kind_count(&r, "emergency_migration");
        let lost = kind_count(&r, "instance_lost");
        let shed: usize = kind_count(&r, "requests_shed");
        recovery_activity += migrations + lost + shed + kind_count(&r, "replica_dropped");

        table.row(&[
            name.to_string(),
            trace.len().to_string(),
            r.total_completed().to_string(),
            r.reroutes.to_string(),
            deaths.to_string(),
            migrations.to_string(),
            lost.to_string(),
            shed.to_string(),
            unrouted.to_string(),
            format!("{:.0}", r.device_seconds),
        ]);
        rep.set(
            name,
            json::obj(vec![
                ("requests", json::num(trace.len() as f64)),
                ("completed", json::num(r.total_completed() as f64)),
                ("reroutes", json::num(r.reroutes as f64)),
                ("deaths", json::num(deaths as f64)),
                ("emergency_migrations", json::num(migrations as f64)),
                ("instances_lost", json::num(lost as f64)),
                ("unrouted_at_end", json::num(unrouted as f64)),
                ("device_seconds", json::num(r.device_seconds)),
                ("audit_records", json::num(audit.log.len() as f64)),
                ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
            ]),
        );
        if golden_out.is_some() {
            dump.push_str(name);
            dump.push('\n');
            dump.push_str(&rj);
            dump.push('\n');
        }
    }

    table.print();
    assert!(
        recovery_activity > 0,
        "no death ever interrupted live work — the chaos schedule is miscalibrated"
    );
    println!(
        "\ngolden replay across all scenarios: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    if let Some(path) = &golden_out {
        std::fs::write(path, dump).expect("write GOLDEN_OUT");
        println!("golden metrics: {path} (seed={})", shape.seed);
    }
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
