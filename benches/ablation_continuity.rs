//! Ablation — Algorithm 1's continuity-sorted candidate order vs random.
//!
//! DESIGN.md calls this design choice out: `SortCandidatesByContinuity`
//! exists to minimize scatter/all-gather boundaries (§3.2). The ablation
//! replicates the same number of layers with (a) continuity order and
//! (b) shuffled order, then compares dataflow transitions and the
//! resulting serving latency.

use cocoserve::cluster::Cluster;
use cocoserve::model::cost::CostModel;
use cocoserve::ops::{ModuleOps, PlanExecutor};
use cocoserve::placement::Placement;
use cocoserve::plan::{ModuleOp, ScalePlan};
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::sim::{OomBehavior, SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::util::rng::Rng;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const BUDGETS: [usize; 3] = [10, 20, 30];

fn policy() -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::continuous(16),
        paged_kv: true,
        autoscale: false,
        oom: OomBehavior::Preempt,
    }
}

/// Replicate `budget` layers onto devices 1–3 in the given layer order,
/// as one executed plan.
fn build(order: &[usize], budget: usize) -> Placement {
    let cfg = SimConfig::paper_13b();
    let mut p = Placement::single_device(cfg.model.n_layers, 0);
    let cm = CostModel::new(cfg.model);
    let ops = ModuleOps::new(&cm, 2, "inst0");
    let mut scratch = Cluster::paper_testbed();
    ops.deploy_instance(&mut scratch, &p).unwrap();
    let mut plan = ScalePlan::new();
    for (i, &l) in order.iter().take(budget).enumerate() {
        plan.push(ModuleOp::Replicate { layer: l, dst: 1 + i % 3 });
    }
    PlanExecutor::new(&ops).execute(&mut scratch, &mut p, &plan).unwrap();
    p
}

fn latency(p: &Placement) -> f64 {
    let cfg = SimConfig::paper_13b();
    let sim = Simulation::new(cfg, Cluster::paper_testbed(), vec![(p.clone(), policy())]);
    let trace = Trace::generate(Arrival::Poisson { rps: 40.0 }, LengthDist::alpaca(), 15.0, 8);
    sim.run(&trace, 15.0).merged_latency().mean()
}

fn main() {
    println!("Ablation — continuity-sorted vs random replication order\n");
    let mut t = Table::new(&["budget", "cont. transitions", "rand transitions",
                             "cont. lat(s)", "rand lat(s)"]);
    let mut rep = Report::new("ablation_continuity");
    let mut rng = Rng::new(77);
    for &budget in &BUDGETS {
        // continuity order: contiguous block split per device (what
        // SortCandidatesByContinuity converges to from an empty placement)
        let per = budget / 3 + 1;
        let mut cont_order = vec![];
        for d in 0..3 {
            for l in (d * per)..((d + 1) * per).min(40) {
                cont_order.push(l);
            }
        }
        // …but assign device by block: rebuild manually for contiguity
        let cfg = SimConfig::paper_13b();
        let mut p_cont = Placement::single_device(cfg.model.n_layers, 0);
        {
            let cm = CostModel::new(cfg.model.clone());
            let ops = ModuleOps::new(&cm, 2, "inst0");
            let mut scratch = Cluster::paper_testbed();
            ops.deploy_instance(&mut scratch, &p_cont).unwrap();
            let mut plan = ScalePlan::new();
            for (i, &l) in cont_order.iter().take(budget).enumerate() {
                let dst = 1 + (i / per).min(2);
                plan.push(ModuleOp::Replicate { layer: l, dst });
            }
            PlanExecutor::new(&ops).execute(&mut scratch, &mut p_cont, &plan).unwrap();
        }

        let mut rand_order: Vec<usize> = (0..40).collect();
        rng.shuffle(&mut rand_order);
        let p_rand = build(&rand_order, budget);

        let (tc, tr) = (p_cont.transition_count(), p_rand.transition_count());
        let (lc, lr) = (latency(&p_cont), latency(&p_rand));
        t.row(&[
            format!("{budget}"),
            format!("{tc}"),
            format!("{tr}"),
            format!("{lc:.2}"),
            format!("{lr:.2}"),
        ]);
        rep.set(
            &format!("budget{budget}"),
            json::arr([tc as f64, tr as f64, lc, lr].into_iter().map(json::num)),
        );
        assert!(tc <= tr, "continuity order must not increase transitions");
    }
    t.print();
    println!(
        "\ncontinuity ordering keeps replicated runs contiguous → fewer \
         scatter/all-gather boundaries → lower communication share (§3.2)."
    );
    println!("report: {}", rep.write().unwrap().display());
}
