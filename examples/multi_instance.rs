//! Multi-instance efficiency — the paper's Fig. 10 / §6.3 scenario.
//!
//! Compares CoCoServe×2 against HFT×2 and HFT×4 on the 4×A100 testbed:
//! CoCoServe's 2 instances harvest the idle devices with layer replicas,
//! approaching HFT×4's performance at roughly half the memory cost.
//!
//! ```bash
//! cargo run --release --example multi_instance
//! ```

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, Simulation};
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn run(n_instances: usize, policy: SimPolicy, label: &str) {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::paper_testbed();
    let placements: Vec<_> = (0..n_instances)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % 4),
                policy,
            )
        })
        .collect();
    let sim = Simulation::new(cfg, cluster, placements);
    let trace = Trace::generate(
        Arrival::Poisson { rps: 30.0 },
        LengthDist::alpaca(),
        25.0,
        17,
    );
    let r = sim.run(&trace, 25.0);
    let mut lat = r.merged_latency();
    println!(
        "{label:<14} lat {:>6.2}s  p95 {:>6.2}s  thr {:>7.1} tok/s  peak mem {:>6.1} GiB",
        lat.mean(),
        lat.p95(),
        r.total_throughput_tps(),
        r.peak_mem_bytes / GIB
    );
}

fn main() {
    println!("== Fig. 10 scenario: 30 RPS over 4×A100, multi-instance ==\n");
    run(2, baselines::hft(16), "HFT × 2");
    run(4, baselines::hft(16), "HFT × 4");
    run(2, baselines::cocoserve(16), "CoCoServe × 2");
    println!(
        "\nCoCoServe×2 approaches HFT×4 performance while holding roughly the\n\
         ×2 memory footprint — the paper's 46% cost-reduction claim (§6.3)."
    );
}
