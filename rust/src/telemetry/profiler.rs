//! Kernel self-profiler: per-event-kind wall-time, event-count and
//! allocation histogram.
//!
//! The run loop wraps every `dispatch` call: it reads the event's
//! [`slot`](crate::sim::events::EventKind::slot) before dispatching,
//! samples the (optional) allocation counter and a monotonic clock
//! around the call, and records the deltas here. Wall-clock therefore
//! never touches simulation state — the profile is reported through
//! [`crate::sim::metrics::SimReport::profile`], which the golden metrics
//! JSON deliberately omits (`BENCH_fleet.json` is its home), so profiled
//! and unprofiled runs stay byte-identical on the golden surface.

use crate::sim::events::EventKind;
use crate::util::json::{self, Json};

/// Accumulator for one event kind.
#[derive(Debug, Clone, Copy, Default)]
struct ProfSlot {
    events: u64,
    wall_ns: u64,
    allocs: u64,
}

/// One row of the finished per-event-kind breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfRow {
    /// Event kind name (from [`EventKind::SLOT_NAMES`]).
    pub kind: &'static str,
    /// Events of this kind dispatched.
    pub events: u64,
    /// Total wall time spent inside dispatch for this kind (ns).
    pub wall_ns: u64,
    /// Heap allocations performed while dispatching this kind (0 when
    /// no allocation probe was installed).
    pub allocs: u64,
}

/// The finished profile: one row per event kind, dispatch order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct KernelProfile {
    /// Per-kind rows (all [`EventKind::N_SLOTS`] kinds, zero rows kept
    /// so the table shape is stable).
    pub rows: Vec<ProfRow>,
}

impl KernelProfile {
    /// Total events across kinds.
    pub fn total_events(&self) -> u64 {
        self.rows.iter().map(|r| r.events).sum()
    }

    /// Total dispatch wall time across kinds (ns).
    pub fn total_wall_ns(&self) -> u64 {
        self.rows.iter().map(|r| r.wall_ns).sum()
    }

    /// Serialize as the `profile` table of `BENCH_fleet.json`: an array
    /// of rows with each kind's event count, wall nanoseconds, share of
    /// total dispatch wall time, and allocation count.
    pub fn to_json(&self) -> Json {
        let total_ns = self.total_wall_ns().max(1) as f64;
        json::arr(self.rows.iter().map(|r| {
            json::obj(vec![
                ("allocs", json::num(r.allocs as f64)),
                ("events", json::num(r.events as f64)),
                ("kind", json::s(r.kind)),
                ("wall_ns", json::num(r.wall_ns as f64)),
                ("wall_share", json::num(r.wall_ns as f64 / total_ns)),
            ])
        }))
    }

    /// Print the breakdown as an aligned table, hottest kind first.
    pub fn print(&self) {
        let total_ns = self.total_wall_ns().max(1) as f64;
        let mut rows: Vec<&ProfRow> = self.rows.iter().collect();
        rows.sort_by(|a, b| b.wall_ns.cmp(&a.wall_ns).then(a.kind.cmp(b.kind)));
        println!(
            "  {:<14} {:>12} {:>12} {:>8} {:>12}",
            "event kind", "events", "wall_ms", "share", "allocs"
        );
        for r in rows {
            println!(
                "  {:<14} {:>12} {:>12.3} {:>7.1}% {:>12}",
                r.kind,
                r.events,
                r.wall_ns as f64 / 1e6,
                100.0 * r.wall_ns as f64 / total_ns,
                r.allocs,
            );
        }
    }
}

/// Live profiler the run loop records into. Construction is the only
/// allocation; recording is two integer adds into a fixed table.
#[derive(Debug)]
pub struct KernelProfiler {
    slots: [ProfSlot; EventKind::N_SLOTS],
    probe: Option<fn() -> u64>,
}

impl KernelProfiler {
    /// A profiler with an optional allocation counter (benches pass
    /// their counting-allocator reader; `None` records 0 allocs).
    pub fn new(probe: Option<fn() -> u64>) -> KernelProfiler {
        KernelProfiler { slots: [ProfSlot::default(); EventKind::N_SLOTS], probe }
    }

    /// Sample the allocation counter (0 without a probe). Call before
    /// and after dispatch; pass the delta to [`KernelProfiler::record`].
    #[inline]
    pub fn probe_now(&self) -> u64 {
        match self.probe {
            Some(f) => f(),
            None => 0,
        }
    }

    /// Record one dispatched event of kind-`slot` with its measured
    /// wall time and allocation delta.
    #[inline]
    pub fn record(&mut self, slot: usize, wall_ns: u64, allocs: u64) {
        let s = &mut self.slots[slot];
        s.events += 1;
        s.wall_ns += wall_ns;
        s.allocs += allocs;
    }

    /// Finish into the per-kind table.
    pub fn finish(self) -> KernelProfile {
        KernelProfile {
            rows: self
                .slots
                .iter()
                .enumerate()
                .map(|(i, s)| ProfRow {
                    kind: EventKind::SLOT_NAMES[i],
                    events: s.events,
                    wall_ns: s.wall_ns,
                    allocs: s.allocs,
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_bucket_by_slot() {
        let mut p = KernelProfiler::new(None);
        let arrival = EventKind::Arrival { request_idx: 0 }.slot();
        let step = EventKind::StepComplete { instance: 0, token: 0 }.slot();
        p.record(arrival, 100, 2);
        p.record(arrival, 50, 0);
        p.record(step, 900, 1);
        let prof = p.finish();
        assert_eq!(prof.rows.len(), EventKind::N_SLOTS);
        assert_eq!(prof.rows[arrival].events, 2);
        assert_eq!(prof.rows[arrival].wall_ns, 150);
        assert_eq!(prof.rows[arrival].allocs, 2);
        assert_eq!(prof.rows[step].kind, "StepComplete");
        assert_eq!(prof.total_events(), 3);
        assert_eq!(prof.total_wall_ns(), 1050);
    }

    #[test]
    fn json_shares_sum_to_one() {
        let mut p = KernelProfiler::new(None);
        p.record(0, 250, 0);
        p.record(7, 750, 0);
        let j = p.finish().to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), EventKind::N_SLOTS);
        let total: f64 =
            rows.iter().map(|r| r.get("wall_share").unwrap().as_f64().unwrap()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert_eq!(rows[0].get("kind").unwrap().as_str().unwrap(), "Arrival");
    }

    #[test]
    fn probe_feeds_alloc_deltas() {
        fn fake_counter() -> u64 {
            42
        }
        let p = KernelProfiler::new(Some(fake_counter));
        assert_eq!(p.probe_now(), 42);
        let p = KernelProfiler::new(None);
        assert_eq!(p.probe_now(), 0);
    }
}
